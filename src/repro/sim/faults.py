"""Deterministic fault injection for the FL simulator — the failure axis.

The network models (sim/network.py) make clients *slow*; this module makes
them *fail*, the regime FedDD is motivated by (cross-device fleets with
constant churn — Bonawitz et al., 1812.07210).  A fault model composes
with any :class:`~repro.sim.network.NetworkModel`: the network decides how
fast a round trip would be, the fault model decides whether (and in what
shape) it completes.  Three failure channels:

* **crash / churn** — the client dies part-way through its round trip
  (probability per communication epoch).  Events after the crash instant
  are never scheduled, so the upload never arrives and the server's
  telemetry EWMA keeps its last estimate (it never saw a measurement —
  the gap is *skipped*, not zero-filled).
* **lossy uplink** — the upload is chunked; every chunk is retransmitted
  under exponential backoff until it lands or ``max_retries`` is spent.
  Retries are charged REAL codec bytes (repro.comm) on both the event
  timeline and the Eq. (12) clock; an exhausted chunk abandons the whole
  upload (the bytes already sent are wasted — ``abandoned_bytes``).
* **corrupted payloads** — bit-flip / NaN / Inf injection into the upload
  the server decodes.  The client's own state stays clean (corruption is
  on the wire); the server's validation screen
  (:func:`screen_quarantine`) quarantines non-finite or norm-anomalous
  updates with a 0 weight on the stacked Eq. (4) aggregation — the same
  mechanism baselines use for non-participation, so the fused engines
  need no new code path.

Determinism contract (tests/test_faults.py): every draw comes from
``np.random.default_rng((seed, tag, epoch, client))`` — a SeedSequence
key, so the fault sequence is a pure function of (seed, epoch, client),
independent of call order and identical across processes.  The sim's
``(time, seq)`` event ordering is untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

CORRUPT_KINDS = ("bitflip", "nan", "inf")

# SeedSequence domain tags: fault draws vs corruption noise can never
# collide even for equal (seed, epoch, client).
_TAG_FAULTS = 0xFA
_TAG_CORRUPT = 0xC0


@dataclasses.dataclass(frozen=True)
class ValidationConfig:
    """Server-side payload screening knobs.

    ``norm_factor`` quarantines an arrived update whose l2 norm exceeds
    ``norm_factor`` x the median norm of this round's finite arrivals
    (<= 0 disables the norm screen); the median needs at least
    ``min_reference`` finite arrivals to be meaningful, with a hard
    floor of 3 (see :func:`screen_quarantine` — survivor sets of 1–2
    are finite-checked only).  Non-finite (NaN/Inf) updates are always
    quarantined when ``screen_nonfinite``.
    """

    screen_nonfinite: bool = True
    norm_factor: float = 10.0
    min_reference: int = 3


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Failure-channel rates and server-degradation knobs.

    crash_rate: per-epoch probability a scheduled client dies mid-round.
    loss_rate: per-chunk uplink packet-loss probability.
    chunk_bytes: uplink chunking granularity (bytes).
    backoff_base: first retransmit backoff (seconds); doubles per retry.
    max_retries: retransmit budget per chunk; exhaustion abandons the
      whole upload.
    corrupt_rate: probability an arriving upload is corrupted on the wire.
    corrupt_kind: ``bitflip`` | ``nan`` | ``inf`` | ``mix`` (uniform draw).
    quorum: minimum VALID contributions per round — a float in (0,1) is a
      fraction of the scheduled participants, an int an absolute count
      (floored at 1: a fault-aware server never aggregates an empty
      round).  Below the floor the round is skipped: global held, client
      params held, allocation LP re-solved on survivor-only telemetry.
    staleness_budget: buffered-async analogue of quorum (0 = unlimited):
      at merge time, buffered updates staler than this many versions are
      dropped and charged as abandoned bytes; the merge proceeds only
      when the surviving buffered mass still meets the quorum floor,
      otherwise the server keeps buffering.
    seed: fault-stream seed (independent of the run seed on purpose, so a
      fault scenario can be replayed over different training seeds).
    validation: :class:`ValidationConfig` for the quarantine screen.
    """

    crash_rate: float = 0.0
    loss_rate: float = 0.0
    chunk_bytes: float = 4096.0
    backoff_base: float = 0.05
    max_retries: int = 5
    corrupt_rate: float = 0.0
    corrupt_kind: str = "mix"
    quorum: float = 1
    staleness_budget: int = 0
    seed: int = 0
    validation: ValidationConfig = dataclasses.field(
        default_factory=ValidationConfig)

    def __post_init__(self):
        for name in ("crash_rate", "loss_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0,1], got {v}")
        if self.corrupt_kind not in CORRUPT_KINDS + ("mix",):
            raise ValueError(f"corrupt_kind must be one of "
                             f"{CORRUPT_KINDS + ('mix',)}, "
                             f"got {self.corrupt_kind!r}")
        if self.chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.quorum < 0:
            raise ValueError("quorum must be >= 0")
        if self.staleness_budget < 0:
            raise ValueError("staleness_budget must be >= 0 "
                             "(0 = unlimited)")


@dataclasses.dataclass
class RoundFaults:
    """The fault draw of one communication epoch; arrays shaped (N,).

    ``crashed`` clients die at ``dispatch + crash_frac * round_trip``;
    ``aborted`` clients exhausted a chunk's retransmit budget (their
    upload never arrives; ``sent_bytes`` already crossed the wire);
    surviving lossy clients arrive ``extra_delay`` seconds late having
    moved ``extra_bytes`` duplicate bytes in ``retries`` retransmits.
    ``corrupt`` holds 0 (clean) or 1 + index into :data:`CORRUPT_KINDS`.
    ``outages`` carries the epoch's cell-level ``outage_begin`` /
    ``outage_end`` incident dicts (repro.sim.outages), forwarded to the
    observability layer by :func:`incident_events`.
    """

    crashed: np.ndarray        # bool
    crash_frac: np.ndarray     # float in [0,1)
    aborted: np.ndarray        # bool
    retries: np.ndarray        # int
    extra_bytes: np.ndarray    # float, retransmitted duplicate bytes
    extra_delay: np.ndarray    # float, seconds added to the upload leg
    sent_bytes: np.ndarray     # float, bytes wasted by aborted uploads
    corrupt: np.ndarray        # int, 0 = clean
    outages: list = dataclasses.field(default_factory=list)

    @classmethod
    def clean(cls, n: int) -> "RoundFaults":
        return cls(crashed=np.zeros(n, bool), crash_frac=np.zeros(n),
                   aborted=np.zeros(n, bool), retries=np.zeros(n, int),
                   extra_bytes=np.zeros(n), extra_delay=np.zeros(n),
                   sent_bytes=np.zeros(n), corrupt=np.zeros(n, int))


class FaultModel:
    """Base: ``round_faults(epoch, wire_bytes, uplink_rate)`` -> the
    epoch's :class:`RoundFaults` (pure function of the constructor
    seed/script and its arguments)."""

    config: FaultConfig

    def round_faults(self, epoch: int, wire_bytes: np.ndarray,
                     uplink_rate: np.ndarray) -> RoundFaults:
        raise NotImplementedError

    @property
    def may_corrupt(self) -> bool:
        return self.config.corrupt_rate > 0.0

    def quorum_floor(self, scheduled: int) -> int:
        """Resolved minimum valid-contribution count for a round with
        ``scheduled`` dispatched participants."""
        q = self.config.quorum
        k = int(np.ceil(q * scheduled)) if 0.0 < q < 1.0 else int(q)
        return max(1, min(k, scheduled) if scheduled else 1)

    def outage_mask(self, epoch: int) -> Optional[np.ndarray]:
        """(N,) bool mask of clients inside an active correlated outage,
        or None.  Overridden by the cell-outage overlay
        (:class:`repro.sim.outages.CellOutageModel`); the base models
        have no correlated structure."""
        del epoch
        return None


def _chunk_losses(rng: np.random.Generator, wire: float,
                  cfg: FaultConfig) -> Tuple[bool, int, float, float, float]:
    """Draw one client's chunked-uplink loss outcome.

    Returns ``(aborted, retries, extra_bytes, backoff_s, sent_bytes)``.
    Chunk k is retransmitted until one attempt succeeds
    (``u >= loss_rate``) or ``max_retries`` retries are exhausted, each
    retry preceded by a ``backoff_base * 2^j`` wait.  Chunk count is
    capped at 4096 (the chunk size grows instead) so pathological
    ``wire/chunk_bytes`` ratios cannot blow up the draw.
    """
    n_chunks = max(1, int(np.ceil(wire / cfg.chunk_bytes)))
    if n_chunks > 4096:
        n_chunks = 4096
    sizes = np.full(n_chunks, wire / n_chunks)
    tries = cfg.max_retries + 1
    u = rng.uniform(size=(n_chunks, tries))
    ok = u >= cfg.loss_rate
    first = np.argmax(ok, axis=1)               # first success per chunk
    dead = ~ok.any(axis=1)
    attempts = np.where(dead, tries, first + 1)
    fatal = int(np.argmax(dead)) if dead.any() else n_chunks
    live = np.arange(n_chunks) < fatal
    retries = int(np.sum((attempts - 1)[live]))
    extra = float(np.sum(((attempts - 1) * sizes)[live]))
    backoff = float(cfg.backoff_base
                    * np.sum((2.0 ** (attempts - 1) - 1.0)[live]))
    if fatal < n_chunks:
        sent = float(np.sum((attempts * sizes)[:fatal + 1]))
        return True, retries + cfg.max_retries, extra, backoff, sent
    return False, retries, extra, backoff, 0.0


class RandomFaults(FaultModel):
    """I.i.d. fault draws at the configured rates, keyed per
    (seed, epoch, client) so the stream is call-order independent."""

    def __init__(self, config: Optional[FaultConfig] = None, **kw):
        self.config = config or FaultConfig(**kw)

    def round_faults(self, epoch: int, wire_bytes: np.ndarray,
                     uplink_rate: np.ndarray) -> RoundFaults:
        cfg = self.config
        n = len(wire_bytes)
        out = RoundFaults.clean(n)
        for i in range(n):
            rng = np.random.default_rng(
                (cfg.seed, _TAG_FAULTS, epoch, i))
            # fixed draw order; unused channels still consume their draws
            # so enabling one channel never shifts another's stream
            u_crash, frac, u_corr, u_kind = rng.uniform(size=4)
            if cfg.crash_rate > 0.0 and u_crash < cfg.crash_rate:
                out.crashed[i] = True
                out.crash_frac[i] = frac
                continue
            if cfg.corrupt_rate > 0.0 and u_corr < cfg.corrupt_rate:
                kind = (cfg.corrupt_kind if cfg.corrupt_kind != "mix"
                        else CORRUPT_KINDS[int(u_kind
                                               * len(CORRUPT_KINDS))])
                out.corrupt[i] = 1 + CORRUPT_KINDS.index(kind)
            if cfg.loss_rate > 0.0:
                aborted, retries, extra, backoff, sent = _chunk_losses(
                    rng, float(wire_bytes[i]), cfg)
                out.aborted[i] = aborted
                out.retries[i] = retries
                out.extra_bytes[i] = extra
                out.sent_bytes[i] = sent
                r_u = max(float(uplink_rate[i]), 1e-9)
                out.extra_delay[i] = extra / r_u + backoff
        return out


class ScriptedFaults(FaultModel):
    """Explicit per-(round, client) fault script — the hand-computable
    scenarios the acceptance tests pin (e.g. "client 2 crashes in round
    3", "client 0's upload needs exactly 2 retransmits in round 1").

    crashes: ``{(epoch, client): crash_frac}`` (``True`` -> 0.5).
    chunk_retries: ``{(epoch, client): k}`` — exactly k retransmits of
      one ``chunk_bytes`` chunk, so the upload lands
      ``k * chunk_bytes / r_u + backoff_base * (2^k - 1)`` late having
      moved ``k * chunk_bytes`` duplicate bytes.
    aborts: ``{(epoch, client): sent_bytes}`` — the upload is abandoned
      after ``sent_bytes`` crossed the wire.
    corrupt: ``{(epoch, client): kind}`` with kind in
      :data:`CORRUPT_KINDS`.
    """

    def __init__(self, crashes: Optional[Dict] = None,
                 chunk_retries: Optional[Dict] = None,
                 aborts: Optional[Dict] = None,
                 corrupt: Optional[Dict] = None,
                 config: Optional[FaultConfig] = None, **kw):
        self.config = config or FaultConfig(**kw)
        self.crashes = dict(crashes or {})
        self.chunk_retries = dict(chunk_retries or {})
        self.aborts = dict(aborts or {})
        self.corrupt = dict(corrupt or {})
        for kind in self.corrupt.values():
            if kind not in CORRUPT_KINDS:
                raise ValueError(f"scripted corrupt kind {kind!r} not in "
                                 f"{CORRUPT_KINDS}")

    @property
    def may_corrupt(self) -> bool:
        return bool(self.corrupt)

    def round_faults(self, epoch: int, wire_bytes: np.ndarray,
                     uplink_rate: np.ndarray) -> RoundFaults:
        cfg = self.config
        n = len(wire_bytes)
        out = RoundFaults.clean(n)
        for (e, i), frac in self.crashes.items():
            if e == epoch and 0 <= i < n:
                out.crashed[i] = True
                out.crash_frac[i] = 0.5 if frac is True else float(frac)
        for (e, i), k in self.chunk_retries.items():
            if e == epoch and 0 <= i < n and not out.crashed[i]:
                out.retries[i] = int(k)
                out.extra_bytes[i] = float(k) * cfg.chunk_bytes
                r_u = max(float(uplink_rate[i]), 1e-9)
                out.extra_delay[i] = (out.extra_bytes[i] / r_u
                                      + cfg.backoff_base * (2.0 ** k - 1.0))
        for (e, i), sent in self.aborts.items():
            if e == epoch and 0 <= i < n and not out.crashed[i]:
                out.aborted[i] = True
                out.sent_bytes[i] = float(sent)
        for (e, i), kind in self.corrupt.items():
            if e == epoch and 0 <= i < n and not out.crashed[i]:
                out.corrupt[i] = 1 + CORRUPT_KINDS.index(kind)
        return out


def incident_events(fr: RoundFaults, scheduled: np.ndarray) -> list:
    """One observability event dict per fault incident in a round's draw
    (consumed by ``repro.obs`` — the recorder's ``fault()`` hook turns
    each into a JSONL ``fault`` event and a
    ``feddd_fault_incidents_total{kind=}`` increment).

    ``scheduled`` is the (N,) bool mask of clients dispatched this round;
    incidents of unscheduled clients never happened on the timeline and
    are not reported.  Kinds: ``crash``, ``abort``, ``retry`` (survived
    retransmits), ``corrupt``, plus the cell-level ``outage_begin`` /
    ``outage_end`` transitions carried on ``fr.outages`` (cell id, member
    clients, duration in rounds — these are fleet-scoped, not filtered by
    the schedule).  Quarantine and quorum-skip incidents are emitted by
    the runner, which owns those decisions.
    """
    sched = np.asarray(scheduled, bool)
    out = [dict(ev) for ev in fr.outages]
    for i in np.flatnonzero(sched & fr.crashed):
        out.append({"kind": "crash", "client": int(i),
                    "crash_frac": float(fr.crash_frac[i])})
    for i in np.flatnonzero(sched & fr.aborted):
        out.append({"kind": "abort", "client": int(i),
                    "retries": int(fr.retries[i]),
                    "sent_bytes": float(fr.sent_bytes[i])})
    for i in np.flatnonzero(sched & (fr.retries > 0) & ~fr.aborted
                            & ~fr.crashed):
        out.append({"kind": "retry", "client": int(i),
                    "retries": int(fr.retries[i]),
                    "extra_bytes": float(fr.extra_bytes[i]),
                    "extra_delay": float(fr.extra_delay[i])})
    for i in np.flatnonzero(sched & (fr.corrupt > 0) & ~fr.crashed):
        out.append({"kind": "corrupt", "client": int(i),
                    "corrupt_kind": CORRUPT_KINDS[int(fr.corrupt[i]) - 1]})
    return out


# ------------------------------------------------- wire-side corruption

def corrupt_pytree(params, kind: str, rng: np.random.Generator):
    """The on-wire corruption of one upload (host-side numpy pytree).

    ``nan`` / ``inf`` poison ~1/64 of each leaf's values; ``bitflip``
    flips one random mantissa/exponent bit of one float32 value per leaf
    (non-float32 leaves fall back to a NaN write).  Deterministic given
    ``rng``'s seed.
    """
    if kind not in CORRUPT_KINDS:
        raise ValueError(f"unknown corruption kind {kind!r}")
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for leaf in leaves:
        arr = np.array(jax.device_get(leaf))
        flat = arr.reshape(-1)
        if flat.size == 0:
            out.append(arr)
            continue
        if kind == "bitflip" and arr.dtype == np.float32:
            pos = int(rng.integers(flat.size))
            bit = int(rng.integers(32))
            view = flat.view(np.uint32)
            view[pos] ^= np.uint32(1 << bit)
        else:
            k = max(1, flat.size // 64)
            pos = rng.choice(flat.size, size=k, replace=False)
            flat[pos] = np.nan if kind != "inf" else np.inf
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def corruption_rng(seed: int, epoch: int, client: int
                   ) -> np.random.Generator:
    """The corruption noise stream for one (epoch, client) upload."""
    return np.random.default_rng((seed, _TAG_CORRUPT, epoch, client))


def host_update_stats(new_params, old_params) -> Tuple[float, bool]:
    """(l2 norm, all-finite) of one host-side update ``new - old`` —
    the per-client mirror of :func:`update_stats_stacked`."""
    sq = 0.0
    finite = True
    for nl, ol in zip(jax.tree_util.tree_leaves(new_params),
                      jax.tree_util.tree_leaves(old_params)):
        d = (np.asarray(jax.device_get(nl), np.float64)
             - np.asarray(jax.device_get(ol), np.float64))
        finite = finite and bool(np.isfinite(d).all())
        sq += float(np.sum(np.square(np.nan_to_num(
            d, nan=0.0, posinf=0.0, neginf=0.0))))
    return float(np.sqrt(sq)), finite


def update_stats_stacked(stacked_new, stacked_old
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-client (l2 norm, all-finite) of client-stacked updates.

    One device reduction over the (N, *leaf) stacks; the host only sees
    two (N,) vectors.  Used by the validation screen every faulty round.
    """
    sq = None
    finite = None
    for nl, ol in zip(jax.tree_util.tree_leaves(stacked_new),
                      jax.tree_util.tree_leaves(stacked_old)):
        d = nl.astype(jnp.float32) - ol.astype(jnp.float32)
        axes = tuple(range(1, d.ndim))
        fin = jnp.all(jnp.isfinite(d), axis=axes) if axes else \
            jnp.isfinite(d)
        s = (jnp.sum(jnp.square(jnp.nan_to_num(d)), axis=axes) if axes
             else jnp.square(jnp.nan_to_num(d)))
        sq = s if sq is None else sq + s
        finite = fin if finite is None else finite & fin
    norms = np.sqrt(np.asarray(jax.device_get(sq), np.float64))
    # force a copy: device_get buffers are read-only, and the runner
    # overwrites corrupted rows' entries in place
    return norms, np.array(jax.device_get(finite), dtype=bool)


def screen_quarantine(norms: np.ndarray, finite: np.ndarray,
                      candidates: np.ndarray,
                      vcfg: ValidationConfig) -> np.ndarray:
    """The server's payload-validation screen.

    Among ``candidates`` (this round's arrivals): quarantine non-finite
    updates, and updates whose norm exceeds ``norm_factor`` x the median
    finite-arrival norm.  Returns the (N,) quarantine mask.

    Small-survivor policy (pinned in tests/test_faults.py): the
    norm-anomaly screen needs a meaningful median, so it only engages
    when at least ``max(min_reference, 3)`` finite arrivals anchor it.
    With n <= 2 finite survivors the median of 1–2 norms says nothing
    about which one is anomalous (n=1 can never exceed 10x itself; n=2
    would let either arrival veto the other), so tiny survivor sets are
    screened by the finite check ONLY — never by the norm test,
    regardless of how low ``min_reference`` is configured.
    """
    cand = np.asarray(candidates, bool)
    quarantine = np.zeros_like(cand)
    if vcfg.screen_nonfinite:
        quarantine |= cand & ~np.asarray(finite, bool)
    good = cand & np.asarray(finite, bool)
    min_ref = max(int(vcfg.min_reference), 3)
    if vcfg.norm_factor > 0 and int(good.sum()) >= min_ref:
        ref = float(np.median(norms[good]))
        if ref > 0.0:
            quarantine |= good & (norms > vcfg.norm_factor * ref)
    return quarantine
