"""Per-client network/compute condition models for the FL simulator.

`core/protocol.py` freezes telemetry at round 0 (the paper's Table-4
sample), so every round sees the same links.  These models own the
*ground truth* conditions per communication epoch instead; the server in
sim/runner.py never reads them directly — it estimates rates from the
event timeline (observed telemetry) and re-solves the allocation LP from
those estimates.

A model maps an epoch index to :class:`NetworkConditions` — the true
``(uplink_rate, downlink_rate, compute_latency)`` arrays of that epoch.
For the wave policies (sync/deadline) the epoch is the round number; for
the async policy it is each client's own dispatch count.

All models are deterministic functions of their constructor seed: epoch
sequences are memoised so ``conditions(e)`` returns identical values
regardless of call order or process (the determinism contract of
tests/test_sim.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.allocation import ClientTelemetry


class NetworkConditions(NamedTuple):
    """True per-client conditions of one epoch; arrays shaped (N,)."""

    uplink_rate: np.ndarray      # bytes / s
    downlink_rate: np.ndarray    # bytes / s
    compute_latency: np.ndarray  # seconds per local-training pass


def telemetry_with_conditions(tel: ClientTelemetry,
                              cond: NetworkConditions) -> ClientTelemetry:
    """A copy of ``tel`` whose link/compute fields are ``cond``'s."""
    return dataclasses.replace(
        tel, uplink_rate=np.asarray(cond.uplink_rate, float),
        downlink_rate=np.asarray(cond.downlink_rate, float),
        compute_latency=np.asarray(cond.compute_latency, float))


class NetworkModel:
    """Base: ``conditions(epoch)`` -> true conditions of that epoch."""

    num_clients: int

    def conditions(self, epoch: int) -> NetworkConditions:
        raise NotImplementedError


class StaticNetwork(NetworkModel):
    """Table-4 conditions frozen for the whole run — the exact setting of
    ``core/protocol.py``'s closed-form clock, so the synchronous policy
    over this model reproduces Eq. (12) round times (tests/test_sim.py).
    """

    def __init__(self, tel: ClientTelemetry):
        self.num_clients = tel.num_clients
        self._cond = NetworkConditions(
            uplink_rate=np.asarray(tel.uplink_rate, float),
            downlink_rate=np.asarray(tel.downlink_rate, float),
            compute_latency=np.asarray(tel.compute_latency, float))

    def conditions(self, epoch: int) -> NetworkConditions:
        del epoch
        return self._cond


class MarkovFadingNetwork(NetworkModel):
    """Two-state (good/bad) Gilbert–Elliott fading per client.

    Each client carries an independent Markov chain over epochs:

        P(good -> bad)  = p_fade
        P(bad  -> good) = p_recover

    In the bad state the client's uplink and downlink rates are scaled by
    ``fade_factor`` (deep fade) and its compute latency by
    ``compute_slowdown`` (e.g. thermal throttling / contention).  All
    clients start in the good state at epoch 0, i.e. epoch 0 equals the
    base Table-4 sample.

    The chain is advanced lazily and memoised, so the model is a
    deterministic function of (base telemetry, seed) alone.
    """

    def __init__(self, tel: ClientTelemetry, *, p_fade: float = 0.2,
                 p_recover: float = 0.5, fade_factor: float = 0.1,
                 compute_slowdown: float = 1.0, seed: int = 0):
        if not (0.0 <= p_fade <= 1.0 and 0.0 <= p_recover <= 1.0):
            raise ValueError("transition probabilities must be in [0,1]")
        self.num_clients = tel.num_clients
        self.p_fade = p_fade
        self.p_recover = p_recover
        self.fade_factor = fade_factor
        self.compute_slowdown = compute_slowdown
        self._base = StaticNetwork(tel).conditions(0)
        self._rng = np.random.default_rng(seed)
        # _states[e] is the (N,) bool "bad" vector of epoch e.
        self._states: List[np.ndarray] = [np.zeros(tel.num_clients, bool)]

    def _advance_to(self, epoch: int) -> None:
        while len(self._states) <= epoch:
            bad = self._states[-1]
            u = self._rng.uniform(size=self.num_clients)
            nxt = np.where(bad, u >= self.p_recover, u < self.p_fade)
            self._states.append(nxt)

    def conditions(self, epoch: int) -> NetworkConditions:
        self._advance_to(epoch)
        bad = self._states[epoch]
        link = np.where(bad, self.fade_factor, 1.0)
        slow = np.where(bad, self.compute_slowdown, 1.0)
        base = self._base
        return NetworkConditions(
            uplink_rate=base.uplink_rate * link,
            downlink_rate=base.downlink_rate * link,
            compute_latency=base.compute_latency * slow)


class TraceNetwork(NetworkModel):
    """Trace-driven conditions: explicit per-epoch rate arrays.

    ``uplink`` / ``downlink`` / ``compute`` are (T, N) arrays (or lists of
    (N,) rows); epoch e uses row ``e % T``.  Useful for replaying measured
    link traces and for constructing adversarial straggler scenarios in
    tests (e.g. one client's uplink collapsing 10x at a known epoch).
    """

    def __init__(self, uplink: Sequence, downlink: Sequence,
                 compute: Sequence):
        self._up = np.atleast_2d(np.asarray(uplink, float))
        self._down = np.atleast_2d(np.asarray(downlink, float))
        self._cmp = np.atleast_2d(np.asarray(compute, float))
        if not (self._up.shape == self._down.shape == self._cmp.shape):
            raise ValueError("trace arrays must share shape (T, N)")
        self.num_clients = self._up.shape[1]

    def conditions(self, epoch: int) -> NetworkConditions:
        r = epoch % self._up.shape[0]
        return NetworkConditions(self._up[r], self._down[r], self._cmp[r])

    @classmethod
    def straggler_collapse(cls, tel: ClientTelemetry, *, epochs: int = 12,
                           clients: Sequence[int] = (0,),
                           factor: float = 50.0,
                           from_epoch: int = 1) -> "TraceNetwork":
        """Canonical adversarial trace: ``clients``' uplinks collapse by
        ``factor`` from ``from_epoch`` on (everything else held at the
        base telemetry).  The scenario the deadline/partial-aggregation
        and fault-injection tests drive (tests/test_faults.py,
        benchmarks/fault_tolerance.py)."""
        up = np.tile(np.asarray(tel.uplink_rate, float), (epochs, 1))
        for c in clients:
            up[from_epoch:, int(c)] /= factor
        return cls(up,
                   np.tile(np.asarray(tel.downlink_rate, float),
                           (epochs, 1)),
                   np.tile(np.asarray(tel.compute_latency, float),
                           (epochs, 1)))


def make_network(name: str, tel: ClientTelemetry, *,
                 seed: int = 0, **kw) -> NetworkModel:
    """Factory keyed by the benchmark-grid names."""
    if name == "static":
        return StaticNetwork(tel)
    if name == "markov":
        return MarkovFadingNetwork(tel, seed=seed, **kw)
    if name == "straggler":
        return TraceNetwork.straggler_collapse(tel, **kw)
    raise ValueError(f"unknown network model {name!r} "
                     "(other trace models are constructed directly)")
