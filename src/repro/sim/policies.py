"""Server aggregation policies — who the server waits for, and how it
weighs what arrives.

The paper's protocol is synchronous: the server "needs to wait for the
slowest client" (FedDD §1), which is exactly what differential dropout is
designed to mitigate.  The simulator makes that a pluggable choice so the
time-to-accuracy benchmark (benchmarks/straggler_policies.py) can compare
FedDD under three serving disciplines:

* :class:`SyncPolicy` — wait for every upload; the round ends at the last
  arrival (Eq. (12) semantics; reproduces core/protocol.py exactly under a
  static network, tests/test_sim.py).
* :class:`DeadlinePolicy` — FedCS-style semi-synchronous round: the server
  sets a deadline from its *observed* telemetry and a straggler that has
  not finished uploading by then is cut off — its in-flight transfer is
  abandoned, its update is excluded from Eq. (4) (a 0 aggregation weight
  in the stacked engine step), and it rejoins the next wave.
* :class:`RetryPolicy` — sync with a hard timeout, the serving discipline
  for LOSSY uplinks (sim/faults.py): the server waits for every expected
  upload (retransmits and their backoff included) but never longer than
  ``slack`` x the slowest expected round trip — a client that silently
  died cannot stall the round forever, yet a retransmitting one gets the
  headroom a plain deadline would deny it.
* :class:`AsyncPolicy` — buffered fully-asynchronous serving (FedBuff /
  FedAsync style): the server merges as soon as ``buffer_size`` uploads
  are in, weighting each by a staleness decay ``(1 + s)^(-alpha)`` where
  ``s`` counts global versions elapsed since the client downloaded.
  Clients re-dispatch immediately after each merge, so fast clients lap
  stragglers instead of waiting for them.

Wave policies (sync/deadline) expose ``horizon(expected_durations)`` —
how long past dispatch the server listens, computed from the durations it
*expects* given its observed telemetry (``inf`` = wait for all).  The
async policy instead parameterises the event loop in sim/runner.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("sync", "deadline", "retry", "async")


@dataclasses.dataclass(frozen=True)
class SyncPolicy:
    """Wait-for-all (the paper's protocol)."""

    name: str = dataclasses.field(default="sync", init=False)

    def horizon(self, expected_durations: np.ndarray) -> float:
        del expected_durations
        return float("inf")


@dataclasses.dataclass(frozen=True)
class DeadlinePolicy:
    """Semi-synchronous: cut off uploads later than an adaptive deadline.

    The listening horizon is ``slack`` x the ``quantile``-th expected
    round-trip duration, where expectations come from the server's
    observed telemetry — the server budgets for the fleet it *believes*
    it has, and a client whose link faded since the last estimate simply
    misses the cut.  The runner always keeps at least one upload (the
    earliest arrival) so a round is never empty (with a fault model
    attached, the quorum rule replaces that fallback).

    ``partial=True`` enables partial aggregation of cut uploads
    (homogeneous fleets): instead of abandoning an in-flight transfer
    outright, the server aggregates the per-leaf prefix of mask channels
    whose bytes landed before the deadline — kept channels serialize in
    ascending channel order (repro.comm.payload), so the delivered byte
    count maps exactly to a per-leaf kept-channel prefix
    (:func:`repro.comm.payload.delivered_prefix_counts`).
    """

    quantile: float = 0.75
    slack: float = 1.5
    partial: bool = False
    name: str = dataclasses.field(default="deadline", init=False)

    def horizon(self, expected_durations: np.ndarray) -> float:
        return self.slack * float(
            np.quantile(np.asarray(expected_durations, float),
                        self.quantile))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded patience: wait for all expected uploads, up to a timeout.

    The horizon is ``slack`` x the SLOWEST expected round-trip duration.
    Expectations come from observed telemetry and do not include
    retransmit delays, so ``slack > 1`` is the headroom granted to lossy
    uplinks (sim/faults.py): a retransmitting client lands inside the
    horizon and its retries are waited out, while a crashed or silently
    dead client can stall the round by at most the timeout.  With no
    faults and ``slack >= 1`` this reduces to :class:`SyncPolicy` over
    any network the expectations track.
    """

    slack: float = 3.0
    name: str = dataclasses.field(default="retry", init=False)

    def horizon(self, expected_durations: np.ndarray) -> float:
        return self.slack * float(
            np.max(np.asarray(expected_durations, float)))


@dataclasses.dataclass(frozen=True)
class AsyncPolicy:
    """Buffered async serving parameters (consumed by sim/runner.py).

    ``buffer_size == 0`` means "pick at runtime": ``max(1, N // 4)``.
    """

    alpha: float = 0.5       # staleness decay exponent
    buffer_size: int = 0     # uploads per merge
    name: str = dataclasses.field(default="async", init=False)

    def resolved_buffer(self, num_clients: int) -> int:
        k = self.buffer_size or max(1, num_clients // 4)
        return min(k, num_clients)

    def staleness_scale(self, staleness: np.ndarray) -> np.ndarray:
        """Weight multiplier ``(1 + s)^(-alpha)`` (FedAsync polynomial)."""
        return (1.0 + np.asarray(staleness, float)) ** (-self.alpha)


def make_policy(name: str, **kw):
    """Factory keyed by the benchmark-grid names."""
    if name == "sync":
        return SyncPolicy(**kw)
    if name == "deadline":
        return DeadlinePolicy(**kw)
    if name == "retry":
        return RetryPolicy(**kw)
    if name == "async":
        return AsyncPolicy(**kw)
    raise ValueError(f"unknown policy {name!r}; expected one of {POLICIES}")
