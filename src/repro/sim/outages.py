"""Correlated cell-outage processes — the cluster-failure axis.

PR 6's fault models draw *independent* per-client failures; real fleets
fail in correlated bursts (a cell tower drops, a building loses power, an
ISP route flaps) and FedDD's rare-client regimes are exactly what such
bursts create.  This module groups clients into **cells** and drives each
cell with a two-state (up/down) Markov outage chain — the same
Gilbert–Elliott machinery as :class:`~repro.sim.network.MarkovFadingNetwork`,
lifted from per-client link quality to per-cell availability:

    P(up   -> down) = p_out
    P(down -> up)   = p_back

While a cell is down every member client behaves as crashed: its upload
never completes, its telemetry EWMA stalls (the server never sees a
measurement), and the runner's survivor-only LP re-solve excludes the
whole cell at once.  An outage therefore composes with ANY inner
:class:`~repro.sim.faults.FaultModel` — independent churn/loss/corruption
draws continue underneath, and the outage overlay forces entire cells
into the crashed channel on top.

Determinism contract (tests/test_outages.py): the chain draw of epoch
``e`` comes from ``np.random.default_rng((seed, _TAG_OUTAGE, e))`` and
each outaged member's crash fraction from
``np.random.default_rng((seed, _TAG_OUTAGE, e, client))`` — pure
functions of (seed, epoch[, client]) like every other fault draw, so
outage scenarios replay identically across call orders, processes and
crash-resume (checkpoint/run_state.py never has to persist the chain).
All cells are up at epoch 0.  ``cells=0`` or ``p_out=0`` is the inert
config: ``round_faults`` returns the inner model's draw bit-identically.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.sim.faults import FaultConfig, FaultModel, RoundFaults

# SeedSequence domain tag: outage draws can never collide with the
# per-client fault (0xFA) or corruption-noise (0xC0) streams.
_TAG_OUTAGE = 0x0D


@dataclasses.dataclass(frozen=True)
class OutageConfig:
    """Cell-outage process knobs.

    cells: number of cells clients are grouped into (round-robin
      ``client % cells`` unless an explicit assignment is given);
      ``0`` disables the overlay entirely (inert config).
    p_out: per-epoch probability an up cell goes down.
    p_back: per-epoch probability a down cell recovers.
    seed: outage-stream seed, independent of the inner fault seed so the
      same outage scenario can be replayed over different fault draws.
    """

    cells: int = 0
    p_out: float = 0.0
    p_back: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.cells < 0:
            raise ValueError(f"cells must be >= 0, got {self.cells}")
        for name in ("p_out", "p_back"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0,1], got {v}")


class CellOutageModel(FaultModel):
    """Correlated-failure overlay: cell-level Markov outages on top of an
    optional inner per-client fault model.

    ``round_faults`` first takes the inner model's draw (or a clean draw
    when ``inner is None``), then marks every member of a down cell as
    crashed with a per-client keyed crash fraction.  Cell up->down /
    down->up transitions are reported as ``outage_begin`` /
    ``outage_end`` incidents on the returned :class:`RoundFaults`
    (``.outages``), which :func:`repro.sim.faults.incident_events`
    forwards to the observability layer.
    """

    def __init__(self, num_clients: int,
                 config: Optional[OutageConfig] = None, *,
                 inner: Optional[FaultModel] = None,
                 assignment: Optional[Sequence[int]] = None, **kw):
        self.outage = config or OutageConfig(**kw)
        self.inner = inner
        self.config = inner.config if inner is not None else FaultConfig()
        self.num_clients = int(num_clients)
        c = self.outage.cells
        if assignment is not None:
            asg = np.asarray(assignment, int)
            if asg.shape != (self.num_clients,):
                raise ValueError("assignment must have one cell index per "
                                 f"client, got shape {asg.shape}")
            if c and (asg.min() < 0 or asg.max() >= c):
                raise ValueError(f"assignment indices must be in [0,{c})")
            self.assignment = asg
        else:
            self.assignment = (np.arange(self.num_clients) % c if c
                               else np.zeros(self.num_clients, int))
        # _states[e] is the (cells,) bool "down" vector of epoch e; all
        # cells up at epoch 0 (epoch 0 equals the inner model alone).
        self._states: List[np.ndarray] = [np.zeros(max(c, 1), bool)]

    @property
    def active(self) -> bool:
        """Whether the overlay can ever produce an outage."""
        return self.outage.cells > 0 and self.outage.p_out > 0.0

    @property
    def may_corrupt(self) -> bool:
        return self.inner.may_corrupt if self.inner is not None else False

    def cell_members(self, cell: int) -> np.ndarray:
        return np.flatnonzero(self.assignment == int(cell))

    def _advance_to(self, epoch: int) -> None:
        cfg = self.outage
        while len(self._states) <= epoch:
            e = len(self._states)
            down = self._states[-1]
            u = np.random.default_rng(
                (cfg.seed, _TAG_OUTAGE, e)).uniform(size=len(down))
            self._states.append(
                np.where(down, u >= cfg.p_back, u < cfg.p_out))

    def down_cells(self, epoch: int) -> np.ndarray:
        """(cells,) bool: which cells are down at ``epoch``."""
        self._advance_to(epoch)
        return self._states[epoch].copy()

    def outage_mask(self, epoch: int) -> Optional[np.ndarray]:
        """(N,) bool mask of clients inside a down cell (None when the
        overlay is inert) — the runner excludes these rows from the
        allocation LP re-solve for the duration of the outage."""
        if not self.active:
            return None
        down = self.down_cells(epoch)
        return down[self.assignment]

    def _transitions(self, epoch: int) -> list:
        """The epoch's ``outage_begin`` / ``outage_end`` incidents,
        computed purely from the memoised chain (repeatable)."""
        if not self.active or epoch <= 0:
            # epoch 0 is all-up by construction: no transitions
            if not self.active:
                return []
            self._advance_to(epoch)
            return []
        self._advance_to(epoch)
        prev, cur = self._states[epoch - 1], self._states[epoch]
        out = []
        for c in np.flatnonzero(cur & ~prev):
            out.append({"kind": "outage_begin", "cell": int(c),
                        "members": [int(i) for i in self.cell_members(c)]})
        for c in np.flatnonzero(prev & ~cur):
            # duration: consecutive down epochs ending at epoch-1
            first = epoch - 1
            while first > 0 and self._states[first - 1][c]:
                first -= 1
            out.append({"kind": "outage_end", "cell": int(c),
                        "members": [int(i) for i in self.cell_members(c)],
                        "duration": int(epoch - first)})
        return out

    def round_faults(self, epoch: int, wire_bytes: np.ndarray,
                     uplink_rate: np.ndarray) -> RoundFaults:
        n = len(wire_bytes)
        if self.inner is not None:
            out = self.inner.round_faults(epoch, wire_bytes, uplink_rate)
        else:
            out = RoundFaults.clean(n)
        if not self.active:
            return out
        mask = self.outage_mask(epoch)
        out.outages = self._transitions(epoch)
        if mask is None or not mask.any():
            return out
        cfg = self.outage
        for i in np.flatnonzero(mask[:n]):
            # overlay wins: a client inside a down cell crashes even if
            # the inner draw had it surviving with retries/corruption
            frac = np.random.default_rng(
                (cfg.seed, _TAG_OUTAGE, epoch, int(i))).uniform()
            out.crashed[i] = True
            out.crash_frac[i] = frac
            out.aborted[i] = False
            out.retries[i] = 0
            out.extra_bytes[i] = 0.0
            out.extra_delay[i] = 0.0
            out.sent_bytes[i] = 0.0
            out.corrupt[i] = 0
        return out
