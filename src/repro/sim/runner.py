"""Event-driven FL runner — composes the clock engine, network models,
and aggregation policies with the batched FedDD round engine.

This is the simulator's driver, the counterpart of
:class:`repro.core.protocol.FedDDServer` for *dynamic* system conditions.
Differences from the closed-form protocol driver:

* **Time is an event queue** (sim/engine.py), not one ``max`` per round:
  every client download / compute / upload is a timestamped event, so
  deadlines can cut stragglers mid-flight and async merges can interleave.
* **Conditions change** (sim/network.py): each communication epoch draws
  true uplink/downlink/compute values from the network model (static,
  Markov fading, or trace-driven).
* **The server is not an oracle**: it re-solves the dropout-rate LP
  (core/allocation.py) every round from telemetry it *observed* on the
  event timeline — per-phase measurements carried on the download /
  compute / upload events, EWMA-smoothed — so FedDD's differential
  dropout adapts as links fade.  Ground-truth conditions never reach the
  allocation.
* **Aggregation discipline is pluggable** (sim/policies.py): synchronous
  wait-for-all, deadline semi-sync that abandons late uploads, or
  buffered fully-async with staleness-decayed weights.

The device math is the round engines of ``core/round_engine.py``:
homogeneous fleets run the :class:`BatchedRoundEngine` step, ragged-width
fleets (HeteroFL-style sub-models) the shape-grouped
:class:`GroupedRoundEngine` step — one fused device step per shape census.
Exclusion (deadline drops, baseline non-participation) and staleness decay
enter as per-client weights on the stacked Eq. (4) aggregation either way,
indexed by each client's row in the aggregation canvas, so the same jit
step serves every policy and every fleet shape.

Determinism contract (tests/test_sim.py, tests/test_grouped_engine.py): a
run is a pure function of (seed, config, network model, fleet) — same seed
gives the identical event trace, sim times, and final parameters in any
process.

With the synchronous policy over a static network this runner reproduces
``protocol.py``'s Eq. (12) round times and global parameters exactly —
for homogeneous and ragged fleets alike.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs as obs_mod
from repro.comm.payload import (WireSpec, account_collective,
                                account_uplink, analytic_uplink_vector,
                                delivered_prefix_counts)
from repro.core import baselines, coverage as cov_mod, round_engine
from repro.core.allocation import (ClientTelemetry,
                                   solve_dropout_rates_overhead_aware,
                                   solve_dropout_rates_with)
from repro.core.protocol import (ProtocolConfig, RoundRecord, RunResult,
                                 _tree_bytes)
from repro.sim import engine as ev_mod
from repro.sim import faults as faults_mod
from repro.sim.engine import (COMPUTE_DONE, DOWNLOAD_DONE, UPLOAD_DONE,
                              Simulator)
from repro.sim.faults import FaultModel
from repro.sim.network import (NetworkModel, StaticNetwork,
                               telemetry_with_conditions)
from repro.sim.policies import AsyncPolicy, DeadlinePolicy, make_policy

# Async-path fault marker (sim/faults.py): the instant a dispatched
# client's crash or abort becomes known to the server, so the slot
# re-enters the free-running pipeline at that simulated time.
CLIENT_DOWN = "client_down"


@dataclasses.dataclass
class SimConfig:
    """Simulator-only knobs (protocol knobs stay on ProtocolConfig)."""

    policy: Union[str, object] = "sync"   # sync | deadline | async, or an
                                          # instance from sim/policies.py
    policy_kw: Dict = dataclasses.field(default_factory=dict)
    observation_ewma: float = 0.5         # weight on the newest measurement
    eval_every: int = 1                   # eval_fn cadence (rounds/merges)

    def resolve_policy(self):
        if isinstance(self.policy, str):
            return make_policy(self.policy, **self.policy_kw)
        return self.policy


@dataclasses.dataclass
class SimResult(RunResult):
    """RunResult + the determinism witnesses of the event timeline."""

    event_trace: List[Tuple[float, str, int]] = dataclasses.field(
        default_factory=list)
    observed_telemetry: Optional[ClientTelemetry] = None


class ObservedTelemetry:
    """The server's running estimate of client link/compute conditions.

    Initialised from the prior the operator supplied (the Table-4 sample
    the closed-form driver treats as an oracle) and EWMA-updated from
    measurements carried on processed events.  A measurement equal to the
    current estimate leaves it bit-identical (no ``a*x + (1-a)*x``
    round-off drift) — that is what makes the static-network sync run
    reproduce protocol.py exactly.

    Estimates are stored per GLOBAL client id.  ``ids`` (population mode,
    repro.population) maps the current cohort's stack positions to global
    ids: events carry stack positions, so measurements land on the global
    row, and :meth:`telemetry` gathers the cohort's rows back out.  With
    ``ids=None`` (fleet == population, today's default) positions and ids
    coincide and nothing changes.  This is what lets cohort membership
    vary round to round without aliasing estimates between the different
    clients that occupy stack position ``i`` over the run.
    """

    def __init__(self, prior: ClientTelemetry, ewma: float,
                 ids: Optional[np.ndarray] = None):
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"observation_ewma must be in (0,1], {ewma}")
        self.base = prior
        self.ewma = ewma
        self.ids = None if ids is None else np.asarray(ids, np.int64)
        self.uplink = np.asarray(prior.uplink_rate, float).copy()
        self.downlink = np.asarray(prior.downlink_rate, float).copy()
        self.compute = np.asarray(prior.compute_latency, float).copy()

    def retarget(self, ids: np.ndarray) -> None:
        """Point the stack-position -> global-id map at a new cohort."""
        self.ids = np.asarray(ids, np.int64)

    def _update(self, arr: np.ndarray, i: int, measured: float) -> None:
        # estimates update ONLY from measurements that actually landed;
        # a client whose upload never arrived (crash, abort, deadline
        # cut — sim/faults.py) produces no event and its estimate stays
        # stale rather than being zero-filled, so one crash cannot
        # crater its dropout allocation next round.  Non-finite
        # measurements are discarded outright.
        if np.isfinite(measured) and measured != arr[i]:
            arr[i] = self.ewma * measured + (1.0 - self.ewma) * arr[i]

    def observe(self, event: ev_mod.Event) -> None:
        """Fold one event's measurement payload into the estimates."""
        if event.payload is None or event.client < 0:
            return
        kind, value = event.payload
        i = (event.client if self.ids is None
             else int(self.ids[event.client]))
        if kind == "uplink":
            self._update(self.uplink, i, value)
        elif kind == "downlink":
            self._update(self.downlink, i, value)
        elif kind == "compute":
            self._update(self.compute, i, value)

    def telemetry(self, train_loss: np.ndarray) -> ClientTelemetry:
        """Estimates as a ClientTelemetry for the allocation LP /
        selection baselines — gathered at the cohort's global ids when a
        map is bound (``train_loss`` is cohort-shaped either way)."""
        if self.ids is None:
            return dataclasses.replace(
                self.base, uplink_rate=self.uplink.copy(),
                downlink_rate=self.downlink.copy(),
                compute_latency=self.compute.copy(),
                train_loss=np.asarray(train_loss, float))
        idx = self.ids
        return dataclasses.replace(
            self.base.subset(idx), uplink_rate=self.uplink[idx],
            downlink_rate=self.downlink[idx],
            compute_latency=self.compute[idx],
            train_loss=np.asarray(train_loss, float))


class _StackedWaveFleet:
    """Homogeneous wave-policy device state: ONE client-stacked pytree that
    persists across rounds and one BatchedRoundEngine step per round."""

    def __init__(self, runner: "SimRunner"):
        self.runner = runner
        # runner.engine is the BatchedRoundEngine, or — under cfg.mesh —
        # the client-sharded ShardedRoundEngine (same step signature)
        self.engine = runner.engine
        self.stacked = round_engine.stack_pytrees(runner.client_params)
        n = runner.tel.num_clients
        if getattr(runner, "mesh", None) is not None and \
                n % runner.engine.num_shards == 0:
            self.stacked = jax.device_put(self.stacked,
                                          runner.engine.shard_spec())
        self._new = None

    def train(self, local_train_fn, rk, part, losses, d_used) -> List:
        del d_used      # homogeneous stacks defer dropout to step()
        n = self.runner.tel.num_clients
        per_client = round_engine.unstack_pytree(self.stacked, n)
        new_list, loss_dev = [None] * n, [None] * n
        for i, p_i in enumerate(per_client):
            if part[i]:
                p, l = local_train_fn(p_i, i, jax.random.fold_in(rk, i))
            else:
                p, l = p_i, losses[i]
            new_list[i], loss_dev[i] = p, l
        self._new = round_engine.stack_pytrees(new_list)
        return loss_dev

    def step(self, d_used, weights, rk, *, full_round, dense,
             delivered=None, overrides=None):
        r = self.runner
        upload = None
        if overrides:
            # wire-side corruption the validation screen missed: the
            # AGGREGATION consumes the corrupted rows, the client's own
            # Eq. (5) state stays its clean ``_new``
            upload = self._new
            for i, row in sorted(overrides.items()):
                upload = jax.tree_util.tree_map(
                    lambda l, c, i=i: l.at[i].set(jnp.asarray(c, l.dtype)),
                    upload, row)
        out = self.engine.step(self.stacked, self._new, r.global_params,
                               d_used, weights, rk,
                               full_round=full_round, dense_masks=dense,
                               stacked_upload=upload, delivered=delivered)
        r.global_params = out.global_params
        self.stacked = out.client_params
        return out.densities, out.wire_overhead

    def discard(self) -> None:
        """Drop the staged round (quorum miss): params stay put."""
        self._new = None

    def upload_stats(self):
        """(norms, finite) of the staged updates, fleet order."""
        return faults_mod.update_stats_stacked(self._new, self.stacked)

    def row_params(self, i: int):
        """Host (old, new) pytrees of client ``i``'s staged update."""
        old = jax.tree_util.tree_map(lambda l: l[i], self.stacked)
        new = jax.tree_util.tree_map(lambda l: l[i], self._new)
        return jax.device_get(old), jax.device_get(new)

    def export(self) -> List:
        n = self.runner.tel.num_clients
        return round_engine.unstack_pytree(self.stacked, n)


class _GroupedWaveFleet:
    """Ragged wave-policy device state: a thin adapter over the shared
    :class:`repro.core.round_engine.GroupedFleetState` (the SAME
    implementation the protocol's grouped executor drives).  Exclusion
    weights stay a full (N,) fleet vector — each group's rows index into it
    via the members' fleet positions, exactly like the homogeneous stacked
    path."""

    def __init__(self, runner: "SimRunner"):
        self.runner = runner
        self.state = round_engine.GroupedFleetState(
            runner.groups, runner.group_coverage, runner.client_params,
            runner.cfg.selection, runner.tel.num_clients, runner.cfg.comm,
            mesh=getattr(runner, "mesh", None),
            robust_agg=runner.cfg.robust_agg)

    def train(self, local_train_fn, rk, part, losses, d_used) -> List:
        return self.state.train(local_train_fn, rk, part, losses, d_used,
                                dense=self.runner.cfg.scheme != "feddd")

    def step(self, d_used, weights, rk, *, full_round, dense,
             delivered=None, overrides=None):
        del d_used      # already baked into the batches by train()
        if delivered is not None or overrides:
            # SimRunner.__init__ rejects corruption / partial aggregation
            # for ragged fleets before a round can reach here
            raise NotImplementedError(
                "upload overrides / delivered prefixes are homogeneous-"
                "engine features")
        r = self.runner
        r.global_params, densities, wire_oh = self.state.step(
            r.global_params, weights, rk, full_round=full_round,
            dense=dense)
        return densities, wire_oh

    def discard(self) -> None:
        """Drop the staged round (quorum miss): params stay put."""
        self.state.discard()

    def upload_stats(self):
        """(norms, finite) of the staged updates, fleet order."""
        n = self.runner.tel.num_clients
        norms = np.zeros(n)
        finite = np.ones(n, bool)
        for b in self.state.staged_batches:
            nb, fb = faults_mod.update_stats_stacked(b.stacked_new,
                                                     b.stacked_old)
            idx = np.asarray(jax.device_get(b.indices))
            norms[idx] = nb
            finite[idx] = fb
        return norms, finite

    def export(self) -> List:
        return self.state.export()


class SimRunner:
    """Event-driven federated run; homogeneous or ragged-width fleets."""

    def __init__(self, global_params, cfg: ProtocolConfig,
                 telemetry: ClientTelemetry, simcfg: SimConfig,
                 network: Optional[NetworkModel] = None,
                 client_params: Optional[List] = None,
                 faults: Optional[FaultModel] = None,
                 population=None, cohort_size: Optional[int] = None):
        if cfg.track_epsilon:
            raise ValueError("track_epsilon is a per-client-loop feature; "
                             "the sim runner does not support it")
        self.cfg = cfg
        self.simcfg = simcfg
        self.policy = simcfg.resolve_policy()
        self.network = network or StaticNetwork(telemetry)
        if self.network.num_clients != telemetry.num_clients:
            raise ValueError("network model / telemetry client count "
                             "mismatch")
        self.global_params = global_params
        # population-scale serving (repro.population): ``telemetry`` (and
        # the network model) cover the POPULATION; only the sampled cohort
        # is materialized into engine buffers.  The rest of __init__ runs
        # unchanged on the cohort-shaped view — with always-on
        # availability and cohort == population the gathered arrays are
        # value-identical to the fleet's own, which is the bit-identity
        # contract (tests/test_population.py).
        self.population = population
        self.pop_tel = None
        self.cohort = None
        if population is not None:
            if population.size != telemetry.num_clients:
                raise ValueError(
                    f"population size {population.size} / telemetry "
                    f"count {telemetry.num_clients} mismatch")
            k = population.size if cohort_size is None else int(cohort_size)
            if not 1 <= k <= population.size:
                raise ValueError(f"cohort_size {k} outside "
                                 f"[1, {population.size}]")
            if isinstance(self.policy, AsyncPolicy):
                raise ValueError(
                    "population cohorts rebind the wave fleet between "
                    "rounds; the async merge stream has no such boundary "
                    "— run populations under sync/deadline/retry")
            if cfg.checkpoint_every is not None or cfg.resume_from:
                raise ValueError(
                    "population sticky state does not yet ride the "
                    "RunState snapshot; run checkpoint/resume without "
                    "population=")
            if cfg.mesh is not None and not population.sampler.static:
                raise ValueError(
                    "client-sharded (mesh) fleets pin device buffers for "
                    "the whole run; population runs on a mesh need a "
                    "static cohort (identity sampler, or cohort_size == "
                    "population with always-on availability)")
            if client_params is not None:
                population.seed_params(
                    [jax.tree_util.tree_map(jnp.asarray, p)
                     for p in client_params])
            self.pop_tel = telemetry
            self.cohort = np.asarray(population.sample_cohort(0, k),
                                     np.int64)
            telemetry = telemetry.subset(self.cohort)
            client_params = population.cohort_params(self.cohort,
                                                     global_params)
        self.tel = telemetry
        n = telemetry.num_clients
        if client_params is None:
            client_params = [global_params] * n
        elif len(client_params) != n:
            raise ValueError("client_params / telemetry count mismatch")
        self.client_params = [jax.tree_util.tree_map(jnp.asarray, p)
                              for p in client_params]
        self._partition_fleet()
        # client-sharded SPMD (cfg.mesh): the wave/async fleets run the
        # sharded engines over a 1-D "clients" device mesh — same routing
        # as the protocol executors (core/protocol.py routing table)
        self.mesh = None
        if cfg.mesh is not None:
            from repro.launch.mesh import resolve_client_mesh
            self.mesh = resolve_client_mesh(cfg.mesh)
            if faults is not None and faults.may_corrupt:
                raise ValueError(
                    "payload corruption rewrites single rows of the "
                    "stacked upload on the host; client-sharded (mesh) "
                    "fleets keep rows on their shard — run corruption "
                    "faults without a mesh")
            if isinstance(self.policy, DeadlinePolicy) and \
                    self.policy.partial:
                raise ValueError(
                    "partial aggregation of delivered prefixes is a "
                    "single-device engine feature; run deadline "
                    "partial=True without a mesh")
        if self.mesh is not None and not self.heterogeneous:
            self.engine = round_engine.ShardedRoundEngine(
                cfg.selection, cfg.comm, mesh=self.mesh,
                collective=cfg.mesh_collective,
                keep_fraction=cfg.mesh_keep_fraction,
                robust_agg=cfg.robust_agg)
        else:
            if self.mesh is not None and cfg.mesh_collective != "dense":
                raise ValueError(
                    "sparse cross-device compaction rides the homogeneous "
                    "sharded engine; ragged (grouped) fleets reduce with "
                    "the dense psum collective")
            self.engine = round_engine.BatchedRoundEngine(
                cfg.selection, cfg.comm, robust_agg=cfg.robust_agg)
        # async ragged merges only; ragged + mesh + non-mean robust_agg
        # is rejected by GroupedRoundEngine itself, so homogeneous
        # sharded robust runs must not trip it here
        self.grouped_engine = round_engine.GroupedRoundEngine(
            cfg.selection, cfg.comm, self.mesh,
            cfg.robust_agg if self.heterogeneous else "mean")
        # global-model spec: the cross-device collective byte model
        # (account_collective) under cfg.mesh
        self._global_spec = WireSpec.from_params(
            global_params, cfg.selection.channel_axis)
        self.faults = faults
        if faults is not None and isinstance(self.policy, AsyncPolicy) \
                and faults.may_corrupt:
            raise ValueError(
                "payload corruption is wave-policy only (sync/deadline/"
                "retry): the async merge consumes pending host pytrees, "
                "not a staged stacked upload the runner can override; "
                "async fault runs support crash / loss / retry and the "
                "staleness-budget quorum")
        if isinstance(self.policy, AsyncPolicy) and (
                cfg.checkpoint_every is not None
                or cfg.resume_from):
            raise ValueError(
                "checkpoint/resume snapshots at wave-round boundaries; "
                "the async merge stream keeps in-flight pending state "
                "with no such boundary — run checkpointing under the "
                "sync/deadline/retry policies")
        if self.heterogeneous:
            if faults is not None and faults.may_corrupt:
                raise ValueError(
                    "payload corruption rides the homogeneous stacked "
                    "engine's upload overrides; ragged fleets support "
                    "crash / loss / quorum faults only")
            if isinstance(self.policy, DeadlinePolicy) and \
                    self.policy.partial:
                raise ValueError(
                    "partial aggregation of delivered prefixes requires "
                    "the homogeneous stacked engine")
        # EWMAs live per GLOBAL id: population mode sizes them to the
        # population and binds the cohort's position -> id map
        self.observed = (
            ObservedTelemetry(self.pop_tel, simcfg.observation_ewma,
                              ids=self.cohort)
            if population is not None else
            ObservedTelemetry(telemetry, simcfg.observation_ewma))
        self.dropout = (population.cohort_dropout(self.cohort)
                        if population is not None
                        else np.zeros(n))     # D_n^1 = 0 (Algorithm 1)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.sim = Simulator()
        # observability hook (repro.obs): inert singleton until a run
        # entry point builds a live recorder for an active cfg.obs
        self.obs = obs_mod.NULL_RECORDER

    # -- fleet binding (shared by __init__ and cohort retargeting) -----------

    def _partition_fleet(self) -> None:
        """Everything derived from the CURRENT fleet's telemetry and
        params: shape groups + coverage (ragged fleets), wire specs,
        Eq. (4) weights.  Called once at __init__ for plain runs and on
        every cohort change in population mode."""
        cfg = self.cfg
        n = self.tel.num_clients
        # ragged fleet? partition by shape once; coverage per group
        from repro.fl.heterogeneity import group_by_shape  # fl -> core dep
        full_w = cov_mod.channel_widths(self.global_params,
                                        cfg.selection.channel_axis)
        cw = [cov_mod.channel_widths(p, cfg.selection.channel_axis)
              for p in self.client_params]
        self.heterogeneous = any(w != full_w for w in cw)
        self.cr = cov_mod.coverage_rates(cw, full_w)
        self.groups = group_by_shape(self.client_params)
        self.group_coverage = [
            cov_mod.coverage_pytree(self.client_params[g.indices[0]],
                                    self.cr, cfg.selection.channel_axis)
            for g in self.groups
        ]
        # fleet-position -> coverage pytree (async merges look coverage up
        # by the arriving client's index — immune to any dtype/structure
        # drift a trainer might introduce into the pending params)
        self._client_coverage = [None] * n
        for g, cov in zip(self.groups, self.group_coverage):
            for i in g.indices:
                self._client_coverage[i] = cov
        # per-client wire specs: the codec byte model the event timeline
        # charges on the uplink leg (repro.comm)
        self.wire_specs = [
            WireSpec.from_params(p, cfg.selection.channel_axis)
            for p in self.client_params
        ]
        self.weights = np.asarray(self.tel.num_samples, float)
        self.full_bytes = float(np.sum(self.tel.model_bytes))

    def _make_fleet(self):
        return (_GroupedWaveFleet(self) if self.heterogeneous
                else _StackedWaveFleet(self))

    def _conditions(self, epoch: int):
        """This epoch's true network conditions, cohort-shaped: in
        population mode the model covers the population, so the cohort's
        rows are gathered out (value-identical when cohort == arange)."""
        cond = self.network.conditions(epoch)
        if self.population is None:
            return cond
        ids = self.cohort
        return type(cond)(*[np.asarray(a, float)[ids] for a in cond])

    def _bind_cohort(self, ids: np.ndarray) -> None:
        """Rebind every cohort-shaped view to a new member list."""
        pop = self.population
        self.cohort = np.asarray(ids, np.int64)
        self.tel = self.pop_tel.subset(self.cohort)
        self.client_params = [
            jax.tree_util.tree_map(jnp.asarray, p)
            for p in pop.cohort_params(self.cohort, self.global_params)]
        self._partition_fleet()
        self.dropout = pop.cohort_dropout(self.cohort)
        self.observed.retarget(self.cohort)

    def _retarget_cohort(self, t: int, fleet, losses: np.ndarray):
        """Sample round ``t``'s cohort; when membership changed, park the
        outgoing cohort's learning state in the store and rebuild the
        wave fleet for the incoming one.  A static cohort (identity
        config, or a sampler that happens to repeat) never rebinds —
        the engines keep their buffers, preserving bit-identity and the
        scan/mesh paths' compiled state."""
        pop = self.population
        ids = np.asarray(pop.sample_cohort(t - 1, len(self.cohort)),
                         np.int64)
        if np.array_equal(ids, self.cohort):
            return fleet, losses
        pop.fold_back(self.cohort, fleet.export(), dropout=self.dropout,
                      losses=losses)
        self._bind_cohort(ids)
        return self._make_fleet(), pop.losses_for(self.cohort)

    def _population_round_done(self, t: int, part: np.ndarray,
                               fr, wire_vec: np.ndarray,
                               losses: np.ndarray, *,
                               contributors: np.ndarray,
                               moved: np.ndarray) -> None:
        """Fold the round's observations back into the population store
        (O(cohort)) and emit the ``cohort`` run-log event.

        ``contributors`` are the clients whose update reached the
        committed Eq. (4) aggregate (all False for a quorum-skipped
        round); ``moved`` are the clients whose upload bytes actually
        travelled, committed or wasted — the client-side byte economy.
        """
        pop = self.population
        if pop is None:
            return
        ids = self.cohort
        n = len(ids)
        extra = fr.extra_bytes if fr is not None else np.zeros(n)
        failed = part & ((fr.crashed | fr.aborted) if fr is not None
                         else np.zeros(n, bool))
        if self.obs.active:
            self.obs.event(
                "cohort", round=t, population=pop.size, cohort_size=n,
                first_contact=pop.first_contact(ids),
                cohort=[int(g) for g in ids],
                participated=[int(g) for g in ids[contributors]])
        tel = self.observed.telemetry(np.maximum(losses, 1e-6))
        util = (np.asarray(tel.num_samples, float)
                * np.sqrt(np.maximum(np.asarray(tel.train_loss, float),
                                     0.0))
                * baselines.oort_system_penalty(tel))
        pop.record_round(
            t, ids, arrived=contributors, failed=failed, losses=losses,
            uplink_bytes=np.where(moved, wire_vec + extra, 0.0),
            utilities=util)

    # -- shared server-side helpers -----------------------------------------

    @property
    def _dense(self) -> bool:
        return self.cfg.scheme != "feddd"

    def _allocate(self, losses: np.ndarray,
                  alive: Optional[np.ndarray] = None) -> None:
        """Re-solve the dropout LP from OBSERVED telemetry (never the
        network model's ground truth).

        ``alive`` restricts the solve to survivor-only telemetry (quorum-
        skipped rounds, sim/faults.py): crashed clients keep their
        previous rate instead of polluting the budget with stale rows; a
        fully-dead fleet leaves the allocation untouched.
        """
        tel = self.observed.telemetry(np.maximum(losses, 1e-6))
        if self.population is not None:
            # cold start: never-seen cohort members can take population-
            # mean priors (Population.cold_start="mean"); the default
            # "prior" passes through untouched
            tel = self.population.lp_telemetry(tel, self.cohort)
        kw = dict(a_server=self.cfg.a_server, d_max=self.cfg.d_max,
                  delta=self.cfg.delta,
                  global_model_bytes=_tree_bytes(self.global_params))
        if alive is not None and not alive.all():
            idx = np.flatnonzero(alive)
            if idx.size == 0:
                return
            tel_s = tel.subset(idx)
            if self.cfg.comm.overhead_aware_allocation:
                alloc = solve_dropout_rates_overhead_aware(
                    tel_s, [self.wire_specs[int(i)] for i in idx],
                    comm=self.cfg.comm, **kw)
            else:
                alloc = solve_dropout_rates_with(self.cfg.allocator,
                                                 tel_s, **kw)
            d = self.dropout.copy()
            d[idx] = alloc.dropout_rates
            self.dropout = d
            return
        if self.cfg.comm.overhead_aware_allocation:
            alloc = solve_dropout_rates_overhead_aware(
                tel, self.wire_specs, comm=self.cfg.comm, **kw)
        else:
            alloc = solve_dropout_rates_with(self.cfg.allocator, tel, **kw)
        self.dropout = alloc.dropout_rates

    def _uplink_wire_vec(self, dropout_vec: np.ndarray
                         ) -> Optional[np.ndarray]:
        """Per-client analytic on-wire uplink bytes (None = idealized
        ``U(1-D)``, the default comm config)."""
        if self.cfg.comm.is_default:
            return None
        return analytic_uplink_vector(self.wire_specs, dropout_vec,
                                      self.cfg.comm)

    def _participants(self, losses: np.ndarray) -> np.ndarray:
        """Baseline client selection, fed the server's observed view."""
        scheme = self.cfg.scheme
        n = self.tel.num_clients
        if scheme in ("feddd", "fedavg"):
            return np.ones(n, bool)
        tel = self.observed.telemetry(losses)
        if scheme == "fedcs":
            return baselines.select_fedcs(tel, a_server=self.cfg.a_server)
        return baselines.select_oort(tel, a_server=self.cfg.a_server)

    def _schedule_round_trip(self, i: int, t0: float, d_i: float,
                             cond, total: Optional[float] = None, *,
                             extra_delay: float = 0.0,
                             cutoff: Optional[float] = None,
                             drop_upload: bool = False,
                             crash_frac: Optional[float] = None
                             ) -> Tuple[float, float, float]:
        """Queue client i's download -> compute -> upload event chain.

        ``total``, when given, pins the upload arrival to ``t0 + total``
        (the vectorised Eq. (12) row) so the sync policy's round end is
        bit-identical to protocol.py's closed form.

        The upload leg moves the CODEC's bytes (repro.comm): with a
        non-default wire format the in-flight transfer a deadline may cut
        is the real payload — values at the codec's precision plus the
        mask encoding — not the idealized kept mass.  The download
        broadcast stays idealized.

        Fault hooks (sim/faults.py; all no-ops by default, leaving the
        fault-free schedule bit-identical): ``extra_delay`` pushes the
        upload arrival back (retransmits + backoff), ``cutoff`` is a
        crash instant — events after it are never scheduled — and
        ``drop_upload`` suppresses the upload event entirely (crashes,
        abandoned transfers).  ``crash_frac`` is the ASYNC path's crash
        hook: the cutoff is derived from the client's own computed round
        trip (the wave paths know theirs up front and pass ``cutoff``)
        and a :data:`CLIENT_DOWN` marker is queued at the crash instant
        so the free-running pipeline re-dispatches the slot.  Returns
        the (download, compute, upload) completion times whether or not
        the events were scheduled, so the caller can reason about
        in-flight progress at a cut.
        """
        u_eff = float(self.tel.model_bytes[i]) * (1.0 - d_i)
        r_d = float(cond.downlink_rate[i])
        r_u = float(cond.uplink_rate[i])
        t_cmp = float(cond.compute_latency[i])
        dl = t0 + u_eff / r_d
        cp = dl + t_cmp
        if total is not None:        # wave paths: arrival pinned by caller
            up = t0 + total + extra_delay
        else:                        # async path computes its own leg
            u_up = (u_eff if self.cfg.comm.is_default else
                    float(analytic_uplink_vector([self.wire_specs[i]],
                                                 np.asarray([d_i]),
                                                 self.cfg.comm)[0]))
            up = cp + u_up / r_u + extra_delay
        if crash_frac is not None:
            cutoff = t0 + float(crash_frac) * (up - t0)
            drop_upload = True
            self.sim.schedule_at(cutoff, CLIENT_DOWN, i)
        if cutoff is None or dl <= cutoff:
            self.sim.schedule_at(dl, DOWNLOAD_DONE, i, ("downlink", r_d))
        if cutoff is None or cp <= cutoff:
            self.sim.schedule_at(cp, COMPUTE_DONE, i, ("compute", t_cmp))
        if not drop_upload and (cutoff is None or up <= cutoff):
            self.sim.schedule_at(up, UPLOAD_DONE, i, ("uplink", r_u))
        return dl, cp, up

    def _merge_grouped(self, buffer: List[int], pending: Dict, w: np.ndarray,
                       merge_key, full_round: bool) -> np.ndarray:
        """One grouped engine step over an async merge buffer.

        The buffer's K arrivals are partitioned by sub-model shape; canvas
        rows (and the mask-RNG fold ids) are the BUFFER positions, mirroring
        the homogeneous async path, and staleness-decayed weights index by
        the same rows.  Membership is traced, so merges re-use the compiled
        step whenever the buffer's shape census repeats.
        """
        from repro.fl.heterogeneity import group_by_shape  # fl -> core dep
        groups = group_by_shape([pending[i][1] for i in buffer])
        batches = []
        for grp in groups:
            members = [buffer[pos] for pos in grp.indices]
            batches.append(round_engine.GroupBatch(
                indices=jnp.asarray(grp.indices, jnp.int32),
                stacked_old=round_engine.stack_pytrees(
                    [pending[i][0] for i in members]),
                stacked_new=round_engine.stack_pytrees(
                    [pending[i][1] for i in members]),
                coverage=(None if self._dense
                          else self._client_coverage[members[0]]),
                dropout=jnp.asarray([pending[i][3] for i in members],
                                    jnp.float32)))
        out = self.grouped_engine.step(
            batches, self.global_params, w, merge_key,
            full_round=full_round, dense_masks=self._dense)
        self.global_params = out.global_params
        for grp, stacked in zip(groups, out.group_client_params):
            for pos, p in zip(grp.indices,
                              round_engine.unstack_pytree(stacked,
                                                          grp.size)):
                self.client_params[buffer[pos]] = p
        dens, oh = jax.device_get((out.densities, out.wire_overhead))
        return np.asarray(dens, float), oh

    def _result(self, history: List[RoundRecord]) -> SimResult:
        return SimResult(history=history, global_params=self.global_params,
                         event_trace=list(self.sim.trace),
                         observed_telemetry=self.observed.telemetry(
                             np.ones(self.tel.num_clients)))

    # -- crash-resume snapshots (repro.checkpoint) ---------------------------

    def _wave_snapshot(self, losses: np.ndarray) -> Dict:
        """Everything the next wave round reads, as one checkpointable
        pytree: per-client params (unstacked — the fleet re-stacks them
        identically on resume), global params, the protocol PRNG key,
        the loss view, the allocated D_{t+1}, and the observed-telemetry
        EWMAs.  The sim clock + event trace ride the sidecar (extras);
        fault / outage / network draws are keyed per epoch and need no
        persisting (repro.checkpoint.run_state)."""
        return {"clients": self.client_params,
                "global": self.global_params,
                "rng": self.rng,
                "losses": np.asarray(losses, np.float64),
                "dropout": np.asarray(self.dropout, np.float64),
                "obs_uplink": self.observed.uplink,
                "obs_downlink": self.observed.downlink,
                "obs_compute": self.observed.compute}

    def _wave_restore(self, arrays: Dict) -> np.ndarray:
        """Inverse of :meth:`_wave_snapshot`; returns the loss view."""
        self.client_params = [jax.tree_util.tree_map(jnp.asarray, p)
                              for p in arrays["clients"]]
        self.global_params = jax.tree_util.tree_map(jnp.asarray,
                                                    arrays["global"])
        self.rng = jnp.asarray(arrays["rng"])
        self.dropout = np.asarray(arrays["dropout"], np.float64)
        self.observed.uplink = np.asarray(arrays["obs_uplink"], float)
        self.observed.downlink = np.asarray(arrays["obs_downlink"], float)
        self.observed.compute = np.asarray(arrays["obs_compute"], float)
        return np.asarray(arrays["losses"], np.float64)

    def _maybe_checkpoint(self, t: int, fleet, losses: np.ndarray,
                          history: List[RoundRecord]) -> None:
        """Atomic RunState snapshot after round ``t`` when due
        (``checkpoint_every=None`` never reaches the fleet export)."""
        cfg = self.cfg
        if cfg.checkpoint_every is None or t % cfg.checkpoint_every:
            return
        from repro import checkpoint as ckpt_mod   # checkpoint -> sim
        self.client_params = fleet.export()
        ckpt_mod.save_run_state(cfg.checkpoint_path, ckpt_mod.RunState(
            round=t, arrays=self._wave_snapshot(losses), history=history,
            extra={"sim_time": float(self.sim.now),
                   "trace": [list(e) for e in self.sim.trace]}))

    # -- wave policies: sync / deadline --------------------------------------

    def run_waves(self, local_train_fn: Callable, eval_fn=None,
                  rounds: Optional[int] = None) -> SimResult:
        self.obs = obs_mod.make_recorder(
            self.cfg.obs, driver="sim", scheme=self.cfg.scheme,
            policy=str(self.simcfg.policy),
            clients=self.tel.num_clients,
            rounds=rounds or self.cfg.rounds)
        try:
            return self._run_waves_impl(local_train_fn, eval_fn, rounds)
        finally:
            self.obs.close()
            self.obs = obs_mod.NULL_RECORDER

    def _cohort_train_fn(self, local_train_fn: Callable) -> Callable:
        """Population mode: the fleets hand ``local_train_fn`` a COHORT
        stack position; user train fns are written against global client
        ids (their data shard).  Translate at the boundary, reading
        ``self.cohort`` at call time so retargets are picked up.  With
        the identity cohort ``cohort[i] == i``, so fleet-mode runs and
        the bit-identity contract are untouched (the PRNG key stays the
        fleet's position-folded key either way)."""
        if self.population is None:
            return local_train_fn

        def wrapped(p, i, key):
            return local_train_fn(p, int(self.cohort[i]), key)

        return wrapped

    def _run_waves_impl(self, local_train_fn: Callable, eval_fn=None,
                        rounds: Optional[int] = None) -> SimResult:
        cfg = self.cfg
        obs = self.obs
        local_train_fn = self._cohort_train_fn(local_train_fn)
        rounds = rounds or cfg.rounds
        n = self.tel.num_clients
        losses = np.ones(n)
        history: List[RoundRecord] = []
        sim = self.sim
        # --- crash-resume (repro.checkpoint): restore BEFORE the fleet
        # stacks client state, so the wave fleet is built from the
        # snapshot; all fault/outage/network draws are keyed per epoch
        # and replay free from start_t
        start_t = 1
        if cfg.resume_from:
            from repro import checkpoint as ckpt_mod   # checkpoint -> sim
            st = ckpt_mod.load_run_state(cfg.resume_from,
                                         self._wave_snapshot(losses))
            losses = self._wave_restore(st.arrays)
            history = st.history
            start_t = st.round + 1
            sim.advance_to(float(st.extra.get("sim_time", 0.0)))
            sim.trace[:] = [tuple(e) for e in st.extra.get("trace", [])]
        fleet = self._make_fleet()
        partial_on = (isinstance(self.policy, DeadlinePolicy)
                      and self.policy.partial)

        for t in range(start_t, rounds + 1):
            host0 = time.perf_counter()
            # population mode: (re)sample the cohort BEFORE the protocol
            # RNG splits, so the key schedule is untouched and a static
            # cohort stays bit-identical to the plain fleet run
            if self.population is not None:
                fleet, losses = self._retarget_cohort(t, fleet, losses)
            self.rng, rk = jax.random.split(self.rng)
            part = self._participants(losses)
            d_used = self.dropout.copy()
            d_time = d_used if cfg.scheme == "feddd" else np.zeros(n)

            # --- device math: local training (participants)
            with obs.span("local_train", round=t):
                loss_dev = fleet.train(local_train_fn, rk, part, losses,
                                       d_used)

            # --- event timeline with TRUE conditions of this epoch; the
            # uplink leg moves the codec's bytes (repro.comm)
            _transport0 = time.perf_counter()
            cond = self._conditions(t - 1)
            true_tel = telemetry_with_conditions(self.tel, cond)
            up_wire = self._uplink_wire_vec(d_time)
            ti = baselines.round_times(true_tel, d_time,
                                       uplink_bytes=up_wire)
            wire_vec = (np.asarray(up_wire, float)
                        if up_wire is not None else
                        np.asarray(self.tel.model_bytes, float)
                        * (1.0 - d_time))
            # --- this epoch's fault draw (sim/faults.py), charged real
            # codec bytes; None leaves the schedule bit-identical
            fr = (self.faults.round_faults(
                t - 1, wire_vec, np.asarray(cond.uplink_rate, float))
                if self.faults is not None else None)
            if fr is not None and obs.active:
                for inc in faults_mod.incident_events(fr, part):
                    obs.fault(t, inc)
            dispatch = sim.now
            spans = {}
            for i in np.flatnonzero(part):
                i = int(i)
                if fr is None:
                    spans[i] = self._schedule_round_trip(
                        i, dispatch, float(d_time[i]), cond,
                        total=float(ti[i]))
                elif fr.crashed[i]:
                    # the client dies at crash_frac of its round trip:
                    # later events are never scheduled, the upload never
                    # arrives, its telemetry estimates go stale
                    spans[i] = self._schedule_round_trip(
                        i, dispatch, float(d_time[i]), cond,
                        total=float(ti[i]),
                        cutoff=dispatch + float(fr.crash_frac[i])
                        * float(ti[i]),
                        drop_upload=True)
                else:
                    # lossy uplink: retransmits + backoff push the
                    # arrival back on the Eq. (12) clock; an exhausted
                    # retry budget abandons the upload entirely
                    spans[i] = self._schedule_round_trip(
                        i, dispatch, float(d_time[i]), cond,
                        total=float(ti[i]),
                        extra_delay=float(fr.extra_delay[i]),
                        drop_upload=bool(fr.aborted[i]))

            # --- the server listens until the policy's horizon: deadlines
            # bind on the EXPECTED real payloads (codec bytes over the
            # observed links), so a codec that inflates uploads tightens
            # who makes the cut
            expected = baselines.round_times(
                self.observed.telemetry(losses), d_time,
                uplink_bytes=up_wire)[part]
            deadline = dispatch + self.policy.horizon(expected)
            dead = (part & (fr.crashed | fr.aborted) if fr is not None
                    else np.zeros(n, bool))
            n_expected = int(np.sum(part & ~dead))
            arrived = np.zeros(n, bool)
            arr_time = np.full(n, np.inf)
            while sim.queue and sim.queue.peek().time <= deadline:
                # a fault-aware server stops listening once every upload
                # that can still arrive has (a sync horizon would
                # otherwise wait on events of clients that already died)
                if (fr is not None and n_expected
                        and int(arrived.sum()) >= n_expected):
                    break
                ev = sim.step()
                self.observed.observe(ev)
                if ev.kind == UPLOAD_DONE:
                    arrived[ev.client] = True
                    arr_time[ev.client] = ev.time
            if fr is None and not arrived.any():
                # never aggregate an empty fault-free round; with a fault
                # model attached the quorum rule below owns this case
                while sim.queue:
                    ev = sim.step()
                    self.observed.observe(ev)
                    if ev.kind == UPLOAD_DONE:
                        arrived[ev.client] = True
                        arr_time[ev.client] = ev.time
                        break
            # late stragglers: in-flight transfers are abandoned (their
            # uplink estimate stays stale — the server never saw it land)
            sim.queue.clear()
            late = part & ~arrived
            cut = late & ~dead          # alive, just past the horizon
            if arrived.any():
                round_end = float(np.max(arr_time[arrived]))
                if cut.any():
                    round_end = max(round_end, float(deadline))
            else:
                round_end = (float(deadline) if np.isfinite(deadline)
                             else float(sim.now))
            round_end = max(round_end, float(sim.now))
            sim.advance_to(round_end)
            obs.span_done("transport", _transport0, round=t)

            # --- delivered prefixes of cut uploads (deadline partial
            # aggregation) and the bytes wasted by transfers that died
            # in flight; progress over the upload window is modelled
            # uniform in time
            partial = np.zeros(n, bool)
            delivered_rows: Dict[int, np.ndarray] = {}
            partial_bytes = 0.0
            abandoned_b = 0.0
            if cut.any() and np.isfinite(deadline):
                for i in np.flatnonzero(cut):
                    i = int(i)
                    _, cp_t, up_t = spans[i]
                    if deadline <= cp_t or up_t <= cp_t:
                        continue              # upload had not started
                    frac = min((deadline - cp_t) / (up_t - cp_t), 1.0)
                    db = float(wire_vec[i]) * frac
                    if partial_on:
                        counts = delivered_prefix_counts(
                            self.wire_specs[i], float(d_time[i]),
                            cfg.comm, db)
                        if counts.sum() > 0:
                            partial[i] = True
                            delivered_rows[i] = counts
                            partial_bytes += db
                            continue
                    abandoned_b += db
            if fr is not None:
                abandoned_b += float(np.sum(fr.sent_bytes[part]))
                for i in np.flatnonzero(part & fr.crashed):
                    i = int(i)
                    _, cp_t, up_t = spans[i]
                    cutoff = dispatch + float(fr.crash_frac[i]) \
                        * float(ti[i])
                    if cutoff > cp_t and up_t > cp_t:
                        abandoned_b += float(wire_vec[i]) * min(
                            (cutoff - cp_t) / (up_t - cp_t), 1.0)

            # --- payload validation: non-finite / norm-anomalous
            # arrivals are quarantined (0 weight on the stacked Eq. (4)
            # step — the baselines' non-participation mechanism)
            quarantine = np.zeros(n, bool)
            overrides: Dict[int, object] = {}
            quarantined_b = 0.0
            contributors = arrived | partial
            if fr is not None and contributors.any():
                norms, finite = fleet.upload_stats()
                for i in np.flatnonzero(arrived & (fr.corrupt > 0)):
                    i = int(i)
                    old_row, new_row = fleet.row_params(i)
                    kind = faults_mod.CORRUPT_KINDS[int(fr.corrupt[i]) - 1]
                    crow = faults_mod.corrupt_pytree(
                        new_row, kind, faults_mod.corruption_rng(
                            self.faults.config.seed, t - 1, i))
                    norms[i], finite[i] = faults_mod.host_update_stats(
                        crow, old_row)
                    overrides[i] = crow
                quarantine = faults_mod.screen_quarantine(
                    norms, finite, contributors,
                    self.faults.config.validation)
                # corrupted uploads the screen MISSED reach the canvas;
                # screened ones never do
                overrides = {i: p for i, p in overrides.items()
                             if not quarantine[i]}
                quarantined_b = float(np.sum(
                    (wire_vec + fr.extra_bytes)[arrived & quarantine]))
                if obs.active:
                    for i in np.flatnonzero(arrived & quarantine):
                        obs.fault(t, {"kind": "quarantine",
                                      "client": int(i),
                                      "norm": float(norms[i]),
                                      "finite": bool(finite[i])})
            valid = arrived & ~quarantine
            partial &= ~quarantine
            contributors = valid | partial
            survivors = int(np.sum(part & ~(
                fr.crashed if fr is not None else np.zeros(n, bool))))
            retries_n = int(np.sum(fr.retries[part])) if fr is not None \
                else 0

            # --- minimum quorum: below the floor the round is SKIPPED —
            # global and client params held, arrivals discarded, and the
            # allocation LP re-solved on survivor-only telemetry
            if fr is not None and int(contributors.sum()) \
                    < self.faults.quorum_floor(int(part.sum())):
                fleet.discard()
                abandoned_b += partial_bytes + float(np.sum(
                    (wire_vec + fr.extra_bytes)[valid]))
                # nobody contributed to a committed aggregate, but the
                # arrivals' bytes travelled — the store's economy (and
                # the seen flags) must reflect the contact
                self._population_round_done(
                    t, part, fr, wire_vec, losses,
                    contributors=np.zeros(n, bool), moved=arrived)
                if cfg.scheme == "feddd":
                    with obs.span("allocate", round=t):
                        self._allocate(losses, alive=~fr.crashed)
                metrics = (eval_fn(self.global_params)
                           if eval_fn and t % self.simcfg.eval_every == 0
                           else None)
                history.append(RoundRecord(
                    round=t, sim_time=round_end,
                    sim_round_time=round_end - dispatch,
                    host_wall_time=time.perf_counter() - host0,
                    mean_loss=float(np.mean(losses)),
                    dropout_rates=self.dropout.copy(),
                    uploaded_fraction=0.0, uploaded_bytes=0.0,
                    wire_bytes=0.0, participants=0,
                    survivors=survivors, retries=retries_n,
                    abandoned_bytes=abandoned_b,
                    quarantined_bytes=quarantined_b,
                    skipped=True, metrics=metrics))
                if obs.active:
                    obs.fault(t, {
                        "kind": "quorum_skip",
                        "contributors": int(contributors.sum()),
                        "floor": self.faults.quorum_floor(
                            int(part.sum()))})
                    obs.round(history[-1], path="sim", scheme=cfg.scheme,
                              client_times=np.where(
                                  arrived, arr_time - dispatch, np.nan))
                self._maybe_checkpoint(t, fleet, losses, history)
                continue

            # --- fused engine step: exclusion == 0 aggregation weight;
            # partial clients keep their weight but only their delivered
            # mask-channel prefix aggregates
            delivered_arg = None
            if partial.any():
                n_leaves = len(self.wire_specs[0].leaves)
                mat = np.full((n, n_leaves), np.iinfo(np.int32).max,
                              np.int32)
                for i, counts in delivered_rows.items():
                    if partial[i]:
                        mat[i] = counts
                delivered_arg = tuple(jnp.asarray(mat[:, li])
                                      for li in range(n_leaves))
            with obs.span("engine_step", round=t):
                densities, wire_oh = fleet.step(
                    d_used, self.weights * contributors, rk,
                    full_round=(t % cfg.h == 0) or self._dense,
                    dense=self._dense, delivered=delivered_arg,
                    overrides=overrides)
            with obs.span("host_transfer", round=t):
                dens, oh, loss_host = jax.device_get(
                    (densities, wire_oh, loss_dev))
            # the loss report ships WITH the upload: a straggler whose
            # transfer was abandoned (or quarantined) keeps its stale
            # loss server-side
            losses = np.where(valid, np.asarray(loss_host, float), losses)
            uploaded, wire = account_uplink(dens, valid,
                                            self.tel.model_bytes, oh,
                                            cfg.comm, obs=obs)
            wire += partial_bytes
            if fr is not None:
                wire += float(np.sum(fr.extra_bytes[valid]))
            if self.mesh is not None and not self.heterogeneous:
                account_collective(
                    self._global_spec, self.engine.num_shards,
                    mode=cfg.mesh_collective,
                    k_fraction=cfg.mesh_keep_fraction, obs=obs)

            # --- population write-back BEFORE the t+1 allocation, so a
            # cold-start solve already sees this round's first contacts
            self._population_round_done(
                t, part, fr, wire_vec, losses,
                contributors=contributors, moved=contributors)

            # --- allocation for round t+1, from what the server observed.
            # A correlated outage (sim/outages.py) excludes its cells
            # wholesale: the LP re-solves on survivor-only telemetry and
            # the downed cells keep their previous rates (None = no
            # outage overlay, bit-identical to the plain solve)
            if cfg.scheme == "feddd":
                om = (self.faults.outage_mask(t - 1)
                      if self.faults is not None else None)
                with obs.span("allocate", round=t):
                    self._allocate(losses,
                                   alive=(~om if om is not None
                                          and om.any() else None))

            if eval_fn and t % self.simcfg.eval_every == 0:
                with obs.span("eval", round=t):
                    metrics = eval_fn(self.global_params)
            else:
                metrics = None
            history.append(RoundRecord(
                round=t, sim_time=round_end,
                sim_round_time=round_end - dispatch,
                host_wall_time=time.perf_counter() - host0,
                mean_loss=float(np.mean(losses)),
                dropout_rates=self.dropout.copy(),
                uploaded_fraction=uploaded / max(self.full_bytes, 1e-9),
                uploaded_bytes=uploaded, wire_bytes=wire,
                participants=int(np.sum(contributors)),
                survivors=survivors, retries=retries_n,
                abandoned_bytes=abandoned_b,
                quarantined_bytes=quarantined_b,
                metrics=metrics))
            if obs.active:
                # per-client upload-completion offsets on the sim clock:
                # the straggler timeline (NaN = never landed this round)
                obs.round(history[-1], path="sim", scheme=cfg.scheme,
                          client_times=np.where(
                              arrived, arr_time - dispatch, np.nan))
            self._maybe_checkpoint(t, fleet, losses, history)

        self.client_params = fleet.export()
        if self.population is not None:
            self.population.fold_back(self.cohort, self.client_params,
                                      dropout=self.dropout, losses=losses)
        return self._result(history)

    # -- buffered fully-async policy ------------------------------------------

    def run_async(self, local_train_fn: Callable, eval_fn=None,
                  rounds: Optional[int] = None) -> SimResult:
        """FedBuff-style serving: merge every ``buffer_size`` arrivals with
        staleness-decayed weights; merged clients re-dispatch immediately.

        One history record per merge ("virtual round"); ``sim_time`` is
        the merge's arrival-complete time, so fast clients lap stragglers
        instead of the fleet idling at Eq. (12)'s max.
        """
        self.obs = obs_mod.make_recorder(
            self.cfg.obs, driver="sim", scheme=self.cfg.scheme,
            policy=str(self.simcfg.policy),
            clients=self.tel.num_clients,
            rounds=rounds or self.cfg.rounds)
        try:
            return self._run_async_impl(local_train_fn, eval_fn, rounds)
        finally:
            self.obs.close()
            self.obs = obs_mod.NULL_RECORDER

    def _run_async_impl(self, local_train_fn: Callable, eval_fn=None,
                        rounds: Optional[int] = None) -> SimResult:
        cfg = self.cfg
        obs = self.obs
        rounds = rounds or cfg.rounds
        n = self.tel.num_clients
        k_buf = self.policy.resolved_buffer(n)
        sim = self.sim
        losses = np.ones(n)
        history: List[RoundRecord] = []
        version = 0
        merges = 0
        epochs = np.zeros(n, int)             # per-client dispatch count
        dispatch_version = np.zeros(n, int)
        pending: Dict[int, tuple] = {}        # i -> (old, new, loss, d_i)
        train_key = jax.random.fold_in(self.rng, 0)
        agg_key = jax.random.fold_in(self.rng, 1)
        seq = 0
        # async fault bookkeeping (sim/faults.py): draws are keyed by the
        # client's OWN dispatch epoch, so the stream is independent of
        # merge interleaving and replay-identical across processes
        faults = self.faults
        budget = (faults.config.staleness_budget
                  if faults is not None else 0)
        pend_wire = np.zeros(n)      # codec bytes of the pending upload
        pend_extra = np.zeros(n)     # retransmitted duplicate bytes
        abandoned_acc = 0.0
        retries_acc = 0
        no_progress = 0

        def dispatch(i: int) -> None:
            nonlocal seq, abandoned_acc, retries_acc
            e = int(epochs[i])
            cond = self.network.conditions(e)
            epochs[i] += 1
            d_i = float(self.dropout[i]) if cfg.scheme == "feddd" else 0.0
            p_new, loss = local_train_fn(
                self.client_params[i], i, jax.random.fold_in(train_key, seq))
            seq += 1
            pending[i] = (self.client_params[i], p_new, loss, d_i)
            dispatch_version[i] = version
            pend_extra[i] = 0.0
            pend_wire[i] = (
                float(self.tel.model_bytes[i]) * (1.0 - d_i)
                if cfg.comm.is_default else
                float(analytic_uplink_vector([self.wire_specs[i]],
                                             np.asarray([d_i]),
                                             cfg.comm)[0]))
            if faults is None:
                self._schedule_round_trip(i, sim.now, d_i, cond)
                return
            fr = faults.round_faults(e, np.full(n, pend_wire[i]),
                                     np.asarray(cond.uplink_rate, float))
            if fr.crashed[i]:
                # the client dies mid-trip; its upload never arrives and
                # the CLIENT_DOWN marker re-enters the slot at the crash
                # instant (a crash-resume of the CLIENT, not the server)
                t0 = sim.now
                _, cp_t, up_t = self._schedule_round_trip(
                    i, t0, d_i, cond, crash_frac=float(fr.crash_frac[i]))
                cutoff = t0 + float(fr.crash_frac[i]) * (up_t - t0)
                if cutoff > cp_t and up_t > cp_t:
                    abandoned_acc += pend_wire[i] * min(
                        (cutoff - cp_t) / (up_t - cp_t), 1.0)
                if obs.active:
                    obs.fault(merges + 1, {
                        "kind": "crash", "client": int(i),
                        "crash_frac": float(fr.crash_frac[i])})
            elif fr.aborted[i]:
                # retransmit budget exhausted: the bytes already sent are
                # wasted and the slot re-enters when the client gives up
                _, _, up_t = self._schedule_round_trip(
                    i, sim.now, d_i, cond,
                    extra_delay=float(fr.extra_delay[i]),
                    drop_upload=True)
                sim.schedule_at(up_t, CLIENT_DOWN, i)
                abandoned_acc += float(fr.sent_bytes[i])
                retries_acc += int(fr.retries[i])
                if obs.active:
                    obs.fault(merges + 1, {
                        "kind": "abort", "client": int(i),
                        "retries": int(fr.retries[i]),
                        "sent_bytes": float(fr.sent_bytes[i])})
            else:
                if fr.retries[i]:
                    retries_acc += int(fr.retries[i])
                    pend_extra[i] = float(fr.extra_bytes[i])
                self._schedule_round_trip(
                    i, sim.now, d_i, cond,
                    extra_delay=float(fr.extra_delay[i]))

        for i in range(n):
            dispatch(i)
        buffer: List[int] = []
        prev_time = 0.0
        host_prev = time.perf_counter()

        while merges < rounds and sim.queue:
            ev = sim.step()
            self.observed.observe(ev)
            if ev.kind == CLIENT_DOWN:
                # crash/abort became known: the slot re-enters now.  The
                # counter guards the degenerate every-dispatch-dies
                # config, which would otherwise spin forever
                no_progress += 1
                if no_progress > 10_000 * max(n, 1):
                    raise RuntimeError(
                        "async run is making no progress: every "
                        "re-dispatched client crashed or aborted "
                        f"{no_progress} times in a row — lower "
                        "crash_rate / loss_rate")
                dispatch(ev.client)
                continue
            if ev.kind != UPLOAD_DONE:
                continue
            no_progress = 0
            buffer.append(ev.client)
            losses[ev.client] = float(pending[ev.client][2])
            if len(buffer) < k_buf:
                continue

            # --- staleness budget (FaultConfig.staleness_budget): the
            # buffered-async analogue of the wave quorum.  Entries staler
            # than the budget are dropped (bytes charged as abandoned,
            # client re-dispatched); the merge proceeds only when the
            # surviving buffered mass still meets the quorum floor,
            # otherwise the server keeps buffering
            if faults is not None and budget:
                stale = (version - dispatch_version[buffer]) > budget
                if stale.any():
                    for i in np.asarray(buffer)[stale]:
                        i = int(i)
                        abandoned_acc += pend_wire[i] + pend_extra[i]
                        if obs.active:
                            obs.fault(merges + 1, {
                                "kind": "stale_drop", "client": i,
                                "staleness": int(version
                                                 - dispatch_version[i]),
                                "budget": int(budget)})
                        dispatch(i)
                    buffer = [i for i, s in zip(buffer, stale) if not s]
                if len(buffer) < faults.quorum_floor(k_buf):
                    continue

            # --- merge the buffer: one fused engine step over K clients
            merges += 1
            staleness = version - dispatch_version[buffer]
            scale = self.policy.staleness_scale(staleness)
            w = self.weights[buffer] * scale
            merge_key = jax.random.fold_in(agg_key, merges)
            full_round = (merges % cfg.h == 0) or self._dense
            with obs.span("engine_step", round=merges):
                if self.heterogeneous:
                    dens, oh = self._merge_grouped(buffer, pending, w,
                                                   merge_key, full_round)
                else:
                    olds = round_engine.stack_pytrees(
                        [pending[i][0] for i in buffer])
                    news = round_engine.stack_pytrees(
                        [pending[i][1] for i in buffer])
                    d_vec = np.asarray([pending[i][3] for i in buffer])
                    out = self.engine.step(
                        olds, news, self.global_params, d_vec, w,
                        merge_key, full_round=full_round,
                        dense_masks=self._dense)
                    self.global_params = out.global_params
                    dens, oh = jax.device_get((out.densities,
                                               out.wire_overhead))
                    dens = np.asarray(dens, float)
                    for j, i in enumerate(buffer):
                        self.client_params[i] = jax.tree_util.tree_map(
                            lambda l, j=j: l[j], out.client_params)
            version += 1
            uploaded, wire = account_uplink(
                dens, np.ones(len(buffer), bool),
                self.tel.model_bytes[buffer], oh, cfg.comm, obs=obs)
            if faults is not None:
                # surviving retransmits moved duplicate bytes on the wire
                wire += float(np.sum(pend_extra[buffer]))

            if cfg.scheme == "feddd":
                with obs.span("allocate", round=merges):
                    self._allocate(losses)
            metrics = (eval_fn(self.global_params)
                       if eval_fn and merges % self.simcfg.eval_every == 0
                       else None)
            history.append(RoundRecord(
                round=merges, sim_time=ev.time,
                sim_round_time=ev.time - prev_time,
                host_wall_time=time.perf_counter() - host_prev,
                mean_loss=float(np.mean(losses)),
                dropout_rates=self.dropout.copy(),
                uploaded_fraction=uploaded / max(self.full_bytes, 1e-9),
                uploaded_bytes=uploaded, wire_bytes=wire,
                participants=len(buffer), survivors=len(buffer),
                retries=retries_acc, abandoned_bytes=abandoned_acc,
                metrics=metrics))
            if obs.active:
                obs.round(history[-1], path="sim_async",
                          scheme=cfg.scheme)
            prev_time = ev.time
            host_prev = time.perf_counter()
            retries_acc, abandoned_acc = 0, 0.0

            for i in buffer:
                dispatch(i)     # re-enter immediately: no fleet barrier
            buffer = []

        return self._result(history)


def run_sim(scheme: str, global_params, telemetry: ClientTelemetry,
            local_train_fn: Callable, eval_fn=None, *,
            sim: Optional[SimConfig] = None,
            network: Optional[NetworkModel] = None,
            client_params: Optional[List] = None,
            faults: Optional[FaultModel] = None,
            population=None, cohort_size: Optional[int] = None,
            rounds: Optional[int] = None, **cfg_kw) -> SimResult:
    """One-call driver, mirroring :func:`repro.core.protocol.run_scheme`.

    Args:
      scheme: feddd | fedavg | fedcs | oort.  Selection baselines
        (fedcs/oort) are evaluated on the server's observed telemetry and
        are wave-only — per-round client selection has no meaning when
        every client free-runs, so combining them with the async policy
        raises instead of silently degenerating to fedavg.
      sim: :class:`SimConfig` — policy + observation knobs.
      network: a :class:`repro.sim.network.NetworkModel`; defaults to
        :class:`StaticNetwork` over ``telemetry`` (the paper's setting).
      client_params: optional per-client sub-model pytrees (ragged widths,
        HeteroFL-style slices of ``global_params``); the runner partitions
        them by shape and drives the grouped engine — stragglers x ragged
        fleets compose freely with every policy.
      faults: a :class:`repro.sim.faults.FaultModel` — client churn, lossy
        uplinks, corrupted payloads, quorum-gated degradation, and the
        correlated cell-outage overlay
        (:class:`repro.sim.outages.CellOutageModel`).  ``None`` (the
        default) leaves every run bit-identical to the fault-free
        simulator.  Crash / loss / retry channels and the
        staleness-budget quorum also apply to the async policy; payload
        corruption stays wave-only.
      population: a :class:`repro.population.Population` — ``telemetry``
        (and ``network``/``client_params``, when given) then cover the
        POPULATION, and each round materializes only the sampled
        ``cohort_size`` clients into engine buffers; availability churn
        and the cohort sampler live on the Population object.  A
        population whose size equals the fleet with always-on
        availability and the default sampler is bit-identical to the
        plain fleet run.  Wave policies only.
      cohort_size: clients per round (default: the whole population).
      **cfg_kw: ProtocolConfig fields (rounds, a_server, d_max, delta, h,
        seed, selection, allocator, robust_agg, checkpoint_every,
        checkpoint_path, resume_from — the last three drive bit-identical
        crash-resume of wave-policy runs; see repro.checkpoint).
    """
    simcfg = sim or SimConfig()
    if rounds is not None:
        cfg_kw["rounds"] = rounds
    cfg_kw.pop("batched", None)       # the sim runner is always batched
    if population is not None:
        cfg_kw.setdefault("population", population.size)
        cfg_kw.setdefault("cohort_size",
                          cohort_size if cohort_size is not None
                          else population.size)
    elif cohort_size is not None:
        raise ValueError("cohort_size requires population=")
    cfg = ProtocolConfig(scheme=scheme, **cfg_kw)
    runner = SimRunner(global_params, cfg, telemetry, simcfg, network,
                       client_params=client_params, faults=faults,
                       population=population,
                       cohort_size=cfg.cohort_size)
    if isinstance(runner.policy, AsyncPolicy):
        if scheme in ("fedcs", "oort"):
            raise ValueError(
                f"scheme {scheme!r} is a per-round client-selection "
                "baseline; it has no async analogue (use sync/deadline, "
                "or feddd/fedavg with async)")
        return runner.run_async(local_train_fn, eval_fn, cfg.rounds)
    return runner.run_waves(local_train_fn, eval_fn, cfg.rounds)
