"""Event-driven federated system simulator — the time-domain subsystem.

Modules
  engine    discrete-event queue + simulated clock (deterministic order)
  network   true per-epoch client conditions: static Table-4, two-state
            Markov fading, trace-driven
  policies  server aggregation disciplines: sync wait-for-all, deadline
            semi-sync (drops late uploads), retry/timeout serving,
            buffered async with staleness-decayed weights
  faults    deterministic fault injection: client churn, lossy uplinks
            with retransmit/backoff, corrupted payloads, server-side
            validation + quorum-gated degradation
  outages   correlated cell-outage overlay: clients grouped into cells,
            each cell driven by a two-state Markov availability chain;
            outages crash whole cells at once
  runner    the driver: composes the above with the batched round engine
            and re-solves the dropout LP from OBSERVED telemetry

Population-scale serving rides the same runner: ``run_sim(...,
population=Population(tel), cohort_size=K)`` (repro.population) samples
a K-client cohort per round from a large, mostly-offline population —
availability models decide who is online, cohort samplers pick the
round's fleet, and per-client sticky state (telemetry EWMAs by GLOBAL
id, losses, dropout rates, params, byte economy) survives cohort churn.
A population the size of the fleet with always-on availability is
bit-identical to a plain fleet run.

Entry points: :func:`run_sim`, or ``run_scheme(..., sim=..., network=...,
faults=..., population=...)`` in repro.core.protocol.  See the routing
table in core/protocol.py for which execution path serves which
scenario.
"""

from repro.sim.engine import (COMPUTE_DONE, DOWNLOAD_DONE, UPLOAD_DONE,
                              Event, EventQueue, Simulator)
from repro.sim.faults import (CORRUPT_KINDS, FaultConfig, FaultModel,
                              RandomFaults, RoundFaults, ScriptedFaults,
                              ValidationConfig)
from repro.sim.network import (MarkovFadingNetwork, NetworkConditions,
                               NetworkModel, StaticNetwork, TraceNetwork,
                               make_network, telemetry_with_conditions)
from repro.sim.outages import CellOutageModel, OutageConfig
from repro.sim.policies import (POLICIES, AsyncPolicy, DeadlinePolicy,
                                RetryPolicy, SyncPolicy, make_policy)
from repro.sim.runner import (ObservedTelemetry, SimConfig, SimResult,
                              SimRunner, run_sim)
