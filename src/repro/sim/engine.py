"""Discrete-event clock and queue — the time axis of the FL simulator.

The protocol driver (core/protocol.py) models time as one closed-form
``max`` per round (paper Eq. (12)); that is exact for the synchronous
policy but cannot express deadlines, stragglers finishing mid-round, or
asynchronous merges.  This engine owns an explicit event timeline instead:

* :class:`Event` — an immutable (time, seq, kind, client, payload) record.
  Ordering is ``(time, seq)``: the monotone ``seq`` counter breaks time
  ties in SCHEDULING order, so a run's event order is a pure function of
  the schedule calls — same seed, same code path ⇒ the same event order
  in every process (tests/test_sim.py pins this).
* :class:`EventQueue` — a binary-heap priority queue of events.
* :class:`Simulator` — queue + clock.  ``schedule`` inserts relative to
  ``now``; ``step`` pops the earliest event, advances the clock to its
  time, and appends it to ``trace``.

Event kinds used by the FL runner (sim/runner.py) are the module
constants below; the engine itself is agnostic and carries any string.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List, Optional, Tuple

# Event kinds of a client round trip (scheduled in this causal order):
DOWNLOAD_DONE = "download_done"   # client received the (masked) global model
COMPUTE_DONE = "compute_done"     # local training finished
UPLOAD_DONE = "upload_done"       # sparse update arrived at the server


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    """One timeline entry.  Sort key is ``(time, seq)`` only."""

    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    client: int = dataclasses.field(compare=False, default=-1)
    payload: Any = dataclasses.field(compare=False, default=None)


class EventQueue:
    """Min-heap of :class:`Event` with a deterministic tie-break counter."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client: int = -1,
             payload: Any = None) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   client=client, payload=payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def clear(self) -> List[Event]:
        """Cancel every pending event (deadline cut-off: in-flight
        transfers of the closing round are abandoned).  Returns the
        cancelled events in time order."""
        out = sorted(self._heap)
        self._heap = []
        return out

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class Simulator:
    """Event queue + simulated clock.

    ``now`` is simulated seconds (the paper's Eq. (12) time domain), NOT
    host seconds — see :class:`repro.core.protocol.RoundRecord` for the
    sim_time / host_wall_time distinction.
    """

    def __init__(self) -> None:
        self.queue = EventQueue()
        self.now: float = 0.0
        # (time, kind, client) triples of every processed event, in order —
        # the determinism witness asserted by tests/test_sim.py.
        self.trace: List[Tuple[float, str, int]] = []

    def schedule(self, delay: float, kind: str, client: int = -1,
                 payload: Any = None) -> Event:
        """Schedule ``kind`` at ``now + delay`` (delay >= 0)."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        return self.queue.push(self.now + delay, kind, client, payload)

    def schedule_at(self, time: float, kind: str, client: int = -1,
                    payload: Any = None) -> Event:
        """Schedule ``kind`` at an absolute simulated time (>= now)."""
        if time < self.now - 1e-12:
            raise ValueError(f"cannot schedule into the past "
                             f"({time} < now={self.now})")
        return self.queue.push(time, kind, client, payload)

    def step(self) -> Event:
        """Pop the earliest event, advance the clock, record the trace."""
        ev = self.queue.pop()
        self.now = ev.time
        self.trace.append((ev.time, ev.kind, ev.client))
        return ev

    def advance_to(self, time: float) -> None:
        """Move the clock forward without an event (e.g. the server sits
        idle until its round deadline)."""
        if time < self.now - 1e-12:
            raise ValueError(f"clock cannot run backwards "
                             f"({time} < now={self.now})")
        self.now = max(self.now, float(time))

    def drain(self, kind: Optional[str] = None) -> List[Event]:
        """Step until the queue is empty; return the processed events
        (optionally only those matching ``kind``)."""
        out: List[Event] = []
        while self.queue:
            ev = self.step()
            if kind is None or ev.kind == kind:
                out.append(ev)
        return out
