"""Mamba (selective SSM) block — used by the jamba hybrid architecture.

Training/prefill runs a *chunked* selective scan: an outer ``lax.scan`` over
sequence chunks carries the (B, d_inner, d_state) SSM state; within a chunk
a parallel associative scan computes the recurrence.  This bounds the
intermediate footprint to O(B * chunk * d_inner * d_state) while keeping the
sequential depth at S/chunk — the TPU-friendly middle ground between a full
associative scan (memory-heavy at 4k-500k tokens) and a per-step scan
(serial latency).

Decode is the exact single-step recurrence with a rolling conv state.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import MambaConfig, ModelConfig
from repro.models.sharding import shard

CHUNK = 256


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mamba
    d, di, ds, dr = cfg.d_model, d_inner(cfg), m.d_state, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation of A
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": layers.init_dense(ks[0], d, 2 * di, dtype)["kernel"],
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, di), jnp.float32)
                   * (1.0 / math.sqrt(m.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.init_dense(ks[2], di, dr + 2 * ds, dtype)["kernel"],
        "dt_proj": layers.init_dense(ks[3], dr, di, dtype)["kernel"],
        "dt_bias": jnp.log(jnp.expm1(  # softplus-inverse of U(1e-3, 1e-1)
            jax.random.uniform(ks[4], (di,), jnp.float32,
                               minval=1e-3, maxval=1e-1))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": layers.init_dense(ks[5], di, d, dtype)["kernel"],
    }


class MambaState(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, d_inner) rolling inputs
    ssm: jax.Array     # (B, d_inner, d_state) fp32

    @staticmethod
    def zeros(b: int, cfg: ModelConfig, dtype) -> "MambaState":
        return MambaState(
            conv=jnp.zeros((b, cfg.mamba.d_conv - 1, d_inner(cfg)), dtype),
            ssm=jnp.zeros((b, d_inner(cfg), cfg.mamba.d_state), jnp.float32))


def _split_proj(p, cfg: ModelConfig, xz: jax.Array):
    di = d_inner(cfg)
    return xz[..., :di], xz[..., di:]


def _ssm_params(p, cfg: ModelConfig, u: jax.Array):
    """u: (..., di) conv output -> (dt (...,di), B (...,ds), C (...,ds))."""
    dr, ds = dt_rank(cfg), cfg.mamba.d_state
    proj = jnp.einsum("...d,de->...e", u, p["x_proj"].astype(u.dtype))
    dt_in, b, c = (proj[..., :dr], proj[..., dr:dr + ds],
                   proj[..., dr + ds:])
    dt = jnp.einsum("...r,rd->...d", dt_in, p["dt_proj"].astype(u.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _causal_conv(p, cfg: ModelConfig, x: jax.Array,
                 prefix: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq.  x: (B,S,di); prefix: (B,dc-1,di)."""
    dc = cfg.mamba.d_conv
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
              for i in range(dc))
    return jax.nn.silu(out + p["conv_b"].astype(x.dtype))


def _scan_chunk(carry: jax.Array, a_bar: jax.Array, bx: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Associative scan of h_t = a_t * h_{t-1} + bx_t within a chunk.

    a_bar/bx: (B, Q, di, ds) fp32; carry: (B, di, ds).
    Returns (new_carry, h (B,Q,di,ds)).
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_cum, h = jax.lax.associative_scan(comb, (a_bar, bx), axis=1)
    h = h + a_cum * carry[:, None]
    return h[:, -1], h


def mamba_forward(p, cfg: ModelConfig, x: jax.Array,
                  chunk: int = CHUNK) -> jax.Array:
    """Training/prefill.  x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di, ds = d_inner(cfg), cfg.mamba.d_state
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xz = shard(xz, "batch", "seq", "inner")
    xs, z = _split_proj(p, cfg, xz)
    prefix = jnp.zeros((b, cfg.mamba.d_conv - 1, di), dt_)
    u = _causal_conv(p, cfg, xs, prefix)
    dt, bmat, cmat = _ssm_params(p, cfg, u)
    a = -jnp.exp(p["a_log"])                                   # (di, ds)
    # discretise: a_bar = exp(dt*A); bx = dt * B * u
    q = max(1, min(chunk, s))
    n_chunks = (s + q - 1) // q
    pad = n_chunks * q - s
    def _padseq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    uq = _padseq(u.astype(jnp.float32)).reshape(b, n_chunks, q, di)
    dtq = _padseq(dt).reshape(b, n_chunks, q, di)
    bq = _padseq(bmat).reshape(b, n_chunks, q, ds)
    cq = _padseq(cmat).reshape(b, n_chunks, q, ds)

    def step(h, inputs):
        u_c, dt_c, b_c, c_c = inputs                 # (B, Q, ...)
        a_bar = jnp.exp(dt_c[..., None] * a)         # (B,Q,di,ds)
        bx = (dt_c * u_c)[..., None] * b_c[:, :, None, :]
        h_new, hs = _scan_chunk(h, a_bar, bx)
        y = jnp.einsum("bqds,bqs->bqd", hs, c_c)
        return h_new, y

    h0 = jnp.zeros((b, di, ds), jnp.float32)
    xs_in = tuple(jnp.moveaxis(t, 1, 0) for t in (uq, dtq, bq, cq))
    _, ys = jax.lax.scan(step, h0, xs_in)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, n_chunks * q, di)[:, :s]
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(dt_)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    return shard(out, "batch", "seq", None)


def mamba_decode(p, cfg: ModelConfig, x: jax.Array, state: MambaState
                 ) -> Tuple[jax.Array, MambaState]:
    """One token.  x: (B, 1, D)."""
    b = x.shape[0]
    di = d_inner(cfg)
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xs, z = _split_proj(p, cfg, xz)                   # (B,1,di)
    window = jnp.concatenate([state.conv.astype(dt_), xs], axis=1)
    u = sum(window[:, i, :] * p["conv_w"][i].astype(dt_)
            for i in range(cfg.mamba.d_conv))
    u = jax.nn.silu(u + p["conv_b"].astype(dt_))      # (B, di)
    dt, bmat, cmat = _ssm_params(p, cfg, u)
    a = -jnp.exp(p["a_log"])
    a_bar = jnp.exp(dt[..., None] * a)                # (B,di,ds)
    bx = (dt * u.astype(jnp.float32))[..., None] * bmat[:, None, :]
    h = a_bar * state.ssm + bx
    y = jnp.einsum("bds,bs->bd", h, cmat) + u.astype(jnp.float32) * p["d_skip"]
    y = y.astype(dt_) * jax.nn.silu(z[:, 0])
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(dt_))
    new_state = MambaState(conv=window[:, 1:], ssm=h)
    return out[:, None, :], new_state
