"""Grouped-query attention: training forward, decode step, cross-attention.

Supports
  * GQA (num_kv_heads < num_heads) with head replication via einsum grouping,
  * causal full attention,
  * sliding-window ("local") causal attention with a static window,
  * bidirectional encoder self-attention,
  * cross-attention over encoder outputs,
  * RoPE (full or partial / "2d"), optional QK-norm,
  * decode: single-token query against a (possibly ring-buffered) KV cache.

Shapes: x (B, S, D); q (B, S, H, hd); kv (B, S, Hkv, hd).
Softmax in fp32; matmuls in the compute dtype (bf16 target).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.sharding import shard

NEG_INF = -2.3819763e38   # lowest bf16-representable; standard flash value


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.init_dense(ks[0], d, h * hd, dtype)["kernel"]
              .reshape(d, h, hd),
        "wk": layers.init_dense(ks[1], d, hkv * hd, dtype)["kernel"]
              .reshape(d, hkv, hd),
        "wv": layers.init_dense(ks[2], d, hkv * hd, dtype)["kernel"]
              .reshape(d, hkv, hd),
        "wo": layers.init_dense(ks[3], h * hd, d, dtype,
                                scale=1.0 / math.sqrt(h * hd))["kernel"]
              .reshape(h, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_norm(hd, "rmsnorm")
        p["k_norm"] = layers.init_norm(hd, "rmsnorm")
    return p


def _project_qkv(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = layers.apply_norm(p["q_norm"], q, "rmsnorm")
        k = layers.apply_norm(p["k_norm"], k, "rmsnorm")
    if cfg.rope != "none":
        rot = int(cfg.head_dim_ * cfg.rotary_pct)
        rot -= rot % 2
        cos, sin = layers.rotary_angles(positions, rot, cfg.rope_theta)
        cos, sin = cos.astype(jnp.float32), sin.astype(jnp.float32)
        q = layers.apply_rotary(q, cos, sin, cfg.rotary_pct)
        k = layers.apply_rotary(k, cos, sin, cfg.rotary_pct)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
          ) -> jax.Array:
    """Grouped scaled-dot-product attention.

    q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd); mask broadcastable to
    (B, H, Sq, Skv) (True = attend).
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        # mask: (B|1, sq, skv) boolean -> broadcast over (hkv, g)
        m = mask[:, None, None, :, :]
        scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", w, v)
    return out.reshape(b, sq, h, hd)


# KV-chunked online-softmax attention kicks in above this sequence length:
# materialising (B, H, S, S) scores at 32k+ dominates HBM traffic
# (EXPERIMENTS.md §Perf Q2); the chunked path bounds it to (B, H, S, CHUNK).
FLASH_CHUNK = 2048
FLASH_MIN_SEQ = 8192


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  mode: str, window: int) -> jax.Array:
    """Flash-style attention: scan over KV chunks with running max/sum.

    q: (B, S, H, hd); k/v: (B, S, Hkv, hd).  Causal ('full') or sliding
    window ('local') masking, self-attention alignment (sq == skv).
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    c = FLASH_CHUNK
    n_chunks = (s + c - 1) // c
    pad = n_chunks * c - s
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(kp.reshape(b, n_chunks, c, hkv, hd), 1, 0)
    vc = jnp.moveaxis(vp.reshape(b, n_chunks, c, hkv, hd), 1, 0)

    qg = (q.reshape(b, s, hkv, g, hd) / math.sqrt(hd)).astype(jnp.float32)
    qi = jnp.arange(s)[:, None]

    def step(carry, inp):
        m_run, l_run, o_run = carry            # (b,hkv,g,s), ., (b,hkv,g,s,hd)
        kj, vj, j0 = inp
        scores = jnp.einsum("bqhgk,bjhk->bhgqj", qg,
                            kj.astype(jnp.float32))
        kid = j0 * c + jnp.arange(c)[None, :]
        valid = kid < s
        if mode == "local":
            m = (kid <= qi) & (kid > qi - window) & valid
        else:
            m = (kid <= qi) & valid
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        o_new = (o_run * corr[..., None]
                 + jnp.einsum("bhgqj,bjhk->bhgqk", p,
                              vj.astype(jnp.float32)))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, s, hd), jnp.float32)
    (m_f, l_f, o_f), _ = jax.lax.scan(
        step, (m0, l0, o0), (kc, vc, jnp.arange(n_chunks)))
    out = o_f / jnp.maximum(l_f[..., None], 1e-30)
    out = jnp.moveaxis(out, -2, 1).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def causal_mask(sq: int, skv: int, offset: int = 0) -> jax.Array:
    """(sq, skv) boolean mask; query i attends kv j iff j <= i + offset."""
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    return kj <= qi + offset


def local_mask(sq: int, skv: int, window: int, offset: int = 0) -> jax.Array:
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    return (kj <= qi + offset) & (kj > qi + offset - window)


def self_attention(p, cfg: ModelConfig, x: jax.Array, *,
                   mode: str, positions: Optional[jax.Array] = None,
                   window: Optional[int] = None) -> jax.Array:
    """Training/prefill self-attention.  mode: full|local|bidir."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if (mode in ("full", "local") and s >= FLASH_MIN_SEQ
            and jax.default_backend() == "tpu"):
        # TPU target: fused Pallas flash kernel — scores stay in VMEM
        from repro.kernels.flash_attention.ops import gqa_flash_attention
        out = gqa_flash_attention(
            q, k, v, causal=True,
            window=(window or cfg.window_size) if mode == "local" else 0)
    elif mode in ("full", "local") and s >= FLASH_MIN_SEQ:
        out = _sdpa_chunked(q, k, v, mode=mode,
                            window=window or cfg.window_size)
    elif mode == "full":
        out = _sdpa(q, k, v, causal_mask(s, s)[None])
    elif mode == "local":
        out = _sdpa(q, k, v, local_mask(s, s, window or cfg.window_size)[None])
    elif mode == "bidir":
        out = _sdpa(q, k, v, None)
    else:
        raise ValueError(mode)
    out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard(y, "batch", "seq", None)


# ------------------------------------------------------------- decode ------

class KVCache(NamedTuple):
    """Static-shape KV cache for one attention layer (group).

    k/v: (B, C, Hkv, hd) where C = full seq budget (full/global layers) or
    the window size (local layers — ring buffer indexed pos % C).
    """
    k: jax.Array
    v: jax.Array

    @staticmethod
    def zeros(b: int, c: int, hkv: int, hd: int, dtype) -> "KVCache":
        z = jnp.zeros((b, c, hkv, hd), dtype)
        return KVCache(k=z, v=z)


def decode_self_attention(p, cfg: ModelConfig, x: jax.Array, cache: KVCache,
                          pos: jax.Array, *, mode: str
                          ) -> Tuple[jax.Array, KVCache]:
    """One-token decode.  x: (B, 1, D); pos: scalar int32 current position.

    Returns (output (B,1,D), updated cache).
    """
    b = x.shape[0]
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    c = cache.k.shape[1]
    slot = (pos % c if mode == "local"
            else jnp.minimum(pos, c - 1)).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    idx = jnp.arange(c)
    if mode == "local":
        # Ring buffer: slot j currently holds the token written at time
        # t_j = pos - ((pos - j) mod c).  It is valid iff t_j >= 0; the
        # window constraint (t_j > pos - c) holds automatically since the
        # buffer length equals the window.
        tj = pos - ((pos - idx) % c)
        valid = (tj >= 0)[None, :]
    else:
        valid = (idx <= pos)[None, :]
    mask = valid[:, None, :]                      # (1, sq=1, C)
    out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k=k, v=v)


# ------------------------------------------------------- cross-attention ---

def init_cross_attention(key, cfg: ModelConfig, dtype) -> dict:
    return init_attention(key, cfg, dtype)


def cross_attention(p, cfg: ModelConfig, x: jax.Array, enc: jax.Array,
                    ) -> jax.Array:
    """Decoder->encoder attention (no positional rotation, bidirectional)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc.astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc.astype(dt), p["wv"].astype(dt))
    out = _sdpa(q, k, v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
