"""The full model: embeddings -> (encoder) -> decoder stack -> logits,
plus train_step / serve_step factories and parameter sharding specs.

Input contract (matches launch/dryrun.py input_specs):
  dense/moe/hybrid/ssm : {"tokens": (B, S) int32}
  vlm                  : {"tokens": (B, S_text) int32,
                          "patch_embeds": (B, P, D)}       # stub frontend
  audio (enc-dec)      : {"tokens": (B, S_dec) int32,
                          "enc_frames": (B, S_enc, D)}     # stub frontend

Training computes next-token CE over the text tokens (VLM: patches are
prefix context only; audio: decoder tokens).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, blocks, layers
from repro.models.config import ModelConfig
from repro.models.sharding import shard, spec as pspec
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def plan_for(cfg: ModelConfig) -> blocks.StackPlan:
    return blocks.StackPlan.from_layout(cfg.layout())


def encoder_plan_for(cfg: ModelConfig) -> Optional[blocks.StackPlan]:
    if not cfg.is_encdec:
        return None
    return blocks.StackPlan.from_layout(cfg.encoder_layout())


# ----------------------------------------------------------------- init ----

def init_model(key, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "stack": blocks.init_stack(ks[1], cfg, plan_for(cfg), dt),
        "final_norm": layers.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_embedding(ks[2], cfg.vocab_size,
                                                  cfg.d_model, dt)
    if cfg.is_encdec:
        params["encoder"] = blocks.init_stack(ks[3], cfg,
                                              encoder_plan_for(cfg), dt)
        params["enc_norm"] = layers.init_norm(cfg.d_model, cfg.norm)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree without allocating (for the dry-run)."""
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


# -------------------------------------------------------------- forward ----

def _embed_inputs(params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    dt = _dtype(cfg)
    x = layers.embed_tokens(params["embed"], batch["tokens"],
                            scale=cfg.embed_scale).astype(dt)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    return shard(x, "batch", "seq", None)


def _run_encoder(params, cfg: ModelConfig, batch: Dict) -> Optional[jax.Array]:
    if not cfg.is_encdec:
        return None
    frames = batch["enc_frames"].astype(_dtype(cfg))
    pe = layers.sinusoidal_positions(frames.shape[1], cfg.d_model)
    h = frames + pe.astype(frames.dtype)
    h = shard(h, "batch", "seq", None)
    h, _ = blocks.apply_stack(params["encoder"], cfg, encoder_plan_for(cfg),
                              h, mode="bidir")
    return layers.apply_norm(params["enc_norm"], h, cfg.norm)


def forward(params, cfg: ModelConfig, batch: Dict,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_out, V) fp32, moe_aux)."""
    enc = _run_encoder(params, cfg, batch)
    x = _embed_inputs(params, cfg, batch)
    if cfg.is_encdec:
        pe = layers.sinusoidal_positions(x.shape[1], cfg.d_model)
        x = x + pe.astype(x.dtype)
    x, aux = blocks.apply_stack(params["stack"], cfg, plan_for(cfg), x,
                                enc=enc, remat=remat)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1]:]   # logits for text only
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x, softcap=cfg.logits_softcap)
    return shard(logits, "batch", "seq", "vocab"), aux


def loss_fn(params, cfg: ModelConfig, batch: Dict,
            remat: bool = True) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    total = ce + aux
    return total, {"ce": ce, "moe_aux": aux}


# ----------------------------------------------------------- train step ----

class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(key, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = init_model(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    num_microbatches: int = 1, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    With ``num_microbatches > 1`` the global batch is split along axis 0 and
    gradients are accumulated with a ``lax.scan`` (activation memory divides
    by the microbatch count; see EXPERIMENTS.md §Perf)."""

    def _grads(params, batch):
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, remat)
        return l, m, g

    def train_step(state: TrainState, batch: Dict):
        if num_microbatches <= 1:
            loss, metrics, grads = _grads(state.params, batch)
        else:
            def _split(t):
                b = t.shape[0]
                mb = b // num_microbatches
                return t.reshape((num_microbatches, mb) + t.shape[1:])
            micro = jax.tree_util.tree_map(_split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, _, g = _grads(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, lsum), _ = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads)
            loss = lsum / num_microbatches
            metrics = {"ce": loss, "moe_aux": jnp.zeros(())}
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return TrainState(params, opt_state, state.step + 1), {
            "loss": loss, "grad_norm": gnorm, **metrics}

    return train_step


# ----------------------------------------------------------- serve step ----

class DecodeState(NamedTuple):
    stack: Any                    # per-layer recurrent / KV states
    pos: jax.Array                # scalar int32 current position
    enc: Optional[jax.Array] = None   # enc-dec: cached encoder output


def init_decode_state(params, cfg: ModelConfig, batch_size: int,
                      cache_len: int,
                      enc_frames: Optional[jax.Array] = None) -> DecodeState:
    dt = _dtype(cfg)
    st = blocks.init_stack_state(cfg, plan_for(cfg), batch_size,
                                 cache_len, dt)
    enc = None
    if cfg.is_encdec:
        if enc_frames is None:
            raise ValueError("enc-dec decode requires enc_frames")
        enc = _run_encoder(params, cfg, {"enc_frames": enc_frames})
    return DecodeState(stack=st, pos=jnp.zeros((), jnp.int32), enc=enc)


def abstract_decode_state(cfg: ModelConfig, batch_size: int, cache_len: int,
                          enc_len: int = 0):
    """ShapeDtypeStructs for the decode state (dry-run input)."""
    dt = _dtype(cfg)
    st = jax.eval_shape(lambda: blocks.init_stack_state(
        cfg, plan_for(cfg), batch_size, cache_len, dt))
    enc = (jax.ShapeDtypeStruct((batch_size, enc_len, cfg.d_model), dt)
           if cfg.is_encdec else None)
    return DecodeState(
        stack=st, pos=jax.ShapeDtypeStruct((), jnp.int32), enc=enc)


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, state, tokens (B,1)) -> (logits (B,V), state)."""

    def serve_step(params, state: DecodeState, tokens: jax.Array):
        dt = _dtype(cfg)
        x = layers.embed_tokens(params["embed"], tokens,
                                scale=cfg.embed_scale).astype(dt)
        if cfg.is_encdec:
            # absolute sinusoid at the current decode position
            pe = layers.sinusoid_at(state.pos, cfg.d_model)
            x = x + pe.astype(dt)
        x = shard(x, "batch", "seq", None)
        x, new_stack = blocks.apply_stack_decode(
            params["stack"], cfg, plan_for(cfg), x, state.stack, state.pos,
            enc=state.enc)
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        head = params.get("lm_head", params["embed"])
        logits = layers.unembed(head, x[:, 0], softcap=cfg.logits_softcap)
        logits = shard(logits, "batch", "vocab")
        return logits, DecodeState(stack=new_stack, pos=state.pos + 1,
                                   enc=state.enc)

    return serve_step


# ------------------------------------------------------- sharding specs ----

_SPEC_BY_NAME_RANK = {
    # name -> {rank: logical axes}
    "table": {2: ("vocab", "table_embed")},
    "wq": {3: ("embed", "heads", None), 2: ("embed", "inner")},
    "wk": {3: ("embed", "kv_heads", None), 2: ("embed", "inner")},
    "wv": {3: ("embed", "kv_heads", None), 2: ("embed", "inner")},
    "wo": {3: ("heads", None, "embed")},
    "w_up": {2: ("embed", "mlp"), 3: ("experts", "embed", None)},
    "w_gate": {2: ("embed", "mlp"), 3: ("experts", "embed", None)},
    "w_down": {2: ("mlp", "embed"), 3: ("experts", None, "embed")},
    "router": {2: (None, None)},
    "in_proj": {2: ("embed", "inner")},
    "conv_w": {2: (None, "inner")},
    "conv_b": {1: ("inner",)},
    "x_proj": {2: ("inner", None)},
    "dt_proj": {2: (None, "inner")},
    "dt_bias": {1: ("inner",)},
    "a_log": {2: ("inner", None)},
    "d_skip": {1: ("inner",)},
    "out_proj": {2: ("inner", "embed")},
    "up": {2: ("embed", "inner")},
    "down": {2: ("inner", "embed")},
    "w_gates": {2: ("inner", None)},
    "w_i": {2: ("inner", None)},
    "w_f": {2: ("inner", None)},
    "b_i": {1: (None,)},
    "b_f": {1: (None,)},
    "b_gates": {1: (None,)},
}


def _path_names(path) -> list:
    names = []
    for p in path:
        if hasattr(p, "key"):          # DictKey
            names.append(str(p.key))
        elif hasattr(p, "name"):       # GetAttrKey (NamedTuple field)
            names.append(str(p.name))
    return names


def _leaf_logical_axes(path, leaf_shape) -> Tuple:
    names = _path_names(path)
    stacked = "super" in names
    base = names[-1] if names else None
    rank = len(leaf_shape) - (1 if stacked else 0)
    axes = _SPEC_BY_NAME_RANK.get(base, {}).get(rank)
    if axes is None:
        axes = (None,) * rank
    if stacked:
        axes = ("layers",) + axes
    return axes


def param_pspecs(cfg: ModelConfig, params_shape) -> Any:
    """PartitionSpec pytree for params (divisibility-aware, current mesh).

    Also correct for TrainState shapes: optimizer moments mirror the params
    subtree, so name-based lookup lands on the same entries."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        axes = _leaf_logical_axes(path, leaf.shape)
        out.append(pspec(*axes, shape=tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


train_state_pspecs = param_pspecs


def decode_state_pspecs(cfg: ModelConfig, state_shape) -> Any:
    """Specs for DecodeState: KV caches shard batch over data and kv-heads
    over model; recurrent states shard batch (and mamba inner dim)."""
    def _one(path, leaf):
        names = _path_names(path)
        stacked = "super" in names
        rank = len(leaf.shape) - (1 if stacked else 0)
        base = names[-1] if names else None
        if base in ("k", "v") and rank == 4:       # KV cache
            axes = ("batch", "kv_seq", "kv_heads", None)
        elif base == "conv" and rank == 3:         # mamba conv window
            axes = ("batch", None, "inner")
        elif base == "ssm" and rank == 3:          # mamba SSM state
            axes = ("batch", "inner", None)
        elif base == "enc" and rank == 3:          # cached encoder output
            axes = ("batch", "seq", None)
        elif rank >= 1 and base != "pos":          # lstm c/n/h/m etc.
            axes = ("batch",) + (None,) * (rank - 1)
        else:
            axes = (None,) * rank
        if stacked:
            axes = ("layers",) + axes
        return pspec(*axes, shape=tuple(leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [_one(p, l) for p, l in flat])
