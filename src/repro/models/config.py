"""Model configuration — a single dataclass covering all 10 assigned
architecture families (dense / moe / hybrid / ssm / vlm / audio).

A config fully determines the per-layer *layout*: an explicit list of
``BlockSpec`` entries (one per layer) describing the mixer (attention /
mamba / mlstm / slstm) and the feed-forward type (dense / moe / none).
``layout_period`` finds the smallest repeating unit so the runtime can
``jax.lax.scan`` over stacked super-blocks (critical to keep HLO size and
compile time sane at 40-96 layers).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01     # load-balance loss weight
    every: int = 1                    # MoE layer every k-th layer (jamba: 2)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8              # one sLSTM block per this many layers
    proj_factor: float = 2.0          # up-projection factor inside blocks
    chunk_size: int = 256             # chunkwise-parallel mLSTM chunk


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer's composition."""
    mixer: str                        # attn | attn_local | mamba | mlstm | slstm
    ff: str                           # dense | moe | none
    cross_attention: bool = False     # decoder layers of enc-dec models
    window: Optional[int] = None      # attn_local sliding-window width


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads
    activation: str = "swiglu"        # swiglu|gelu|squared_relu|geglu
    norm: str = "rmsnorm"             # rmsnorm|layernorm
    qk_norm: bool = False
    rope: str = "1d"                  # none|1d|2d(partial rotary)
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0           # fraction of head_dim rotated (2d: 0.5)
    window_size: int = 1024           # sliding-window width for attn_local
    local_global_ratio: Optional[Tuple[int, int]] = None  # e.g. (5,1) gemma3
    block_pattern: Optional[Tuple[str, ...]] = None  # e.g. ('attn',)+('mamba',)*7
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec (audio):
    encoder_layers: int = 0
    encoder_seq_cap: int = 1500       # whisper's native frame budget (noted)
    # vlm:
    num_patch_tokens: int = 0         # prepended patch-embedding tokens
    # long-context serving: when set, global/full attention layers run as
    # sliding-window (ring KV) with this width — Gemma-3-style windowed
    # global KV for the 500k decode shape (DESIGN.md §4).
    long_context_global_window: Optional[int] = None
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma-style sqrt(d) embedding scale
    logits_softcap: float = 0.0       # gemma-style tanh soft-capping
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # source citation (paper / model card) — required by the assignment:
    source: str = ""

    def __post_init__(self):
        if self.family not in ("dense", "moe", "hybrid", "ssm", "vlm", "audio"):
            raise ValueError(f"bad family {self.family}")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # ---- layout -------------------------------------------------------------

    def layout(self) -> List[BlockSpec]:
        """Explicit per-layer block layout for the decoder stack."""
        specs: List[BlockSpec] = []
        for i in range(self.num_layers):
            mixer = self._mixer_at(i)
            ff = self._ff_at(i, mixer)
            window = None
            if mixer == "attn_local":
                window = self.window_size
            elif mixer == "attn" and self.long_context_global_window:
                mixer = "attn_local"
                window = self.long_context_global_window
            specs.append(BlockSpec(mixer=mixer, ff=ff,
                                   cross_attention=self.is_encdec,
                                   window=window))
        return specs

    def encoder_layout(self) -> List[BlockSpec]:
        return [BlockSpec(mixer="attn", ff="dense")
                for _ in range(self.encoder_layers)]

    def _mixer_at(self, i: int) -> str:
        if self.block_pattern is not None:
            return self.block_pattern[i % len(self.block_pattern)]
        if self.local_global_ratio is not None:
            l, g = self.local_global_ratio
            return "attn_local" if (i % (l + g)) < l else "attn"
        return "attn"

    def _ff_at(self, i: int, mixer: str) -> str:
        if mixer in ("mlstm", "slstm"):
            return "none"             # xLSTM blocks have internal projections
        if self.moe is not None and (i % self.moe.every) == (self.moe.every - 1):
            return "moe"
        return "dense"


def layout_period(specs: Sequence[BlockSpec]) -> int:
    """Smallest p such that specs is (a prefix of) a p-periodic sequence."""
    n = len(specs)
    for p in range(1, n + 1):
        if all(specs[i] == specs[i % p] for i in range(n)):
            return p
    return n


def split_layout(specs: Sequence[BlockSpec]) -> Tuple[List[BlockSpec], int, List[BlockSpec]]:
    """Returns (period_specs, num_superblocks, remainder_specs).

    The stack is executed as ``scan(num_superblocks, period_specs)`` followed
    by the remainder layers (unrolled — always < period of extra layers).
    """
    n = len(specs)
    p = layout_period(specs)
    if p == n:                         # aperiodic — look for periodic prefix
        # try small periods over the longest prefix they cover
        best = (n, 1, [])              # (period, count, remainder)
        for cand in range(1, min(12, n) + 1):
            k = 0
            while (k + 1) * cand <= n and all(
                    specs[k * cand + j] == specs[j] for j in range(cand)):
                k += 1
            covered = k * cand
            if k >= 2 and covered > best[0] * best[1]:
                best = (cand, k, list(specs[covered:]))
        if best[1] >= 2:
            return list(specs[:best[0]]), best[1], best[2]
        return list(specs), 1, []
    n_super = n // p
    rem = list(specs[n_super * p:])
    return list(specs[:p]), n_super, rem
