"""Block assembly + the scan-over-superblocks layer stack.

A model's layer layout (config.layout()) is decomposed into
``(period_specs, n_super, remainder_specs)``.  Parameters for each position
in the period are stacked with a leading ``n_super`` axis, and the stack
executes as one ``jax.lax.scan`` whose body applies the whole period — this
keeps HLO size O(period) instead of O(num_layers) (essential at 40-96 layers
x 40 dry-run configs on a single-core compile budget).

Recurrent/KV state for decode is stacked the same way and threaded through
the scan as (xs -> ys).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mlp, moe, ssm, xlstm
from repro.models.config import BlockSpec, ModelConfig, split_layout
from repro.models.sharding import shard


# --------------------------------------------------------------- params ----

def init_block(key, cfg: ModelConfig, spec: BlockSpec, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"pre_norm": layers.init_norm(cfg.d_model, cfg.norm)}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = attention.init_attention(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm.init_mamba(ks[0], cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(ks[0], cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attention:
        p["cross_norm"] = layers.init_norm(cfg.d_model, cfg.norm)
        p["cross"] = attention.init_cross_attention(ks[1], cfg, dtype)
    if spec.ff == "dense":
        p["post_norm"] = layers.init_norm(cfg.d_model, cfg.norm)
        p["ff"] = mlp.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                               cfg.activation, dtype)
    elif spec.ff == "moe":
        p["post_norm"] = layers.init_norm(cfg.d_model, cfg.norm)
        p["ff"] = moe.init_moe(ks[2], cfg.d_model, cfg.moe,
                               cfg.activation, dtype)
    return p


# --------------------------------------------------------------- states ----

def init_block_state(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     cache_len: int, dtype) -> Optional[Any]:
    """Decode-time state for one layer (None for pure-FF encoder use)."""
    if spec.mixer == "attn":
        return attention.KVCache.zeros(batch, cache_len, cfg.num_kv_heads,
                                       cfg.head_dim_, dtype)
    if spec.mixer == "attn_local":
        w = spec.window or cfg.window_size
        return attention.KVCache.zeros(batch, min(w, cache_len),
                                       cfg.num_kv_heads, cfg.head_dim_, dtype)
    if spec.mixer == "mamba":
        return ssm.MambaState.zeros(batch, cfg, dtype)
    if spec.mixer == "mlstm":
        return xlstm.MLSTMState.zeros(batch, cfg)
    if spec.mixer == "slstm":
        return xlstm.SLSTMState.zeros(batch, cfg)
    raise ValueError(spec.mixer)


# --------------------------------------------------------------- apply -----

def apply_block(p, cfg: ModelConfig, spec: BlockSpec, x: jax.Array, *,
                enc: Optional[jax.Array] = None,
                mode: str = "causal") -> Tuple[jax.Array, jax.Array]:
    """Training/prefill application.  Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["pre_norm"], x, cfg.norm)
    if spec.mixer in ("attn", "attn_local"):
        attn_mode = ("bidir" if mode == "bidir" else
                     ("local" if spec.mixer == "attn_local" else "full"))
        y = attention.self_attention(p["mixer"], cfg, h, mode=attn_mode,
                                     window=spec.window)
    elif spec.mixer == "mamba":
        y = ssm.mamba_forward(p["mixer"], cfg, h)
    elif spec.mixer == "mlstm":
        y = xlstm.mlstm_forward(p["mixer"], cfg, h)
    elif spec.mixer == "slstm":
        y = xlstm.slstm_forward(p["mixer"], cfg, h)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    if spec.cross_attention and enc is not None:
        h = layers.apply_norm(p["cross_norm"], x, cfg.norm)
        x = x + attention.cross_attention(p["cross"], cfg, h, enc)
    if spec.ff == "dense":
        h = layers.apply_norm(p["post_norm"], x, cfg.norm)
        x = x + mlp.apply_mlp(p["ff"], h, cfg.activation)
    elif spec.ff == "moe":
        h = layers.apply_norm(p["post_norm"], x, cfg.norm)
        y, a = moe.apply_moe(p["ff"], h, cfg.moe, cfg.activation)
        x = x + y
        aux = aux + a
    return x, aux


def apply_block_decode(p, cfg: ModelConfig, spec: BlockSpec, x: jax.Array,
                       state, pos: jax.Array, *,
                       enc: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, Any]:
    """Single-token decode.  x: (B,1,D)."""
    h = layers.apply_norm(p["pre_norm"], x, cfg.norm)
    if spec.mixer in ("attn", "attn_local"):
        amode = "local" if spec.mixer == "attn_local" else "full"
        y, state = attention.decode_self_attention(p["mixer"], cfg, h, state,
                                                   pos, mode=amode)
    elif spec.mixer == "mamba":
        y, state = ssm.mamba_decode(p["mixer"], cfg, h, state)
    elif spec.mixer == "mlstm":
        y, state = xlstm.mlstm_decode(p["mixer"], cfg, h, state)
    elif spec.mixer == "slstm":
        y, state = xlstm.slstm_decode(p["mixer"], cfg, h, state)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    if spec.cross_attention and enc is not None:
        h = layers.apply_norm(p["cross_norm"], x, cfg.norm)
        x = x + attention.cross_attention(p["cross"], cfg, h, enc)
    if spec.ff == "dense":
        h = layers.apply_norm(p["post_norm"], x, cfg.norm)
        x = x + mlp.apply_mlp(p["ff"], h, cfg.activation)
    elif spec.ff == "moe":
        h = layers.apply_norm(p["post_norm"], x, cfg.norm)
        y, _ = moe.apply_moe(p["ff"], h, cfg.moe, cfg.activation)
        x = x + y
    return x, state


# ---------------------------------------------------------------- stack ----

@dataclasses.dataclass(frozen=True)
class StackPlan:
    period: Tuple[BlockSpec, ...]
    n_super: int
    remainder: Tuple[BlockSpec, ...]

    @staticmethod
    def from_layout(specs: List[BlockSpec]) -> "StackPlan":
        p, n, r = split_layout(specs)
        return StackPlan(tuple(p), n, tuple(r))


def init_stack(key, cfg: ModelConfig, plan: StackPlan, dtype) -> Dict:
    """Stacked parameters: {'super': {'p0': stacked, ...}, 'rem': {...}}."""
    out: Dict[str, Any] = {"super": {}, "rem": {}}
    for pi, spec in enumerate(plan.period):
        keys = jax.random.split(jax.random.fold_in(key, pi), plan.n_super)
        stacked = jax.vmap(
            lambda k, s=spec: init_block(k, cfg, s, dtype))(keys)
        out["super"][f"p{pi}"] = stacked
    for ri, spec in enumerate(plan.remainder):
        out["rem"][f"r{ri}"] = init_block(
            jax.random.fold_in(key, 10_000 + ri), cfg, spec, dtype)
    return out


def init_stack_state(cfg: ModelConfig, plan: StackPlan, batch: int,
                     cache_len: int, dtype) -> Dict:
    out: Dict[str, Any] = {"super": {}, "rem": {}}
    for pi, spec in enumerate(plan.period):
        one = init_block_state(cfg, spec, batch, cache_len, dtype)
        out["super"][f"p{pi}"] = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (plan.n_super,) + t.shape),
            one)
    for ri, spec in enumerate(plan.remainder):
        out["rem"][f"r{ri}"] = init_block_state(cfg, spec, batch,
                                                cache_len, dtype)
    return out


def _checkpoint_group(n_super: int) -> int:
    """Group size for sqrt-L checkpointing (0/1 = disabled).  Enabled for
    deep stacks; override with REPRO_CKPT_GROUP."""
    import os
    v = os.environ.get("REPRO_CKPT_GROUP")
    if v is not None:
        return int(v)
    # MEASURED NEGATIVE (EXPERIMENTS.md §Perf N5): on the CPU-XLA dry-run
    # the grouped recompute DOUBLED nemotron's footprint (58 -> 122 GB/dev)
    # because the hoisted bf16->f32 convert of the saved stack happens per
    # group on top of the recompute buffers.  Disabled by default; opt in
    # via REPRO_CKPT_GROUP for TPU-pipeline verification.
    return 1


def _unroll_for_analysis() -> bool:
    """When REPRO_UNROLL_SCAN=1, layer scans fully unroll so that
    cost_analysis / collective parsing count every layer (XLA's
    HloCostAnalysis counts while bodies once — see EXPERIMENTS.md
    §Roofline).  Analysis-only: never set for real training."""
    import os
    return os.environ.get("REPRO_UNROLL_SCAN", "0") == "1"


def apply_stack(params: Dict, cfg: ModelConfig, plan: StackPlan,
                x: jax.Array, *, enc: Optional[jax.Array] = None,
                mode: str = "causal", remat: bool = True
                ) -> Tuple[jax.Array, jax.Array]:
    """Forward through the full stack.  Returns (x, total_moe_aux)."""

    def superblock(carry, stacked_slice):
        h, aux = carry
        for pi, spec in enumerate(plan.period):
            h, a = apply_block(stacked_slice[f"p{pi}"], cfg, spec, h,
                               enc=enc, mode=mode)
            aux = aux + a
        # residual-stream layout hook: default replicated-over-(seq,hidden);
        # perf experiments reshard via set_rules(seq_act=..., residual=...)
        h = shard(h, "batch", "seq_act", "residual")
        return (h, aux), None

    body = jax.checkpoint(superblock) if remat else superblock
    aux0 = jnp.zeros((), jnp.float32)
    unroll = plan.n_super if _unroll_for_analysis() else 1
    # sqrt-L two-level checkpointing: for deep stacks, scan over G groups
    # (outer carries saved) each rescanning n_super/G super-blocks whose
    # carries are RECOMPUTED in the backward pass — saved-activation stack
    # shrinks from O(L) to O(G + L/G) at ~1 extra group forward
    # (EXPERIMENTS.md §Perf N5).
    group = _checkpoint_group(plan.n_super) if remat else 0
    if plan.n_super > 0 and group > 1 and plan.n_super % group == 0:
        n_groups = plan.n_super // group

        def group_body(carry, group_params):
            def inner(c, slice_):
                return superblock(c, slice_)
            out, _ = jax.lax.scan(inner, carry, group_params)
            return out, None

        grouped = jax.tree_util.tree_map(
            lambda t: t.reshape((n_groups, group) + t.shape[1:]),
            params["super"])
        (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body), (x, aux0),
                                   grouped)
    elif plan.n_super > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["super"],
                                   unroll=unroll)
    else:
        aux = aux0
    for ri, spec in enumerate(plan.remainder):
        x, a = apply_block(params["rem"][f"r{ri}"], cfg, spec, x,
                           enc=enc, mode=mode)
        aux = aux + a
    return x, aux


def apply_stack_decode(params: Dict, cfg: ModelConfig, plan: StackPlan,
                       x: jax.Array, state: Dict, pos: jax.Array, *,
                       enc: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, Dict]:
    def superblock(h, slices):
        param_slice, state_slice = slices
        new_states = {}
        for pi, spec in enumerate(plan.period):
            h, ns = apply_block_decode(param_slice[f"p{pi}"], cfg, spec, h,
                                       state_slice[f"p{pi}"], pos, enc=enc)
            new_states[f"p{pi}"] = ns
        return h, new_states

    if plan.n_super > 0:
        x, new_super = jax.lax.scan(superblock, x,
                                    (params["super"], state["super"]),
                                    unroll=(plan.n_super
                                            if _unroll_for_analysis() else 1))
    else:
        new_super = state["super"]
    new_rem = {}
    for ri, spec in enumerate(plan.remainder):
        x, ns = apply_block_decode(params["rem"][f"r{ri}"], cfg, spec, x,
                                   state["rem"][f"r{ri}"], pos, enc=enc)
        new_rem[f"r{ri}"] = ns
    return x, {"super": new_super, "rem": new_rem}
