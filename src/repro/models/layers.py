"""Basic layers: norms, dense projections, embeddings, rotary embeddings.

All ``init_*`` functions return plain dict pytrees; ``apply`` logic is free
functions so everything composes under jit/scan without framework magic.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- norms ----

def init_norm(d: int, norm: str, dtype=jnp.float32):
    if norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x: jax.Array, norm: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# --------------------------------------------------------------- dense -----

def init_dense(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)
    return {"kernel": w.astype(dtype)}


def apply_dense(p, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, p["kernel"].astype(x.dtype))


# ------------------------------------------------------------ embedding ----

def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * (1.0 / math.sqrt(d))
    return {"table": w.astype(dtype)}


def embed_tokens(p, tokens: jax.Array, *, scale: bool = False) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(p["table"].shape[1]), x.dtype)
    return x


def unembed(p, x: jax.Array, *, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        p["table"].astype(jnp.float32))
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# --------------------------------------------------------------- rotary ----

def rotary_angles(positions: jax.Array, rotary_dim: int, theta: float
                  ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions.  Shapes (..., rotary_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2,
                                        dtype=jnp.float32) / rotary_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array,
                 rotary_pct: float = 1.0) -> jax.Array:
    """Apply RoPE to the leading ``rotary_pct`` fraction of the head dim.

    ``x``: (..., seq, heads, head_dim); cos/sin: (..., seq, rot/2) broadcast.

    rotary_pct < 1 gives ChatGLM-style partial ("2d") rotary: only the first
    half of each head rotates, the rest passes through.
    """
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    xf = x_rot.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    c = cos[..., None, :]     # broadcast over heads axis
    s = sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    y = jnp.stack([y1, y2], axis=-1).reshape(xf.shape).astype(x.dtype)
    return jnp.concatenate([y, x_pass], axis=-1) if x_pass.shape[-1] else y


# ----------------------------------------------------- sinusoidal (abs) ----

def sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    """Single-position sinusoidal embedding (d,) for a traced position."""
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (seq, d), float32."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe
