from repro.models.config import (BlockSpec, MambaConfig, ModelConfig,
                                 MoEConfig, XLSTMConfig)
from repro.models.lm import (DecodeState, TrainState, abstract_params,
                             forward, init_decode_state, init_model,
                             init_train_state, loss_fn, make_serve_step,
                             make_train_step, param_pspecs,
                             decode_state_pspecs, train_state_pspecs)
