"""Mixture-of-Experts layer with top-k token-choice routing.

TPU-native dispatch (DESIGN.md §5): sort-based capacity dispatch —

  1. router logits -> top-k expert ids + normalised probs per token;
  2. position-in-expert via a stable sort over expert ids (O(T log T), no
     (T, E) one-hot materialisation);
  3. scatter into a static (E, capacity, d) buffer, einsum per-expert FFN,
     gather back and combine with routing probs.

Experts are sharded over the ``model`` mesh axis (expert parallelism); the
token->expert buffer transition is a resharding GSPMD lowers to an
all-to-all.  Load-balance auxiliary loss follows Switch/Shard designs
(mean(prob_per_expert * frac_tokens_per_expert) * E).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig, MoEConfig
from repro.models.sharding import shard


def init_moe(key, d_model: int, mcfg: MoEConfig, activation: str, dtype
             ) -> dict:
    ks = jax.random.split(key, 4)
    e, f = mcfg.num_experts, mcfg.d_ff_expert
    import math
    s_in = 1.0 / math.sqrt(d_model)
    s_ff = 1.0 / math.sqrt(f)
    p = {
        "router": layers.init_dense(ks[0], d_model, e, jnp.float32)["kernel"],
        "w_up": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, f, d_model), jnp.float32)
                   * s_ff).astype(dtype),
    }
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (e, d_model, f), jnp.float32)
                       * s_in).astype(dtype)
    return p


def _capacity(num_tokens: int, mcfg: MoEConfig) -> int:
    cap = int(num_tokens * mcfg.top_k * mcfg.capacity_factor
              / mcfg.num_experts)
    return max(8, (cap + 7) // 8 * 8)   # pad to 8 for TPU-friendly tiling


def route(p, x_flat: jax.Array, mcfg: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (expert_ids (T,k), probs (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs_full, mcfg.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    e = mcfg.num_experts
    me = jnp.mean(probs_full, axis=0)                          # (E,)
    ce = jnp.zeros(e).at[top_ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(jnp.sum(ce), 1.0)
    aux = jnp.sum(me * ce) * e
    return top_ids.astype(jnp.int32), top_p.astype(x_flat.dtype), aux


def _data_shards(t: int) -> int:
    """Number of token blocks for shard-local dispatch = size of the
    ('pod','data') mesh axes (1 off-mesh).  Blocked dispatch keeps routing,
    scatter, and combine LOCAL to each data shard (its own capacity slice),
    so GSPMD never all-reduces the dispatch buffer — see EXPERIMENTS.md
    §Perf Q1."""
    from repro.models.sharding import _mesh, _rules
    m = _mesh()
    if m is None:
        return 1
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    rule = _rules.get("batch", ("pod", "data"))
    cand = rule if isinstance(rule, tuple) else (rule,)
    n = 1
    for a in cand:
        n *= sizes.get(a, 1)
    # blocks need >= 256 tokens each: smaller blocks inflate the per-expert
    # capacity padding (min 8 slots/expert/block — measured +43% footprint
    # on jamba decode_32k) and the original single-buffer path wins.
    while n > 1 and (t % n != 0 or t // n < 256):
        n //= 2
    return max(n, 1)


def _positions_in_expert(flat_ids: jax.Array, e: int, cap: int):
    """Stable-sort ranking of assignments within their expert's run."""
    n = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.zeros(e, jnp.int32).at[flat_ids].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]
    pos = jnp.zeros(n, jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    return jnp.where(keep, pos, cap - 1), keep


def _ep_mesh_info(t: int, e: int):
    """(mesh, data_axes, n_blocks, model_size) when the explicit
    expert-parallel path is usable, else None."""
    from repro.models.sharding import _mesh
    m = _mesh()
    if m is None:
        return None
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    ms = sizes.get("model", 1)
    if ms <= 1 or e % ms != 0:
        return None
    data_axes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    nb = 1
    for a in data_axes:
        nb *= sizes[a]
    if t % max(nb, 1) != 0:
        return None
    # decode-size batches: the EP path's fixed shard_map overheads exceed
    # the win when each block routes only a handful of tokens (measured:
    # jamba decode_32k +40% footprint) — fall back to the GSPMD path.
    if t // max(nb, 1) < 64:
        return None
    return m, data_axes, max(nb, 1), ms


def apply_moe(p, x: jax.Array, mcfg: MoEConfig, activation: str
              ) -> Tuple[jax.Array, jax.Array]:
    """Dispatcher: explicit expert-parallel shard_map path on meshes with a
    'model' axis (EXPERIMENTS.md §Perf Q3 — dispatch is shard-local, the
    only cross-shard traffic is one (tl, d) psum at combine); blocked
    GSPMD path otherwise."""
    t = x.shape[0] * x.shape[1]
    info = _ep_mesh_info(t, mcfg.num_experts)
    if info is not None:
        return _apply_moe_ep(p, x, mcfg, activation, info)
    return _apply_moe_gspmd(p, x, mcfg, activation)


def _apply_moe_ep(p, x: jax.Array, mcfg: MoEConfig, activation: str, info
                  ) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P
    mesh, data_axes, nb, ms = info
    b, s, d = x.shape
    t = b * s
    dt = x.dtype
    k, e = mcfg.top_k, mcfg.num_experts
    e_local = e // ms
    tl = t // nb
    cap = _capacity(tl, mcfg)
    gated = activation in ("swiglu", "geglu")

    xf = x.reshape(t, d)
    ids, probs, aux = route(p, xf, mcfg)
    ids_b = ids.reshape(nb, tl * k)
    probs_b = probs.reshape(nb, tl * k).astype(jnp.float32)
    x_b = xf.reshape(nb, tl, d)
    token_idx = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)

    dspec = data_axes if len(data_axes) > 1 else (data_axes[0]
                                                  if data_axes else None)

    def body(x_blk, ids_blk, pr_blk, wu, wg, wd):
        x_blk, ids_blk, pr_blk = x_blk[0], ids_blk[0], pr_blk[0]
        mi = jax.lax.axis_index("model")
        lo = mi * e_local
        local = (ids_blk >= lo) & (ids_blk < lo + e_local)
        # rank only the LOCAL assignments (sentinel bucket for the rest)
        ids_loc = jnp.where(local, ids_blk - lo, e_local)
        pos, keep = _positions_in_expert(ids_loc, e_local + 1, cap)
        keep = keep & local
        ids_safe = jnp.where(local, ids_loc, 0)
        contrib = jnp.where(keep[:, None], x_blk[token_idx], 0.0)
        buf = jnp.zeros((e_local, cap, d), dt).at[ids_safe, pos].add(contrib)
        up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
        if gated:
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
            act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
            h = act * up
        else:
            h = (jax.nn.gelu(up) if activation == "gelu"
                 else jax.nn.relu(up) ** 2)
        out = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))
        rows = out[ids_safe, pos]
        rows = jnp.where(keep[:, None], rows, 0.0)
        y_part = jnp.zeros((tl, d), jnp.float32).at[token_idx].add(
            rows.astype(jnp.float32) * pr_blk[:, None])
        # the ONLY cross-shard traffic: combine partial sums over experts
        y = jax.lax.psum(y_part, "model").astype(dt)
        return y[None]

    wg_in = p.get("w_gate", p["w_up"])
    y_b = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dspec), P(dspec), P(dspec),
                  P("model"), P("model"), P("model")),
        out_specs=P(dspec),
        check_vma=False,
    )(x_b, ids_b, probs_b, p["w_up"], wg_in, p["w_down"])
    y = y_b.reshape(b, s, d)
    return shard(y, "batch", "seq", None), aux * mcfg.router_aux_loss


def _apply_moe_gspmd(p, x: jax.Array, mcfg: MoEConfig, activation: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss).

    Blocked (hierarchical) dispatch: tokens are viewed as (n_blocks,
    t_local) with n_blocks = data-shard count; each block routes into its
    OWN capacity slice of the (E, n_blocks, cap_local, D) buffer, which is
    sharded (experts->model, blocks->data).  Scatter and combine are then
    block-local; the only cross-device traffic is the expert-dim gather at
    combine time (bounded by assignment bytes), not a full-buffer
    all-reduce."""
    b, s, d = x.shape
    t = b * s
    dt = x.dtype
    xf = x.reshape(t, d)
    ids, probs, aux = route(p, xf, mcfg)
    k, e = mcfg.top_k, mcfg.num_experts
    nb = _data_shards(t)
    tl = t // nb
    cap = _capacity(tl, mcfg)

    ids_b = ids.reshape(nb, tl * k)
    xf_b = xf.reshape(nb, tl, d)
    probs_b = probs.reshape(nb, tl * k)
    token_idx = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)

    def _dispatch(ids_blk, x_blk):
        pos, keep = _positions_in_expert(ids_blk, e, cap)
        contrib = jnp.where(keep[:, None], x_blk[token_idx], 0.0)
        buf = jnp.zeros((e, cap, d), dt).at[ids_blk, pos].add(contrib)
        return buf, pos, keep

    buf, pos_b, keep_b = jax.vmap(_dispatch)(ids_b, xf_b)   # (nb,E,cap,d)
    buf = jnp.swapaxes(buf, 0, 1)                           # (E,nb,cap,d)
    buf = shard(buf, "experts", "batch", None, None)

    up = jnp.einsum("encd,edf->encf", buf, p["w_up"].astype(dt))
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("encd,edf->encf", buf, p["w_gate"].astype(dt))
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(up) if activation == "gelu" else jax.nn.relu(up) ** 2
    out_buf = jnp.einsum("encf,efd->encd", h, p["w_down"].astype(dt))
    out_buf = shard(out_buf, "experts", "batch", None, None)
    out_buf = jnp.swapaxes(out_buf, 0, 1)                   # (nb,E,cap,d)

    def _combine(out_blk, ids_blk, pos, keep, pr):
        gathered = out_blk[ids_blk, pos]                    # (tl*k, d)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weighted = gathered * pr[:, None]
        return jnp.zeros((tl, d), dt).at[token_idx].add(weighted)

    y = jax.vmap(_combine)(out_buf, ids_b, pos_b, keep_b, probs_b)
    y = y.reshape(b, s, d)
    return shard(y, "batch", "seq", None), aux * mcfg.router_aux_loss
