"""Feed-forward blocks: SwiGLU / GEGLU (gated), GELU, squared-ReLU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.sharding import shard

GATED = ("swiglu", "geglu")


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": layers.init_dense(ks[0], d_model, d_ff, dtype)["kernel"],
         "w_down": layers.init_dense(ks[1], d_ff, d_model, dtype)["kernel"]}
    if activation in GATED:
        p["w_gate"] = layers.init_dense(ks[2], d_model, d_ff, dtype)["kernel"]
    return p


def _act(activation: str, x: jax.Array) -> jax.Array:
    if activation in ("swiglu",):
        return jax.nn.silu(x)
    if activation in ("gelu", "geglu"):
        return jax.nn.gelu(x)
    if activation == "squared_relu":            # nemotron-4
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(activation)


def apply_mlp(p, x: jax.Array, activation: str) -> jax.Array:
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    up = shard(up, "batch", "seq", "mlp")
    if activation in GATED:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        gate = shard(gate, "batch", "seq", "mlp")
        h = _act(activation, gate) * up
    else:
        h = _act(activation, up)
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return shard(y, "batch", "seq", None)
