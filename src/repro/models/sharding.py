"""Logical-axis sharding rules.

Every parameter/activation carries *logical* axis names; a rules table maps
them to mesh axes.  The production mesh is ``(data=16, model=16)`` per pod,
with an optional leading ``pod`` axis (see launch/mesh.py).

Default rules (MaxText-style FSDP + TP):

  batch     -> ("pod", "data")     activations' batch dim
  embed     -> ("pod", "data")     parameter fan-in  (FSDP)
  heads     -> "model"             attention heads   (TP)
  mlp       -> "model"             FFN hidden        (TP)
  vocab     -> "model"             embedding/logits vocab dim
  experts   -> "model"             MoE expert-parallel
  kv_heads  -> "model"             (GQA: only when kv_heads >= mesh model dim)
  seq, layers, conv, state, ...    -> replicated

``shard(x, *logical_axes)`` applies a with_sharding_constraint when running
under a mesh context; it is the single choke-point the perf iterations tune.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# logical name -> mesh axes (None = replicate). Tuples mean "shard over the
# product of these mesh axes". Mutated only by perf experiments via
# set_rules().
_DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),
    # embedding-table fan-in: FSDP-sharded like other weights.  Replicating
    # it (tried in §Perf N1) was REFUTED twice: it did not fix nemotron's
    # blowup and it regressed every small-model train pair by replicating
    # the table's optimizer moments (observed +0.5..3.5 GB/dev).
    "table_embed": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "seq": None,
    "seq_act": None,           # residual-stream seq dim (perf experiments)
    "residual": None,          # residual-stream hidden dim (perf experiments)
    "kv_seq": None,
    "layers": None,
    "conv": None,
    "state": None,
    "capacity": None,
    "dconv": None,
    "inner": "model",          # mamba/xlstm inner (expanded) dim
    "head_out": None,
    None: None,
}

_rules = dict(_DEFAULT_RULES)


def set_rules(**overrides):
    """Override logical->mesh mappings (perf experiments)."""
    _rules.update(overrides)


def reset_rules():
    _rules.clear()
    _rules.update(_DEFAULT_RULES)


def _mesh() :
    m = jax.sharding.get_abstract_mesh()
    if m is None or not m.axis_names:
        return None
    return m


def _resolve(ax: Optional[str], dim: Optional[int], axis_sizes: dict):
    """Map one logical axis to mesh axes, honouring divisibility of ``dim``.

    Returns None / str / tuple-of-str suitable for a PartitionSpec entry.
    Mesh axes missing from the active mesh are dropped; if ``dim`` is given,
    axes whose (product) size does not divide it are dropped greedily.
    """
    m = _rules.get(ax, None)
    if m is None:
        return None
    cand = m if isinstance(m, tuple) else (m,)
    kept = []
    prod = 1
    for a in cand:
        sz = axis_sizes.get(a)
        if sz is None:
            continue
        if dim is not None and dim % (prod * sz) != 0:
            continue
        kept.append(a)
        prod *= sz
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec(*logical_axes: Optional[str], shape: Optional[Tuple[int, ...]] = None,
         mesh=None) -> P:
    """PartitionSpec for the given logical axes under the current (or given)
    mesh, dropping unavailable mesh axes and non-divisible dims."""
    m = mesh or _mesh()
    sizes = {}
    if m is not None:
        types = getattr(m, "axis_types", None) or ()
        for i, (name, size) in enumerate(zip(m.axis_names, m.axis_sizes)):
            # inside shard_map an axis is Manual — constraints must not
            # reference it (it is already fully mapped)
            t = types[i] if i < len(types) else None
            if t is not None and "Manual" in str(t):
                continue
            sizes[name] = size
    out = []
    used = set()
    for i, ax in enumerate(logical_axes):
        dim = shape[i] if shape is not None else None
        r = _resolve(ax, dim, sizes)
        # a mesh axis may appear at most once in a PartitionSpec: first wins
        if isinstance(r, tuple):
            r = tuple(a for a in r if a not in used)
            r = r if len(r) > 1 else (r[0] if r else None)
        if isinstance(r, str) and r in used:
            r = None
        for a in ((r,) if isinstance(r, str) else (r or ())):
            if isinstance(a, str):
                used.add(a)
        out.append(r)
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under an active mesh; identity otherwise."""
    if _mesh() is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): {len(logical_axes)} axes for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, spec(*logical_axes, shape=x.shape))


def named_sharding(mesh, *logical_axes,
                   shape: Optional[Tuple[int, ...]] = None
                   ) -> jax.sharding.NamedSharding:
    return jax.sharding.NamedSharding(
        mesh, spec(*logical_axes, shape=shape, mesh=mesh))
