"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM + sLSTM.

* mLSTM — matrix-memory LSTM with exponential gating.  Training/prefill uses
  the *chunkwise-parallel stabilised* form (official repo's
  ``parallel_stabilized`` generalised with an inter-chunk carry): an outer
  ``lax.scan`` over chunks carries stabilised ``(C, n, m)`` states; within a
  chunk the quadratic (Q x Q) masked-decay attention computes exact outputs.
  Decode is the exact single-step recurrence.

* sLSTM — scalar-memory LSTM with recurrent (per-head block-diagonal) hidden
  connections; inherently sequential, implemented as ``lax.scan`` over time
  (this is the architecture's stated trade-off, noted in DESIGN.md).

Both are wrapped in xLSTM's pre-norm up-projection block:
    x -> norm -> up(2*di) -> [core(x_half) * silu(gate_half)] -> down(d)
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.sharding import shard


def d_inner(cfg: ModelConfig) -> int:
    return int(cfg.xlstm.proj_factor * cfg.d_model)


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    h = cfg.num_heads
    di = d_inner(cfg)
    assert di % h == 0
    return h, di // h


# ------------------------------------------------------------- mLSTM -------

def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = d_inner(cfg)
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 8)

    def _blockdiag(k):
        # official xLSTM uses per-head block-diagonal q/k/v projections
        return (jax.random.normal(k, (h, hd, hd), jnp.float32)
                / math.sqrt(hd)).astype(dtype)

    return {
        "up": layers.init_dense(ks[0], d, 2 * di, dtype)["kernel"],
        "wq": _blockdiag(ks[1]),
        "wk": _blockdiag(ks[2]),
        "wv": _blockdiag(ks[3]),
        "w_i": layers.init_dense(ks[4], di, h, jnp.float32)["kernel"],
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": layers.init_dense(ks[5], di, h, jnp.float32)["kernel"],
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # open forget gates at init
        "out_norm": layers.init_norm(di, "rmsnorm"),
        "down": layers.init_dense(ks[6], di, d, dtype)["kernel"],
    }


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd, hd) fp32, stabilised by exp(-m)
    n: jax.Array   # (B, H, hd)
    m: jax.Array   # (B, H)

    @staticmethod
    def zeros(b: int, cfg: ModelConfig) -> "MLSTMState":
        h, hd = _heads(cfg)
        return MLSTMState(c=jnp.zeros((b, h, hd, hd), jnp.float32),
                          n=jnp.zeros((b, h, hd), jnp.float32),
                          m=jnp.full((b, h), -1e30, jnp.float32))


def _qkv_gates(p, cfg, xin):
    """xin: (B,S,di) -> q,k,v (B,S,H,hd); log_i, log_f (B,S,H) fp32.

    q/k/v are per-head block-diagonal (official xLSTM)."""
    b, s, di = xin.shape
    h, hd = _heads(cfg)
    dt = xin.dtype
    xh = xin.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"].astype(dt))
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"].astype(dt))
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"].astype(dt))
    xf = xin.astype(jnp.float32)
    log_i = jnp.einsum("bsd,dh->bsh", xf, p["w_i"]) + p["b_i"]
    f_raw = jnp.einsum("bsd,dh->bsh", xf, p["w_f"]) + p["b_f"]
    log_f = -jax.nn.softplus(-f_raw)      # log sigmoid
    q = q / math.sqrt(hd)
    return q, k, v, log_i, log_f


def _mlstm_chunk(state: MLSTMState, q, k, v, log_i, log_f):
    """Exact stabilised chunk step.

    q,k,v: (B,Q,H,hd); log_i/log_f: (B,Q,H).  Returns (state', h (B,Q,H,hd)).
    """
    bsz, qlen, h, hd = q.shape
    c_st, n_st, m_st = state
    bq = jnp.cumsum(log_f, axis=1)                       # (B,Q,H) inclusive
    # local stabiliser: m_loc[q] = b_q + cummax_j<=q (log_i_j - b_j)
    a = log_i - bq
    cmax = jax.lax.cummax(a, axis=1)
    m_loc = bq + cmax
    m_new = jnp.maximum(m_loc, m_st[:, None, :] + bq)    # (B,Q,H)

    # intra-chunk decay matrix: logD[q,j] = b_q - b_j + log_i_j  (j <= q)
    logd = (bq[:, :, None, :] - bq[:, None, :, :]
            + log_i[:, None, :, :])                      # (B,Q,J,H)
    mask = (jnp.arange(qlen)[:, None] >= jnp.arange(qlen)[None, :])
    logd = jnp.where(mask[None, :, :, None], logd, -jnp.inf)
    w = jnp.exp(logd - m_new[:, :, None, :])             # (B,Q,J,H)

    qk = jnp.einsum("bqhd,bjhd->bqjh", q.astype(jnp.float32),
                    k.astype(jnp.float32))               # (B,Q,J,H)
    s_mat = qk * w
    num_intra = jnp.einsum("bqjh,bjhd->bqhd", s_mat, v.astype(jnp.float32))
    den_intra = jnp.sum(s_mat, axis=2)                   # (B,Q,H)

    scale_inter = jnp.exp(m_st[:, None, :] + bq - m_new) # (B,Q,H)
    num_inter = jnp.einsum("bqhd,bhde->bqhe", q.astype(jnp.float32), c_st)
    num_inter = num_inter * scale_inter[..., None]
    den_inter = jnp.einsum("bqhd,bhd->bqh",
                           q.astype(jnp.float32), n_st) * scale_inter

    num = num_intra + num_inter
    den = den_intra + den_inter
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h_out = num / denom                                  # (B,Q,H,hd)

    # carry update (decay everything to end-of-chunk, stabilise by m')
    b_tot = bq[:, -1, :]                                 # (B,H)
    m_next = jnp.maximum(m_st + b_tot, b_tot + cmax[:, -1, :])
    kv_w = jnp.exp(b_tot[:, None, :] - bq + log_i
                   - m_next[:, None, :])                 # (B,Q,H)
    c_new = (c_st * jnp.exp(m_st + b_tot - m_next)[..., None, None]
             + jnp.einsum("bqh,bqhd,bqhe->bhde", kv_w,
                          k.astype(jnp.float32), v.astype(jnp.float32)))
    n_new = (n_st * jnp.exp(m_st + b_tot - m_next)[..., None]
             + jnp.einsum("bqh,bqhd->bhd", kv_w, k.astype(jnp.float32)))
    return MLSTMState(c_new, n_new, m_next), h_out


def mlstm_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    di = d_inner(cfg)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    up = shard(up, "batch", "seq", "inner")
    xin, z = up[..., :di], up[..., di:]
    q, k, v, log_i, log_f = _qkv_gates(p, cfg, xin)

    qc = max(1, min(cfg.xlstm.chunk_size, s))
    n_chunks = (s + qc - 1) // qc
    pad = n_chunks * qc - s

    def _p(t):   # pad seq axis then split chunks to leading axis
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return jnp.moveaxis(
            t.reshape((b, n_chunks, qc) + t.shape[2:]), 1, 0)

    def step(st, inp):
        st2, h = _mlstm_chunk(st, *inp)
        return st2, h

    _, hs = jax.lax.scan(step, MLSTMState.zeros(b, cfg),
                         tuple(_p(t) for t in (q, k, v, log_i, log_f)))
    hcat = jnp.moveaxis(hs, 0, 1).reshape(b, n_chunks * qc, di)[:, :s]
    hcat = layers.apply_norm(p["out_norm"], hcat.astype(dt), "rmsnorm")
    y = hcat * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down"].astype(dt))
    return shard(out, "batch", "seq", None)


def mlstm_decode(p, cfg: ModelConfig, x: jax.Array, state: MLSTMState
                 ) -> Tuple[jax.Array, MLSTMState]:
    """x: (B,1,D)."""
    b = x.shape[0]
    di = d_inner(cfg)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    xin, z = up[..., :di], up[..., di:]
    q, k, v, log_i, log_f = _qkv_gates(p, cfg, xin)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                  # (B,H,hd)
    log_i, log_f = log_i[:, 0], log_f[:, 0]              # (B,H)
    c_st, n_st, m_st = state
    m_new = jnp.maximum(log_f + m_st, log_i)
    fs = jnp.exp(log_f + m_st - m_new)
    is_ = jnp.exp(log_i - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    c_new = fs[..., None, None] * c_st + is_[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = fs[..., None] * n_st + is_[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    hflat = h.reshape(b, 1, di).astype(dt)
    hflat = layers.apply_norm(p["out_norm"], hflat, "rmsnorm")
    y = hflat * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down"].astype(dt))
    return out, MLSTMState(c_new, n_new, m_new)


# ------------------------------------------------------------- sLSTM -------

def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = d_inner(cfg)
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 8)
    def _r(k):   # per-head recurrent block-diagonal
        return (jax.random.normal(k, (h, hd, hd), jnp.float32)
                / math.sqrt(hd)).astype(jnp.float32)
    return {
        "up": layers.init_dense(ks[0], d, 2 * di, dtype)["kernel"],
        "w_gates": layers.init_dense(ks[1], di, 4 * di, dtype)["kernel"],
        "r_z": _r(ks[2]), "r_i": _r(ks[3]),
        "r_f": _r(ks[4]), "r_o": _r(ks[5]),
        "b_gates": jnp.concatenate([
            jnp.zeros((2 * di,)), jnp.full((di,), 3.0), jnp.zeros((di,))]
        ).astype(jnp.float32),
        "out_norm": layers.init_norm(di, "rmsnorm"),
        "down": layers.init_dense(ks[6], di, d, dtype)["kernel"],
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd)
    n: jax.Array
    h: jax.Array
    m: jax.Array   # (B, H, hd)

    @staticmethod
    def zeros(b: int, cfg: ModelConfig) -> "SLSTMState":
        hh, hd = _heads(cfg)
        z = jnp.zeros((b, hh, hd), jnp.float32)
        return SLSTMState(c=z, n=z + 1e-6, h=z, m=z - 1e30)


def _slstm_step(p, cfg, st: SLSTMState, wx: jax.Array
                ) -> Tuple[SLSTMState, jax.Array]:
    """wx: (B, 4*di) precomputed input contribution (fp32)."""
    h, hd = _heads(cfg)
    b = wx.shape[0]
    di = h * hd
    hprev = st.h                                          # (B,H,hd)
    def _rec(r):  # (B,H,hd) x (H,hd,hd) -> (B,H,hd)
        return jnp.einsum("bhd,hde->bhe", hprev, r)
    wz, wi, wf, wo = [wx[:, i * di:(i + 1) * di].reshape(b, h, hd)
                      for i in range(4)]
    z = jnp.tanh(wz + _rec(p["r_z"]))
    log_i = wi + _rec(p["r_i"])
    log_f = -jax.nn.softplus(-(wf + _rec(p["r_f"])))      # log sigmoid
    o = jax.nn.sigmoid(wo + _rec(p["r_o"]))
    m_new = jnp.maximum(log_f + st.m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + st.m - m_new)
    c = f_s * st.c + i_s * z
    n = f_s * st.n + i_s
    h_out = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h_out, m_new), h_out


def slstm_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    di = d_inner(cfg)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    up = shard(up, "batch", "seq", "inner")
    xin, zgate = up[..., :di], up[..., di:]
    wx = (jnp.einsum("bse,ef->bsf", xin, p["w_gates"].astype(dt))
          .astype(jnp.float32) + p["b_gates"])

    def step(st, wx_t):
        return _slstm_step(p, cfg, st, wx_t)

    _, hs = jax.lax.scan(step, SLSTMState.zeros(b, cfg),
                         jnp.moveaxis(wx, 1, 0))
    hcat = jnp.moveaxis(hs, 0, 1).reshape(b, s, di).astype(dt)
    hcat = layers.apply_norm(p["out_norm"], hcat, "rmsnorm")
    y = hcat * jax.nn.silu(zgate)
    out = jnp.einsum("bse,ed->bsd", y, p["down"].astype(dt))
    return shard(out, "batch", "seq", None)


def slstm_decode(p, cfg: ModelConfig, x: jax.Array, state: SLSTMState
                 ) -> Tuple[jax.Array, SLSTMState]:
    b = x.shape[0]
    di = d_inner(cfg)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    xin, zgate = up[..., :di], up[..., di:]
    wx = (jnp.einsum("bse,ef->bsf", xin, p["w_gates"].astype(dt))
          .astype(jnp.float32)[:, 0] + p["b_gates"])
    st, h = _slstm_step(p, cfg, state, wx)
    hcat = h.reshape(b, 1, di).astype(dt)
    hcat = layers.apply_norm(p["out_norm"], hcat, "rmsnorm")
    y = hcat * jax.nn.silu(zgate)
    out = jnp.einsum("bse,ed->bsd", y, p["down"].astype(dt))
    return out, st
