"""Dropout-rate allocation — the server-side module of FedDD (paper §4.1).

Solves the convex program Eq. (16)/(17):

    min_{D, t_srv}   t_srv + delta * sum_n re_n * D_n
    s.t.             0 <= D_n <= D_max
                     sum_n U_n (1 - D_n) = A_server * sum_n U_n
                     t_n_cmp + U_n (1 - D_n) * (1/r_u + 1/r_d) <= t_srv

This is a linear program.  We exploit its structure instead of calling an
external solver (none is available offline, and the paper only requires "a
convex solver"):

* For a FIXED ``t_srv`` the straggler constraints become per-client lower
  bounds  ``D_n >= l_n(t_srv) = 1 - (t_srv - t_cmp_n) / k_n``  with
  ``k_n = U_n (1/r_u + 1/r_d)``.
* Minimizing the linear penalty  ``sum_n c_n D_n``  (``c_n = delta*re_n``)
  subject to box bounds and the single equality  ``sum_n U_n D_n = B``  is a
  fractional knapsack: start from the lower bounds, then raise ``D`` for the
  clients with the smallest marginal cost ``c_n / U_n`` until the budget is
  met.  This inner solution is exact.
* The inner optimum is a convex piecewise-linear function of ``t_srv``; a
  golden-section search over the (bounded) interval of feasible ``t_srv``
  values finds the global optimum to tolerance.

Both a numpy reference (`solve_dropout_rates`) and a fully vectorised,
jit-able JAX implementation (`solve_dropout_rates_jax`) are provided.  The
JAX version is what the pod-scale federated driver uses so the allocation can
live inside a jitted server step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ClientTelemetry:
    """Per-client state the server needs to run the allocation LP.

    All arrays have shape ``(N,)`` for N clients.
    """

    model_bytes: np.ndarray        # U_n   — size of client n's local model
    uplink_rate: np.ndarray        # r_n^u — bytes / s
    downlink_rate: np.ndarray      # r_n^d — bytes / s
    compute_latency: np.ndarray    # t_n^cmp — seconds (c_n * b_n / f_n)
    num_samples: np.ndarray        # m_n
    label_coverage: np.ndarray     # sum_c min(C * dis_n^c, 1)   (Eq. 13 term)
    train_loss: np.ndarray         # loss_n^t

    def __post_init__(self):
        n = len(self.model_bytes)
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if len(arr) != n:
                raise ValueError(
                    f"telemetry field {f.name} has length {len(arr)} != {n}")

    @property
    def num_clients(self) -> int:
        return len(self.model_bytes)

    def subset(self, indices) -> "ClientTelemetry":
        """Telemetry restricted to a client subset (boolean mask or index
        array) — survivor-only LP re-solves when churn thins the fleet
        below quorum (sim/faults.py)."""
        idx = np.asarray(indices)
        if idx.dtype == bool:
            idx = np.flatnonzero(idx)
        return ClientTelemetry(**{
            f.name: np.asarray(getattr(self, f.name))[idx]
            for f in dataclasses.fields(self)
        })


def regularizer(tel: ClientTelemetry, global_model_bytes: float) -> np.ndarray:
    """``re_n`` of Eq. (13): (m_n/m) * coverage * (U_n/U) * loss_n.

    Larger ``re_n``  ==> the client is more valuable  ==> it is costlier to
    drop its parameters  ==> it receives a LOWER dropout rate.
    """
    m = float(np.sum(tel.num_samples))
    return (
        (tel.num_samples / m)
        * tel.label_coverage
        * (tel.model_bytes / float(global_model_bytes))
        * tel.train_loss
    )


@dataclasses.dataclass(frozen=True)
class AllocationResult:
    dropout_rates: np.ndarray   # D_n in [0, D_max]
    t_server: float             # optimal round time (straggler makespan)
    objective: float            # t_server + delta * sum re_n D_n
    feasible: bool


def _inner_knapsack(
    lower: np.ndarray,
    upper: np.ndarray,
    weights: np.ndarray,   # U_n  (budget is in units of sum U_n D_n)
    costs: np.ndarray,     # c_n = delta * re_n  (cost per unit of D_n)
    budget: float,         # required sum_n U_n D_n
) -> Tuple[Optional[np.ndarray], float]:
    """Exactly minimise sum c_n D_n  s.t.  lower<=D<=upper, sum U_n D_n = budget.

    Returns (D, cost) or (None, inf) when infeasible.
    """
    lo_mass = float(np.dot(weights, lower))
    hi_mass = float(np.dot(weights, upper))
    if budget < lo_mass - 1e-9 or budget > hi_mass + 1e-9:
        return None, float("inf")
    d = lower.astype(np.float64).copy()
    remaining = budget - lo_mass
    if remaining <= 1e-12:
        return d, float(np.dot(costs, d))
    # marginal cost of one unit of U*D mass for client n is costs_n/weights_n
    order = np.argsort(costs / np.maximum(weights, 1e-30))
    for i in order:
        cap = (upper[i] - d[i]) * weights[i]
        take = min(cap, remaining)
        if take > 0:
            d[i] += take / weights[i]
            remaining -= take
        if remaining <= 1e-12:
            break
    if remaining > 1e-6 * max(budget, 1.0):
        return None, float("inf")
    return d, float(np.dot(costs, d))


def solve_dropout_rates(
    tel: ClientTelemetry,
    *,
    a_server: float,
    d_max: float,
    delta: float,
    global_model_bytes: Optional[float] = None,
    tol: float = 1e-7,
) -> AllocationResult:
    """Exact numpy solver for the Eq. (16)/(17) LP.

    Args:
      a_server: fraction of total parameter mass the server requires
        (``A_server``); the equality budget is ``(1-a_server) * sum U_n`` of
        *dropped* mass.
      d_max: per-client max dropout rate (``D_max``).
      delta: penalty factor balancing system vs data/model heterogeneity.
    """
    if not 0.0 <= a_server <= 1.0:
        raise ValueError(f"a_server must be in [0,1], got {a_server}")
    if not 0.0 <= d_max <= 1.0:
        raise ValueError(f"d_max must be in [0,1], got {d_max}")
    u = tel.model_bytes.astype(np.float64)
    n = tel.num_clients
    gmb = float(global_model_bytes if global_model_bytes is not None
                else np.max(u))
    re = regularizer(tel, gmb)
    costs = delta * re
    k = u * (1.0 / tel.uplink_rate + 1.0 / tel.downlink_rate)  # secs at D=0
    tc = tel.compute_latency.astype(np.float64)

    total_u = float(np.sum(u))
    budget = (1.0 - a_server) * total_u  # required dropped mass sum U_n D_n

    zeros = np.zeros(n)
    upper = np.full(n, d_max)

    # Feasible interval of t_srv: at t_lo every client must drop D_max (the
    # tightest makespan possible); t_hi is the makespan when nothing is
    # dropped (any larger t_srv leaves the constraint slack everywhere).
    t_lo = float(np.max(tc + k * (1.0 - d_max)))
    t_hi = float(np.max(tc + k))

    def inner(t_srv: float) -> Tuple[Optional[np.ndarray], float]:
        # straggler constraint lower bound on D_n
        with np.errstate(divide="ignore", invalid="ignore"):
            l = 1.0 - (t_srv - tc) / np.maximum(k, 1e-30)
        l = np.clip(l, 0.0, None)
        if np.any(l > d_max + 1e-12):
            return None, float("inf")
        l = np.minimum(l, d_max)
        d, cost = _inner_knapsack(l, upper, u, costs, budget)
        if d is None:
            return None, float("inf")
        return d, t_srv + cost

    # Budget feasibility is independent of t_srv at t_hi; check once.
    d0, f_hi = inner(t_hi)
    if d0 is None:
        return AllocationResult(np.clip(np.full(n, 1 - a_server), 0, d_max),
                                t_hi, float("inf"), False)

    # Golden-section search on the convex piecewise-linear objective.
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = t_lo, t_hi
    # handle infeasible low end: shrink up to feasibility first via bisection
    _, f_a = inner(a)
    if not np.isfinite(f_a):
        lo, hi = a, b
        for _ in range(100):
            mid = 0.5 * (lo + hi)
            _, fm = inner(mid)
            if np.isfinite(fm):
                hi = mid
            else:
                lo = mid
        a = hi
    c = b - gr * (b - a)
    d_pt = a + gr * (b - a)
    _, fc = inner(c)
    _, fd = inner(d_pt)
    it = 0
    while (b - a) > tol * max(1.0, abs(b)) and it < 200:
        if fc <= fd:
            b, d_pt, fd = d_pt, c, fc
            c = b - gr * (b - a)
            _, fc = inner(c)
        else:
            a, c, fc = c, d_pt, fd
            d_pt = a + gr * (b - a)
            _, fd = inner(d_pt)
        it += 1
    t_star = 0.5 * (a + b)
    d_star, f_star = inner(t_star)
    if d_star is None:   # numerical edge: fall back to safe end
        d_star, f_star = d0, f_hi
        t_star = t_hi
    # The true makespan may be below t_star if constraints are slack.
    makespan = float(np.max(tc + k * (1.0 - d_star)))
    obj = makespan + float(np.dot(costs, d_star))
    return AllocationResult(d_star, makespan, obj, True)


# ---------------------------------------------------------------------------
# JAX implementation (vectorised, jit-able — used inside the pod-scale
# federated server step).
# ---------------------------------------------------------------------------

def _inner_knapsack_jax(lower, upper, weights, costs, budget):
    """Vectorised fractional knapsack.  Shapes (N,) throughout.

    Returns (d, cost, feasible).
    """
    lo_mass = jnp.dot(weights, lower)
    hi_mass = jnp.dot(weights, upper)
    feasible = (budget >= lo_mass - 1e-9) & (budget <= hi_mass + 1e-9)
    remaining = jnp.maximum(budget - lo_mass, 0.0)
    marg = costs / jnp.maximum(weights, 1e-30)
    order = jnp.argsort(marg)
    caps = ((upper - lower) * weights)[order]           # mass capacity, sorted
    csum = jnp.cumsum(caps)
    prev = csum - caps
    take_sorted = jnp.clip(remaining - prev, 0.0, caps)  # greedy fill
    take = jnp.zeros_like(take_sorted).at[order].set(take_sorted)
    d = lower + take / jnp.maximum(weights, 1e-30)
    cost = jnp.dot(costs, d)
    return d, cost, feasible


@functools.partial(jax.jit, static_argnames=("a_server", "d_max", "delta",
                                             "global_model_bytes",
                                             "num_iters"))
def solve_dropout_rates_jax(
    model_bytes: jax.Array,
    uplink_rate: jax.Array,
    downlink_rate: jax.Array,
    compute_latency: jax.Array,
    num_samples: jax.Array,
    label_coverage: jax.Array,
    train_loss: jax.Array,
    *,
    a_server: float,
    d_max: float,
    delta: float,
    global_model_bytes: Optional[float] = None,
    num_iters: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """JAX golden-section solver; returns (dropout_rates, t_server).

    Mirrors :func:`solve_dropout_rates`; differentiable in the telemetry is
    NOT required (allocation is a control decision), but everything is
    traceable so it can sit inside a jitted server step — the multi-round
    scanned engine (``round_engine.BatchedRoundEngine.run``) inlines it
    into the per-round ``lax.scan`` body.

    Bitwise stability: the solver is fenced with
    ``lax.optimization_barrier`` at entry, at exit, and around the
    derived search coefficients, and the function itself is jitted
    (protocol constants static — exactly the constants a ``lax.scan``
    round body bakes in).  XLA only guarantees identical bits for
    identical fusion contexts; the barriers pin the solver's subgraph so
    the per-round host dispatch and the scan-inlined call return the SAME
    dropout bits — the scanned-vs-sequential contract
    (tests/test_round_engine.py) relies on this.  Without them, an fma
    formed across the call boundary (e.g. fusing ``t_hi = max(tc + k)``
    with the surrounding round body) perturbs the golden-section bracket
    by one ulp, which the search then amplifies.
    """
    (model_bytes, uplink_rate, downlink_rate, compute_latency,
     num_samples, label_coverage, train_loss) = jax.lax.optimization_barrier(
        (model_bytes, uplink_rate, downlink_rate, compute_latency,
         num_samples, label_coverage, train_loss))
    u = model_bytes.astype(jnp.float32)
    gmb = jnp.max(u) if global_model_bytes is None else global_model_bytes
    m = jnp.sum(num_samples)
    re = (num_samples / m) * label_coverage * (u / gmb) * train_loss
    costs = delta * re
    k = u * (1.0 / uplink_rate + 1.0 / downlink_rate)
    tc = compute_latency.astype(jnp.float32)
    total_u = jnp.sum(u)
    budget = (1.0 - a_server) * total_u
    # Fence the derived coefficients before the golden-section search.
    # The search amplifies last-bit differences (a flipped fc<fd probe
    # moves the bracket), and without the barrier XLA may fold/fuse these
    # chains differently depending on the SURROUNDING graph — e.g. an fma
    # for tc + k inside a lax.scan round body vs separate mul/add when
    # called standalone.  With opaque inputs the downstream search graph
    # is structurally identical in every context, so the solver returns
    # the same bits whether dispatched per round or inlined in the
    # multi-round scan (the scanned-vs-sequential contract relies on it).
    u, costs, k, tc, budget = jax.lax.optimization_barrier(
        (u, costs, k, tc, budget))
    upper = jnp.full_like(u, d_max)
    big = jnp.asarray(1e30, jnp.float32)

    def inner_obj(t_srv):
        l = jnp.clip(1.0 - (t_srv - tc) / jnp.maximum(k, 1e-30), 0.0, None)
        bad = jnp.any(l > d_max + 1e-12)
        l = jnp.minimum(l, d_max)
        d, cost, feas = _inner_knapsack_jax(l, upper, u, costs, budget)
        obj = jnp.where(bad | ~feas, big, t_srv + cost)
        return obj, d

    t_lo = jnp.max(tc + k * (1.0 - d_max))
    t_hi = jnp.max(tc + k)

    gr = (jnp.sqrt(5.0) - 1.0) / 2.0

    def body(_, st):
        a, b = st
        c = b - gr * (b - a)
        dd = a + gr * (b - a)
        fc, _ = inner_obj(c)
        fd, _ = inner_obj(dd)
        # strict '<' so that when both probes are infeasible (equal big
        # sentinels, which happens only at the LOW end of the interval) the
        # interval shrinks from the left, moving toward feasibility.
        a2 = jnp.where(fc < fd, a, c)
        b2 = jnp.where(fc < fd, dd, b)
        return (a2, b2)

    a, b = jax.lax.fori_loop(0, num_iters, body, (t_lo, t_hi))
    t_star = 0.5 * (a + b)
    _, d_star = inner_obj(t_star)
    makespan = jnp.max(tc + k * (1.0 - d_star))
    return jax.lax.optimization_barrier((d_star, makespan))


ALLOCATORS = ("numpy", "jax")


def solve_dropout_rates_overhead_aware(
    tel: ClientTelemetry,
    wire_specs,
    *,
    comm,
    a_server: float,
    d_max: float,
    delta: float,
    global_model_bytes: Optional[float] = None,
    num_refinements: int = 4,
) -> AllocationResult:
    """Eq. (16)/(17) on EFFECTIVE on-wire bytes instead of the linear proxy.

    The LP treats client n's upload as ``U_n (1 - D_n)`` — linear in the
    dropout rate.  On a real wire the upload is
    ``B_n(D) = values(D) * qbits/32 + mask_overhead(D)`` (repro.comm
    .payload.analytic_wire_bytes): NONLINEAR in D, because the mask
    encoding has a floor (headers, the bitmask's density-independent
    ceil(C/8)) and the index codec's cost tracks the kept count.  Dropping
    harder therefore saves fewer bytes per unit of D than the proxy
    claims, and the LP overspends its budget on the wire.

    This solver keeps the exact knapsack/golden-section machinery but
    linearises around the current solution: each refinement replaces the
    per-client byte weight with the effective bytes-per-kept-fraction
    ``U_eff,n = B_n(D_n) / (1 - D_n)`` and rescales ``a_server`` so the
    budget equality binds on actual wire bytes,
    ``sum_n B_n(D_n) = A_server * sum_n B_n(0)``.  The overhead is mildly
    nonlinear, so a handful of refinements converge (tests pin the
    on-wire budget).  Host-side numpy only — it cannot ride the
    multi-round ``lax.scan`` (``ProtocolConfig`` enforces
    ``allocator="numpy"``).

    Args:
      wire_specs: one ``repro.comm.payload.WireSpec`` per client.
      comm: the ``repro.comm.payload.CommConfig`` whose byte model to use.
    """
    from repro.comm.payload import analytic_uplink_vector  # comm <- core

    n = tel.num_clients
    kw = dict(a_server=a_server, d_max=d_max, delta=delta,
              global_model_bytes=global_model_bytes)
    result = solve_dropout_rates(tel, **kw)
    wire_full = analytic_uplink_vector(wire_specs, np.zeros(n), comm)
    total_full = float(np.sum(wire_full))
    u_raw = tel.model_bytes.astype(np.float64)
    for _ in range(num_refinements):
        d = np.clip(result.dropout_rates, 0.0, d_max)
        keep = np.maximum(1.0 - d, 1e-6)
        u_eff = analytic_uplink_vector(wire_specs, d, comm) / keep
        # budget equality on wire bytes: sum u_eff (1-D) = a_server *
        # sum B(0)  ==>  rescale a_server into u_eff units
        a_eff = float(np.clip(a_server * total_full / max(
            float(np.sum(u_eff)), 1e-30), 0.0, 1.0))
        # u_eff must change ONLY the uplink mass the budget and the
        # straggler uplink leg see.  The inner solver derives everything
        # from model_bytes, so compensate the two places it would leak:
        # the Eq. (13) regularizer's (U_n/U) term (fold the inverse ratio
        # into train_loss — re_n is linear in both) and the downlink leg
        # of k_n (scale downlink_rate by the same ratio so
        # u_eff/r_d_eff == U_raw/r_d; the broadcast stays idealized).
        ratio = u_eff / np.maximum(u_raw, 1e-30)
        tel_eff = dataclasses.replace(
            tel, model_bytes=np.asarray(u_eff, np.float64),
            train_loss=tel.train_loss / np.maximum(ratio, 1e-30),
            downlink_rate=tel.downlink_rate * ratio)
        result = solve_dropout_rates(
            tel_eff, a_server=a_eff, d_max=d_max, delta=delta,
            global_model_bytes=global_model_bytes)
    d = np.clip(result.dropout_rates, 0.0, d_max)
    wire = analytic_uplink_vector(wire_specs, d, comm)
    # report the makespan the WIRE would see (uplink = codec bytes)
    u_eff_dl = tel.model_bytes.astype(np.float64) * (1.0 - d)
    makespan = float(np.max(tel.compute_latency + wire / tel.uplink_rate
                            + u_eff_dl / tel.downlink_rate))
    gmb = float(global_model_bytes if global_model_bytes is not None
                else np.max(tel.model_bytes))
    obj = makespan + delta * float(np.dot(regularizer(tel, gmb), d))
    feasible = bool(abs(float(np.sum(wire)) - a_server * total_full)
                    <= 5e-2 * max(total_full, 1.0))
    return AllocationResult(d, makespan, obj, feasible)


def solve_dropout_rates_with(
    allocator: str,
    tel: ClientTelemetry,
    *,
    a_server: float,
    d_max: float,
    delta: float,
    global_model_bytes: Optional[float] = None,
    num_iters: int = 96,
) -> AllocationResult:
    """Allocator dispatch: the numpy reference or the vectorised JAX solver.

    Both minimise the same Eq. (16)/(17) LP; ``"jax"`` runs the
    golden-section search as a ``lax.fori_loop`` (jit-compiled, fixed
    iteration count) and is the stepping stone toward folding the
    allocation into a multi-round ``lax.scan``.  Returns the same
    :class:`AllocationResult` host struct either way; the budget equality
    ``sum U_n (1-D_n) = A_server sum U_n`` holds for both (the parity test
    in tests/test_allocation.py pins it).
    """
    if allocator == "numpy":
        return solve_dropout_rates(
            tel, a_server=a_server, d_max=d_max, delta=delta,
            global_model_bytes=global_model_bytes)
    if allocator != "jax":
        raise ValueError(f"unknown allocator {allocator!r}; "
                         f"expected one of {ALLOCATORS}")
    d_dev, t_dev = solve_dropout_rates_jax(
        jnp.asarray(tel.model_bytes, jnp.float32),
        jnp.asarray(tel.uplink_rate, jnp.float32),
        jnp.asarray(tel.downlink_rate, jnp.float32),
        jnp.asarray(tel.compute_latency, jnp.float32),
        jnp.asarray(tel.num_samples, jnp.float32),
        jnp.asarray(tel.label_coverage, jnp.float32),
        jnp.asarray(tel.train_loss, jnp.float32),
        a_server=a_server, d_max=d_max, delta=delta,
        global_model_bytes=global_model_bytes, num_iters=num_iters)
    d = np.clip(np.asarray(d_dev, np.float64), 0.0, d_max)
    u = tel.model_bytes.astype(np.float64)
    gmb = float(global_model_bytes if global_model_bytes is not None
                else np.max(u))
    makespan = float(t_dev)
    obj = makespan + delta * float(np.dot(regularizer(tel, gmb), d))
    budget = (1.0 - a_server) * float(np.sum(u))
    feasible = bool(abs(float(np.dot(u, d)) - budget)
                    <= 1e-4 * max(float(np.sum(u)), 1.0))
    return AllocationResult(d, makespan, obj, feasible)
