"""FedDD core — the paper's contribution as composable JAX modules.

Modules
  allocation        dropout-rate allocation LP (paper §4.1, Eq. 16/17)
  importance        parameter importance indices (Eq. 20/21)
  selection         per-layer channel top-k mask building (Algorithm 2)
  aggregation       sparse aggregation + client update rules (Eq. 4/5/6)
  coverage          CR(k) coverage rates for heterogeneous models
  baselines         FedAvg / FedCS / Oort client selection
  protocol          Algorithm-1 orchestration (server + clients)
  round_engine      batched jit-compiled round step (homogeneous hot path)
  sparse_collective compacted cross-pod collectives (TPU adaptation)
  convergence       Theorem-2 bound evaluation + epsilon estimator

The event-driven system simulator (dynamic networks, stragglers, deadline
and async serving) lives in the sibling package ``repro.sim``; see the
routing table in the protocol module docstring.
"""

from repro.core.allocation import (AllocationResult, ClientTelemetry,
                                   regularizer, solve_dropout_rates,
                                   solve_dropout_rates_jax,
                                   solve_dropout_rates_with)
from repro.core.aggregation import (aggregate_sparse,
                                    aggregate_sparse_grouped,
                                    aggregate_sparse_stacked,
                                    client_update_full,
                                    client_update_sparse, fedavg_aggregate)
from repro.core.convergence import (BoundInputs, estimate_epsilon, eta_max,
                                    residual_error, theorem2_bound)
from repro.core.importance import channel_importance, elementwise_importance
from repro.core.protocol import (FedDDServer, ProtocolConfig, RoundRecord,
                                 RunResult, run_scheme)
from repro.core.round_engine import (BatchedRoundEngine, GroupBatch,
                                     GroupedFleetState, GroupedRoundEngine,
                                     GroupedRoundOutputs, RoundOutputs,
                                     ScanState, ScanTelemetry, ScanTrace,
                                     make_batched_train_fn, slice_pytree,
                                     stack_pytrees, unstack_groups,
                                     unstack_pytree)
from repro.core.selection import (SelectionConfig, apply_mask, build_masks,
                                  build_masks_batched, mask_density)
from repro.core.sparse_collective import (dense_allreduce_mean,
                                          make_federated_allreduce,
                                          sparse_allgather_mean)
