"""Client-selection baselines the paper compares against (§6.2).

* FedAvg  — everyone uploads the full model (no budget).
* FedCS   — drop the clients with the longest round time until the uploaded
            parameter mass fits the communication budget (Nishio & Yonetani).
* Oort    — utility-guided selection (Lai et al., OSDI'21): statistical
            utility (loss-based) x system-utility penalty for stragglers,
            select highest-utility clients within the budget.

All selectors return a boolean participation vector; selected clients upload
FULL models (that is the point of the comparison — same total transmitted
bytes as FedDD's sparse uploads at a given A_server).

Two Oort entry points exist: :func:`select_oort` (the numpy reference the
per-round drivers call) and :func:`select_oort_traced` (a jit-able JAX
mirror the multi-round scanned engine calls in-trace — Oort is the one
baseline whose selection depends on the round-varying losses, so it cannot
be precomputed host-side like FedCS).  The loss-independent system-utility
penalty IS static per telemetry; :func:`oort_system_penalty` precomputes it
host-side in float64 so the traced selector only re-ranks by loss.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.allocation import ClientTelemetry


def round_times(tel: ClientTelemetry, dropout: Optional[np.ndarray] = None,
                *, uplink_bytes: Optional[np.ndarray] = None) -> np.ndarray:
    """t_n = t_cmp + U(1-D)/r_u + U(1-D)/r_d (Eq. (12) summand).

    ``uplink_bytes`` replaces the uplink leg's idealized ``U(1-D)`` with
    codec-measured on-wire bytes (repro.comm): sparse uploads also ship
    the mask encoding and may quantize the values, so what crosses the
    uplink is NOT just the kept parameter mass.  The downlink (the
    server's broadcast) stays on the idealized model either way.
    """
    d = np.zeros(tel.num_clients) if dropout is None else dropout
    u_eff = tel.model_bytes * (1.0 - d)
    up = u_eff if uplink_bytes is None else np.asarray(uplink_bytes)
    return (tel.compute_latency
            + up / tel.uplink_rate
            + u_eff / tel.downlink_rate)


def select_fedavg(tel: ClientTelemetry) -> np.ndarray:
    return np.ones(tel.num_clients, bool)


def select_fedcs(tel: ClientTelemetry, *, a_server: float) -> np.ndarray:
    """Keep fastest clients until budget A_server * sum(U) is exhausted."""
    t = round_times(tel)
    order = np.argsort(t)  # fastest first
    budget = a_server * float(np.sum(tel.model_bytes))
    sel = np.zeros(tel.num_clients, bool)
    used = 0.0
    for i in order:
        if used + tel.model_bytes[i] <= budget + 1e-9:
            sel[i] = True
            used += tel.model_bytes[i]
    if not sel.any():           # always keep at least the fastest client
        sel[order[0]] = True
    return sel


@dataclasses.dataclass
class OortState:
    """Exploitation statistics for Oort (simplified faithful variant)."""
    straggler_penalty: float = 2.0   # alpha in the paper (=2 per FedDD §6.2)

    def utilities(self, tel: ClientTelemetry,
                  round_deadline: Optional[float] = None) -> np.ndarray:
        # statistical utility: m_n * sqrt(mean loss^2)  (Oort Eq. 1 simplified
        # to per-client loss since we track client-level, not sample-level)
        stat = tel.num_samples * np.sqrt(np.maximum(tel.train_loss, 0.0))
        return stat * oort_system_penalty(tel, state=self,
                                          round_deadline=round_deadline)


def oort_system_penalty(tel: ClientTelemetry, *,
                        state: Optional[OortState] = None,
                        round_deadline: Optional[float] = None) -> np.ndarray:
    """The loss-independent factor of Oort's utility — static per telemetry.

    ``utilities == num_samples * sqrt(max(loss, 0)) * oort_system_penalty``
    (this IS the penalty :meth:`OortState.utilities` applies — single
    source, so the numpy and traced Oort paths cannot drift): the
    straggler penalty depends only on the (static) round times, so the
    scanned engine precomputes it host-side in float64 and passes it into
    the traced selector, which then only has to re-rank by the carried
    losses each round.
    """
    state = state or OortState()
    t = round_times(tel)
    if round_deadline is None:
        round_deadline = float(np.percentile(t, 80))
    return np.where(
        t > round_deadline,
        (round_deadline / np.maximum(t, 1e-9)) ** state.straggler_penalty,
        1.0)


def select_oort_traced(train_loss: jax.Array, *, num_samples: jax.Array,
                       system_penalty: jax.Array, model_bytes: jax.Array,
                       budget: jax.Array) -> jax.Array:
    """Jit-able :func:`select_oort` for the multi-round scanned engine.

    Mirrors the numpy greedy exactly — rank by utility, admit clients whose
    model fits the remaining ``a_server`` byte budget, always keep at least
    the top-ranked client — but runs on traced (carry) losses so Oort
    rounds can live inside ``lax.scan``.  Arithmetic is float32 on device
    (the reference is float64), so selection can differ from the numpy path
    only when two utilities or a budget boundary tie to within float32
    resolution; ``jnp.argsort`` is additionally stable where ``np.argsort``
    is not.  The scanned-vs-sequential parity test pins agreement on
    generic (non-degenerate) telemetry.
    """
    util = (num_samples * jnp.sqrt(jnp.maximum(train_loss, 0.0))
            * system_penalty)
    order = jnp.argsort(-util)
    n = util.shape[0]

    def admit(carry, i):
        used, sel = carry
        u_i = model_bytes[i]
        take = used + u_i <= budget + 1e-9
        used = used + jnp.where(take, u_i, 0.0)
        return (used, sel.at[i].set(take)), None

    (_, sel), _ = jax.lax.scan(
        admit, (jnp.zeros((), jnp.float32), jnp.zeros((n,), bool)), order)
    fallback = jnp.zeros((n,), bool).at[order[0]].set(True)
    return jnp.where(jnp.any(sel), sel, fallback)


def select_oort(tel: ClientTelemetry, *, a_server: float,
                state: Optional[OortState] = None) -> np.ndarray:
    """Highest-utility clients within the parameter budget."""
    state = state or OortState()
    util = state.utilities(tel)
    order = np.argsort(-util)
    budget = a_server * float(np.sum(tel.model_bytes))
    sel = np.zeros(tel.num_clients, bool)
    used = 0.0
    for i in order:
        if used + tel.model_bytes[i] <= budget + 1e-9:
            sel[i] = True
            used += tel.model_bytes[i]
    if not sel.any():
        sel[order[0]] = True
    return sel
