"""Client-selection baselines the paper compares against (§6.2).

* FedAvg  — everyone uploads the full model (no budget).
* FedCS   — drop the clients with the longest round time until the uploaded
            parameter mass fits the communication budget (Nishio & Yonetani).
* Oort    — utility-guided selection (Lai et al., OSDI'21): statistical
            utility (loss-based) x system-utility penalty for stragglers,
            select highest-utility clients within the budget.

All selectors return a boolean participation vector; selected clients upload
FULL models (that is the point of the comparison — same total transmitted
bytes as FedDD's sparse uploads at a given A_server).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.allocation import ClientTelemetry


def round_times(tel: ClientTelemetry, dropout: Optional[np.ndarray] = None
                ) -> np.ndarray:
    """t_n = t_cmp + U(1-D)/r_u + U(1-D)/r_d (Eq. (12) summand)."""
    d = np.zeros(tel.num_clients) if dropout is None else dropout
    u_eff = tel.model_bytes * (1.0 - d)
    return (tel.compute_latency
            + u_eff / tel.uplink_rate
            + u_eff / tel.downlink_rate)


def select_fedavg(tel: ClientTelemetry) -> np.ndarray:
    return np.ones(tel.num_clients, bool)


def select_fedcs(tel: ClientTelemetry, *, a_server: float) -> np.ndarray:
    """Keep fastest clients until budget A_server * sum(U) is exhausted."""
    t = round_times(tel)
    order = np.argsort(t)  # fastest first
    budget = a_server * float(np.sum(tel.model_bytes))
    sel = np.zeros(tel.num_clients, bool)
    used = 0.0
    for i in order:
        if used + tel.model_bytes[i] <= budget + 1e-9:
            sel[i] = True
            used += tel.model_bytes[i]
    if not sel.any():           # always keep at least the fastest client
        sel[order[0]] = True
    return sel


@dataclasses.dataclass
class OortState:
    """Exploitation statistics for Oort (simplified faithful variant)."""
    straggler_penalty: float = 2.0   # alpha in the paper (=2 per FedDD §6.2)

    def utilities(self, tel: ClientTelemetry,
                  round_deadline: Optional[float] = None) -> np.ndarray:
        # statistical utility: m_n * sqrt(mean loss^2)  (Oort Eq. 1 simplified
        # to per-client loss since we track client-level, not sample-level)
        stat = tel.num_samples * np.sqrt(np.maximum(tel.train_loss, 0.0))
        t = round_times(tel)
        if round_deadline is None:
            round_deadline = float(np.percentile(t, 80))
        sys_pen = np.where(
            t > round_deadline,
            (round_deadline / np.maximum(t, 1e-9)) ** self.straggler_penalty,
            1.0,
        )
        return stat * sys_pen


def select_oort(tel: ClientTelemetry, *, a_server: float,
                state: Optional[OortState] = None) -> np.ndarray:
    """Highest-utility clients within the parameter budget."""
    state = state or OortState()
    util = state.utilities(tel)
    order = np.argsort(-util)
    budget = a_server * float(np.sum(tel.model_bytes))
    sel = np.zeros(tel.num_clients, bool)
    used = 0.0
    for i in order:
        if used + tel.model_bytes[i] <= budget + 1e-9:
            sel[i] = True
            used += tel.model_bytes[i]
    if not sel.any():
        sel[order[0]] = True
    return sel
