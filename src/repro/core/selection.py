"""Uploaded-parameter selection — FedDD Algorithm 2.

Given a client's dropout rate ``D`` and its parameter pytree before/after the
local update, build the binary mask pytree ``M`` (same structure/shapes as the
parameters) that keeps, per layer, the top ``ceil(N_l * (1 - D))`` channels by
importance.

Paper fidelity notes:

* The paper performs dropout at *channel/neuron* granularity with the SAME
  dropout rate for each layer (§4.2: "we set the same dropout rate for each
  layer, and perform dropout at channel-wised manner").
* Algorithm 2 writes ``n_l_up = N_l * D`` but the surrounding text ("select
  the parameters with high importance indices ... to meet the required
  uploaded number", and D being the *dropped* proportion) makes clear the
  uploaded count is ``N_l * (1 - D)``; we implement the latter.
* 1-D parameters (biases, norm scales) ride along with their channel: each is
  treated as channels of fan-in 1.

Masks are returned as the params' dtype (0/1 values) so that ``W * M`` and the
aggregation maths need no casting.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import importance as imp_mod

SCHEMES = ("feddd", "max", "delta", "random", "ordered")


@dataclasses.dataclass(frozen=True)
class SelectionConfig:
    scheme: str = "feddd"          # one of SCHEMES
    channel_axis: int = -1         # which axis of each tensor is 'channels'
    use_kernel: bool = False       # route importance through the Pallas kernel

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown selection scheme {self.scheme!r}")


def mask_from_scores(scores: jax.Array, keep: jax.Array | int,
                     num_channels: int) -> jax.Array:
    """Binary (float32) mask of shape (num_channels,) keeping the top
    ``keep`` scores.  ``keep`` may be a traced scalar: ranks come from a
    full-width ``lax.top_k`` (descending order, ties broken toward the lower
    index — the same tie order as a stable descending argsort) and the mask
    is a jit-safe ``rank < keep`` compare.  keep==0 -> all-zero mask.
    """
    _, order = jax.lax.top_k(scores, num_channels)
    ranks = jnp.zeros(num_channels, jnp.int32).at[order].set(
        jnp.arange(num_channels, dtype=jnp.int32))
    return (ranks < keep).astype(jnp.float32)


def mask_from_scores_argsort(scores: jax.Array, keep: jax.Array | int,
                             num_channels: int) -> jax.Array:
    """Reference implementation of :func:`mask_from_scores` via a stable
    descending argsort.  Kept as the tie-handling oracle for tests; the
    production path uses ``lax.top_k``."""
    order = jnp.argsort(-scores)
    ranks = jnp.zeros(num_channels, jnp.int32).at[order].set(
        jnp.arange(num_channels, dtype=jnp.int32))
    return (ranks < keep).astype(jnp.float32)


def keep_count(num_channels: int, dropout_rate: jax.Array) -> jax.Array:
    """ceil(N * (1-D)), clipped to [0, N], as int32 (jit-safe)."""
    k = jnp.ceil(num_channels * (1.0 - dropout_rate))
    return jnp.clip(k, 0, num_channels).astype(jnp.int32)


def _tensor_scores(cfg: SelectionConfig, w_old, w_new, coverage, rng):
    ax = cfg.channel_axis
    if cfg.scheme == "feddd":
        if cfg.use_kernel:
            from repro.kernels.importance import ops as kops
            return kops.channel_importance(w_old, w_new, channel_axis=ax,
                                           coverage=coverage)
        return imp_mod.channel_importance(w_old, w_new, channel_axis=ax,
                                          coverage=coverage)
    if cfg.scheme == "max":
        return imp_mod.channel_score_max(w_old, w_new, channel_axis=ax)
    if cfg.scheme == "delta":
        return imp_mod.channel_score_delta(w_old, w_new, channel_axis=ax)
    nch = w_new.shape[ax]
    if cfg.scheme == "random":
        return imp_mod.channel_score_random(rng, nch)
    if cfg.scheme == "ordered":
        return imp_mod.channel_score_ordered(nch)
    raise AssertionError(cfg.scheme)


def build_masks(
    params_old,
    params_new,
    dropout_rate: jax.Array,
    *,
    config: SelectionConfig = SelectionConfig(),
    coverage: Optional[Dict] = None,
    rng: Optional[jax.Array] = None,
    always_upload: Optional[Callable[[str], bool]] = None,
) -> Dict:
    """Build the mask pytree ``M_n^t``.

    Args:
      params_old / params_new: pytrees of identical structure (W, W-hat).
      dropout_rate: scalar in [0, 1] (can be traced).
      coverage: optional pytree of per-channel coverage rates CR(k), each leaf
        shaped (num_channels,) matching the corresponding parameter's channel
        axis (heterogeneous-model case, Eq. (21)).
      rng: PRNG key, required for scheme='random'.
      always_upload: predicate on the flattened leaf path name; leaves for
        which it returns True get an all-ones mask (used for tiny critical
        tensors, e.g. MoE router weights — see DESIGN.md).

    Returns a mask pytree (leaves broadcastable against the params: shape is
    1s everywhere except the channel axis).
    """
    if config.scheme == "random" and rng is None:
        raise ValueError("scheme='random' requires rng")

    flat_old = jax.tree_util.tree_flatten_with_path(params_old)[0]
    flat_new, treedef = jax.tree_util.tree_flatten_with_path(params_new)
    flat_cov = (jax.tree_util.tree_leaves(coverage)
                if coverage is not None else [None] * len(flat_new))
    if len(flat_old) != len(flat_new):
        raise ValueError("params_old/params_new structure mismatch")

    masks = []
    for i, ((path, w_new), (_, w_old), cov) in enumerate(
            zip(flat_new, flat_old, flat_cov)):
        name = jax.tree_util.keystr(path)
        ax = config.channel_axis % max(w_new.ndim, 1)
        nch = w_new.shape[ax] if w_new.ndim > 0 else 1
        if (always_upload is not None and always_upload(name)) or w_new.ndim == 0:
            mask = jnp.ones((1,) * max(w_new.ndim, 1), w_new.dtype)
            masks.append(jnp.broadcast_to(mask, w_new.shape)
                         if w_new.ndim == 0 else mask)
            continue
        leaf_rng = (jax.random.fold_in(rng, i) if rng is not None else None)
        scores = _tensor_scores(config, w_old, w_new, cov, leaf_rng)
        k = keep_count(nch, dropout_rate)
        m1d = mask_from_scores(scores, k, nch)
        shape = [1] * w_new.ndim
        shape[ax] = nch
        masks.append(m1d.reshape(shape).astype(w_new.dtype))
    return jax.tree_util.tree_unflatten(treedef, masks)


def _tensor_scores_batched(cfg: SelectionConfig, w_old, w_new,
                           leaf_rngs: Optional[jax.Array],
                           coverage: Optional[jax.Array] = None):
    """Scores for a client-stacked leaf: (N, *leaf) x2 -> (N, C).

    ``leaf_rngs`` is a (N, key) stack of per-client keys already folded with
    this leaf's index (matching the per-client ``build_masks`` fold order).
    ``coverage`` is an optional (C,) coverage-rate vector shared by every
    client in the stack (shape groups: same sub-model widths => same CR
    slice); it divides the feddd importance exactly as in the per-client
    path (Eq. (21)).
    """
    ax = cfg.channel_axis
    if cfg.scheme == "feddd":
        if cfg.use_kernel:
            from repro.kernels.importance import ops as kops
            return kops.channel_importance_batched(w_old, w_new,
                                                   channel_axis=ax,
                                                   coverage=coverage)
        return imp_mod.channel_importance_batched(w_old, w_new,
                                                  channel_axis=ax,
                                                  coverage=coverage)
    if cfg.scheme == "max":
        return imp_mod.channel_score_max_batched(w_old, w_new,
                                                 channel_axis=ax)
    if cfg.scheme == "delta":
        return imp_mod.channel_score_delta_batched(w_old, w_new,
                                                   channel_axis=ax)
    nch = w_new.shape[ax % (w_new.ndim - 1) + 1]
    if cfg.scheme == "random":
        return jax.vmap(
            lambda k: imp_mod.channel_score_random(k, nch))(leaf_rngs)
    if cfg.scheme == "ordered":
        return jnp.broadcast_to(imp_mod.channel_score_ordered(nch),
                                (w_new.shape[0], nch))
    raise AssertionError(cfg.scheme)


def build_masks_batched(
    stacked_old,
    stacked_new,
    dropout_rates: jax.Array,
    *,
    config: SelectionConfig = SelectionConfig(),
    rng: Optional[jax.Array] = None,
    coverage: Optional[object] = None,
    client_indices: Optional[jax.Array] = None,
):
    """Client-stacked ``build_masks``: all clients' masks in one traced pass.

    Args:
      stacked_old / stacked_new: pytrees whose leaves carry a leading client
        axis — leaf shape (N, *leaf_shape).
      dropout_rates: (N,) per-client dropout rates (can be traced).
      rng: the ROUND key; per-client keys are derived as
        ``fold_in(fold_in(rng, 10_000 + i), leaf_index)`` — the exact fold
        order of the per-client loop, so scheme='random' masks are
        bit-identical to looping :func:`build_masks` with
        ``rng=fold_in(round_key, 10_000 + i)``.
      coverage: optional UN-stacked pytree of per-channel coverage rates
        CR(k), each leaf (C,) — shared by every client in the stack.  This
        is the shape-group case: members hold identically-shaped sub-models,
        so they share one coverage slice and Eq. (21)'s division broadcasts
        over the client axis.
      client_indices: optional (N,) ids ``i`` the per-client RNG keys fold
        in.  Defaults to ``arange(N)``; a shape group passes its members'
        fleet positions so group masks are bit-identical to the per-client
        loop over the whole fleet.  May be a traced array — group membership
        changes do not retrigger compilation.

    Returns ``(masks, density)``: a mask pytree with leaves shaped
    (N, 1, ..., C, ..., 1) and the (N,) fraction of parameter elements kept
    (the per-client upload density, computed on device so the caller makes a
    single small host transfer instead of O(clients x leaves) ``float()``
    round-trips).

    Everything here is scan-safe: ``dropout_rates``, ``rng``, and
    ``client_indices`` may be values carried by an enclosing ``lax.scan``
    (the multi-round engine passes the round key and the in-scan allocated
    rates straight from its carry), and the ``lax.top_k`` rank compare
    keeps the keep-count dynamic so per-round rate changes never retrace.
    """
    if config.scheme == "random" and rng is None:
        raise ValueError("scheme='random' requires rng")

    flat_old = jax.tree_util.tree_leaves(stacked_old)
    flat_new, treedef = jax.tree_util.tree_flatten(stacked_new)
    flat_cov = (jax.tree_util.tree_leaves(coverage)
                if coverage is not None else [None] * len(flat_new))
    if len(flat_old) != len(flat_new):
        raise ValueError("stacked_old/stacked_new structure mismatch")
    n = flat_new[0].shape[0]

    client_keys = None
    if rng is not None:
        ids = (jnp.asarray(client_indices)
               if client_indices is not None else jnp.arange(n))
        client_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng, i))(10_000 + ids)

    masks = []
    kept = jnp.zeros((n,), jnp.float32)
    total = 0.0
    for i, (w_old, w_new, cov) in enumerate(
            zip(flat_old, flat_new, flat_cov)):
        leaf_ndim = w_new.ndim - 1
        leaf_size = float(np.prod(w_new.shape[1:], dtype=np.float64))
        if leaf_ndim == 0:
            masks.append(jnp.ones((n,), w_new.dtype))
            kept = kept + leaf_size
            total += leaf_size
            continue
        ax = config.channel_axis % leaf_ndim + 1
        nch = w_new.shape[ax]
        leaf_rngs = (jax.vmap(lambda k: jax.random.fold_in(k, i))(client_keys)
                     if client_keys is not None else None)
        scores = _tensor_scores_batched(config, w_old, w_new, leaf_rngs, cov)
        k = keep_count(nch, dropout_rates)                     # (N,)
        m1d = jax.vmap(mask_from_scores, (0, 0, None))(scores, k, nch)
        shape = [n] + [1] * leaf_ndim
        shape[ax] = nch
        masks.append(m1d.reshape(shape).astype(w_new.dtype))
        kept = kept + jnp.sum(m1d, axis=1) * (leaf_size / nch)
        total += leaf_size
    density = kept / total
    return jax.tree_util.tree_unflatten(treedef, masks), density


def apply_mask(params, masks):
    """W ⊙ M with broadcasting (masks are channel-shaped)."""
    return jax.tree_util.tree_map(lambda w, m: w * m, params, masks)


def mask_density(params, masks) -> jax.Array:
    """Fraction of parameter *elements* kept (for telemetry / byte counts)."""
    def _counts(w, m):
        kept = jnp.sum(jnp.broadcast_to(m, w.shape).astype(jnp.float32))
        return kept, jnp.asarray(w.size, jnp.float32)
    kept_tot = 0.0
    size_tot = 0.0
    for w, m in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(masks)):
        kc, sc = _counts(w, m)
        kept_tot = kept_tot + kc
        size_tot = size_tot + sc
    return kept_tot / size_tot
