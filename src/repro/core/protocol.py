"""FedDD training protocol — the paper's Algorithm 1, plus baseline drivers.

The driver is deliberately generic: it orchestrates *any* model exposing

    local_train_fn(params, client_data, rng) -> (new_params, loss)
    eval_fn(params) -> metrics dict            (optional)

so the same code runs the paper's MLP/CNN FL simulations and the pod-scale
transformer federation (examples/federated_pods.py uses the shard_map
collectives in core/sparse_collective.py instead, for on-device execution;
this driver is the faithful parameter-server formulation).

Round execution is a strategy behind one executor interface
(:class:`_RoundExecutor`): every strategy runs the identical Algorithm-1
maths, they differ only in how the device work is dispatched (see
tests/test_round_engine.py, tests/test_grouped_engine.py, tests/test_sim.py
for the equivalence contracts).  Routing table — which executor handles
which scenario:

==========================  =================================================
scenario                    executor
==========================  =================================================
homogeneous (any scheme)    **batched engine** (core/round_engine.py): one
                            jit-compiled device step per round; feddd may
                            pass ``batched_train_fn`` to fuse local training
                            too; fedavg / fedcs / oort run ``dense_masks``
                            mode with non-participants as 0-weights in the
                            stacked Eq. (4) aggregation
homogeneous +               **scanned engine** (core/round_engine.py
``rounds_per_dispatch>1``   BatchedRoundEngine.run): K rounds per device
                            dispatch via ``lax.scan`` — training, masks,
                            Eq. (4)/(5)/(6), the Eq. (9)-(11) re-allocation
                            AND the Eq. (12) clock all live in the scan
                            carry; ONE host transfer (the stacked
                            ScanTrace) per chunk.  Requires
                            ``allocator="jax"``, ``batched_train_fn``, and
                            no per-round ``eval_fn``; learning state is
                            bit-identical to K sequential engine rounds
                            (allocator pinned to f32-ulp scale)
heterogeneous (ragged       **grouped engine** (core/round_engine.py
widths, any scheme)         GroupedRoundEngine): clients partitioned by
                            sub-model shape (repro.fl.heterogeneity), one
                            fused step per shape census — coverage-aware
                            batched masks at native widths, one shared
                            scatter into the full-width Eq. (4) canvas,
                            local-width client updates
homogeneous +               **sharded engine** (core/round_engine.py
``mesh=``                   ShardedRoundEngine): the fleet's client axis
                            shards over a 1-D ``clients`` device mesh;
                            masks, wire encoding, Eq. (4) partials and
                            Eq. (5)/(6) updates run per shard inside one
                            ``shard_map`` and only the (num, den)
                            reduction crosses devices — dense psum
                            (default; bit-identical to the batched engine
                            on a 1-device mesh) or the compacted top-K
                            channel exchange of core/sparse_collective.py
                            (``mesh_collective="sparse"``: per-link bytes
                            scale with 1-D).  Ragged fleets with ``mesh=``
                            ride the grouped engine's sharded step (per
                            group member-axis shard_map + per-group psum).
                            The allocation LP and the Eq. (12) clock run
                            on gathered host telemetry exactly as the
                            batched row above.  Excludes
                            ``rounds_per_dispatch>1`` (the scan carries
                            single-device state)
track_epsilon, or           **reference loop**: the per-client Python loop,
``batched=False``           kept as the bit-exactness oracle (grouped and
                            batched engines are pinned against it) and for
                            the Assumption-3 epsilon estimator's per-client
                            mask pytrees
dynamic networks /          **sim runner** (repro/sim/runner.py): pass
stragglers / deadline or    ``sim=``/``network=`` to :func:`run_scheme`;
async serving               event-driven clock, observed-telemetry LP
                            re-solve, sync / deadline / async policies;
                            ragged fleets ride the grouped engine there too
faults: churn, lossy or     **sim runner + fault layer** (repro/sim/
corrupted uplinks, retry/   faults.py): pass ``faults=`` to
timeout serving, quorum     :func:`run_scheme` (with ``sim=``); crash /
degradation                 packet-loss / corruption injection, payload
                            validation + quarantine (0-weight on the same
                            stacked Eq. (4) step), the ``retry`` timeout
                            policy, deadline partial aggregation of
                            delivered mask-channel prefixes, and the
                            minimum-quorum round skip with survivor-only
                            LP re-solves.  All fault rates 0 == no fault
                            model, bit for bit (tests/test_faults.py)
observability (metrics,     **every executor** via ``ProtocolConfig(obs=
span tracing, JSONL run     ObsConfig(...))`` (repro.obs): the driver builds
logs, run-inspection CLI)   one recorder per run; host spans wrap each
                            pipeline phase (allocate / local_train /
                            engine_step / encode / aggregate /
                            client_update / host_transfer / eval —
                            ``Recorder.span`` in the executors below), a
                            metrics registry accumulates round / byte /
                            failure totals, and every RoundRecord lands in
                            the JSONL log as a ``round`` event (inspect
                            with ``python -m repro.obs.report``).  Byte
                            counters hook the ONE shared reduction
                            (``account_uplink(obs=...)``); everything else
                            reads the round's existing host transfer — no
                            new device->host syncs.  The default
                            ``ObsConfig()`` is inert (NULL_RECORDER): runs
                            are bit-identical with observability off, and
                            the engines' ``jax.named_scope`` phase
                            annotations are compile-time metadata, so
                            enabling it never changes compiled programs
                            (tests/test_obs.py)
Byzantine-robust            **batched / scanned / grouped / sharded
aggregation                 engines** via ``ProtocolConfig(robust_agg=
(``robust_agg``)            ...)`` (core/aggregation.py): coordinate-wise
                            trimmed mean (``"trimmed[:beta]"``) or
                            per-client update norm-clipping
                            (``"clip[:factor]"``) replace the weighted
                            mean inside the SAME fused stacked Eq. (4)
                            step — no per-client host loop.  On a mesh
                            the trimmed/clip statistics need the full
                            client axis, so the sharded step falls back
                            to a dense ``all_gather`` of the masked
                            leaves (per-link bytes scale with the fleet);
                            sharded+grouped robust is rejected.  The
                            default ``"mean"`` is bit-identical to the
                            plain engines on every path
                            (tests/test_robust_agg.py)
crash-resume                **engine + loop executors** via
(``checkpoint_every`` /     ``ProtocolConfig(checkpoint_every=K,
``resume_from``)            checkpoint_path=...)`` (repro.checkpoint):
                            every K rounds the driver atomically
                            snapshots a full :class:`RunState` — global
                            + stacked client params, PRNG key, losses,
                            dropout rates, round history — and
                            ``resume_from=`` restarts a killed run at
                            the next round with BIT-IDENTICAL RoundRecord
                            history and final params, faults and obs
                            included (fault/outage draws are keyed per
                            (seed, tag, epoch, client), so they replay
                            free; tests/test_resume.py).  The sim runner
                            checkpoints its own wave-policy state the
                            same way.  ``checkpoint_every=None``
                            (default) touches no code path
population-scale serving    **sim runner + repro.population**: pass
(``population=`` /          ``population=Population(tel, availability=...,
``cohort_size=``)           sampler=...)`` and ``cohort_size=`` to
                            :func:`run_scheme` / ``run_sim`` — telemetry
                            and the network model cover a 100k+ client
                            POPULATION, availability churn decides who is
                            online each epoch, and only the sampled
                            cohort is materialized into the stacked /
                            grouped engine buffers; sticky per-client
                            state (telemetry EWMAs by global id, losses,
                            dropout rates, Oort utilities, byte/failure
                            economy) survives cohort changes in the
                            Population store, and the Eq. (9)-(11) LP can
                            cold-start first-contact clients from
                            population means.  Population == fleet with
                            always-on availability and the default
                            sampler is bit-identical to the plain runs on
                            every engine path (tests/test_population.py)
wire formats (sparse        **every executor** via ``ProtocolConfig(comm=
codecs, quantization,       CommConfig(codec=..., qbits=...))`` (repro.comm):
on-wire byte accounting)    masks ship as packed-bitmask / delta+varint
                            index / auto encodings, values as fp32 / fp16 /
                            int8-SR; ``RoundRecord.wire_bytes`` carries the
                            measured cost next to the raw
                            ``uploaded_bytes``, the Eq. (12) uplink and the
                            sim's event timeline charge codec bytes, and
                            ``comm.overhead_aware_allocation`` solves the
                            LP on effective bytes.  Default = the analytic
                            accounting, bit for bit
==========================  =================================================

* The batched and grouped engines are bit-identical to the reference loop
  for FedDD and match it to float tolerance for the baselines (summation
  order differs).  Benchmarks: ``PYTHONPATH=src python
  benchmarks/perf_federated.py`` (homogeneous), ``PYTHONPATH=src python
  benchmarks/heterogeneous.py --perf`` (ragged).
* The sim runner with the synchronous policy over a static network
  reproduces this driver's Eq. (12) round times exactly — for homogeneous
  AND ragged fleets.

Simulated wall-clock follows the paper's system model exactly
(t = t_cmp + U(1-D)/r_u + U(1-D)/r_d; the round takes max over participating
clients, using the dropout rates the round's uploads actually used) — this
is how the paper's own simulation computes time-to-accuracy.  The closed
form is exact only for the synchronous policy; anything event-ordered
(deadlines, stragglers, async merges) lives in ``repro.sim``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs as obs_mod
from repro.comm import codecs as wire_codecs
from repro.comm import quantize as wire_quant
from repro.comm.payload import (CommConfig, WireSpec, account_collective,
                                account_uplink, analytic_uplink_vector)
from repro.core import (aggregation, baselines, coverage as cov_mod,
                        round_engine, selection)
from repro.core.allocation import (ALLOCATORS, AllocationResult,
                                   ClientTelemetry,
                                   solve_dropout_rates_overhead_aware,
                                   solve_dropout_rates_with)
from repro.core.convergence import estimate_epsilon

Params = object  # pytree


@dataclasses.dataclass
class ProtocolConfig:
    scheme: str = "feddd"            # feddd | fedavg | fedcs | oort
    selection: selection.SelectionConfig = dataclasses.field(
        default_factory=selection.SelectionConfig)
    a_server: float = 0.6            # communication budget (Table 4)
    d_max: float = 0.8               # max dropout rate (Table 4)
    delta: float = 1.0               # heterogeneity penalty factor
    h: int = 5                       # full-broadcast period (Table 4)
    rounds: int = 50
    seed: int = 0
    track_epsilon: bool = False      # Assumption-3 estimator (costly)
    batched: bool = True             # engine-backed execution (homogeneous
                                     # batched engine / ragged grouped
                                     # engine); False forces the reference
                                     # per-client loop
    allocator: str = "numpy"         # Eq. (16)/(17) LP solver: "numpy"
                                     # (exact reference) or "jax" (jit-able
                                     # fori_loop golden section; required
                                     # by the multi-round lax.scan)
    rounds_per_dispatch: int = 1     # K>1: run K rounds as ONE lax.scan
                                     # device dispatch (homogeneous engine
                                     # + batched_train_fn + allocator="jax"
                                     # only); 1 = per-round dispatch
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
                                     # wire format (repro.comm): mask codec
                                     # + value precision + overhead-aware
                                     # allocation.  The default (dense, 32)
                                     # is the pre-comm analytic accounting,
                                     # bit for bit.
    obs: obs_mod.ObsConfig = dataclasses.field(
        default_factory=obs_mod.ObsConfig)
                                     # observability (repro.obs): metrics
                                     # registry + host spans + JSONL run
                                     # log.  The default is INERT — runs
                                     # are bit-identical with it off.
    mesh: object = None              # client-sharded SPMD execution
                                     # (core/round_engine.py
                                     # ShardedRoundEngine): an int device
                                     # count, True (all local devices), or
                                     # a jax.sharding.Mesh with a
                                     # "clients" axis.  None = the
                                     # single-device engines.
    mesh_collective: str = "dense"   # cross-shard Eq. (4) reduction:
                                     # "dense" psum (exact) or "sparse"
                                     # compacted top-K channel exchange
                                     # (core/sparse_collective.py)
    mesh_keep_fraction: float = 1.0  # sparse collective buffer size:
                                     # K = ceil(C * fraction) channels per
                                     # shard on the wire
    robust_agg: str = "mean"         # Eq. (4) aggregation variant
                                     # (core/aggregation.py): "mean"
                                     # (default; bit-identical to the
                                     # plain engines), "trimmed[:beta]"
                                     # coordinate-wise trimmed mean, or
                                     # "clip[:factor]" per-client update
                                     # norm clipping.  Engine-backed
                                     # paths only.
    checkpoint_every: Optional[int] = None
                                     # crash-resume (repro.checkpoint):
                                     # snapshot the full RunState every K
                                     # completed rounds.  None (default)
                                     # = no checkpointing, bit for bit.
    checkpoint_path: Optional[str] = None
                                     # where the RunState snapshot lands
                                     # (atomic temp+rename; one file pair,
                                     # overwritten each save)
    resume_from: Optional[str] = None
                                     # path of a RunState snapshot to
                                     # restart from; the run continues at
                                     # the snapshot's round + 1 with
                                     # bit-identical history
    population: Optional[int] = None
                                     # population-scale serving
                                     # (repro.population): the registered
                                     # client population this run samples
                                     # cohorts from.  The sim entry points
                                     # take the Population OBJECT and
                                     # record its size here; None = the
                                     # fleet IS the population (default).
    cohort_size: Optional[int] = None
                                     # clients materialized per round in
                                     # population mode (None with
                                     # population set = the whole
                                     # population — the identity
                                     # configuration)

    def __post_init__(self):
        if self.scheme not in ("feddd", "fedavg", "fedcs", "oort"):
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.allocator not in ALLOCATORS:
            raise ValueError(f"unknown allocator {self.allocator!r}; "
                             f"expected one of {ALLOCATORS}")
        if self.rounds_per_dispatch < 1:
            raise ValueError("rounds_per_dispatch must be >= 1, got "
                             f"{self.rounds_per_dispatch}")
        if self.rounds_per_dispatch > 1 and self.allocator != "jax":
            raise ValueError(
                "rounds_per_dispatch > 1 scans the dropout-rate allocation "
                "inside the device step and therefore requires "
                "allocator='jax' (the numpy LP cannot be traced)")
        if self.comm.overhead_aware_allocation and self.allocator != "numpy":
            raise ValueError(
                "comm.overhead_aware_allocation is a host-side fixed point "
                "around the numpy LP; it requires allocator='numpy' (and "
                "therefore cannot ride rounds_per_dispatch > 1)")
        if self.mesh is not None and self.rounds_per_dispatch > 1:
            raise ValueError(
                "mesh (client-sharded SPMD) and rounds_per_dispatch > 1 "
                "are mutually exclusive: the multi-round lax.scan carries "
                "single-device state")
        if self.mesh_collective not in ("dense", "sparse"):
            raise ValueError(f"mesh_collective must be 'dense' or "
                             f"'sparse', got {self.mesh_collective!r}")
        if not 0.0 < self.mesh_keep_fraction <= 1.0:
            raise ValueError(f"mesh_keep_fraction must be in (0,1], got "
                             f"{self.mesh_keep_fraction}")
        aggregation.parse_robust_agg(self.robust_agg)  # validate the spec
        if self.checkpoint_every is not None:
            if self.checkpoint_every < 1:
                raise ValueError("checkpoint_every must be >= 1 (or None "
                                 f"to disable), got {self.checkpoint_every}")
            if not self.checkpoint_path:
                raise ValueError("checkpoint_every requires "
                                 "checkpoint_path: somewhere for the "
                                 "RunState snapshot to land")
        if ((self.checkpoint_every is not None or self.resume_from)
                and self.rounds_per_dispatch > 1):
            raise ValueError(
                "checkpointing / resume operates at per-round dispatch "
                "boundaries; rounds_per_dispatch > 1 keeps rounds on the "
                "device inside one lax.scan and has no boundary to "
                "snapshot at")
        if self.cohort_size is not None and self.population is None:
            raise ValueError("cohort_size requires population= (the "
                             "fleet IS the cohort otherwise)")
        if self.population is not None:
            if self.population < 1:
                raise ValueError(f"population must be >= 1, got "
                                 f"{self.population}")
            k = self.cohort_size
            if k is not None and not 1 <= k <= self.population:
                raise ValueError(f"cohort_size {k} outside [1, "
                                 f"{self.population}]")


@dataclasses.dataclass
class ClientState:
    params: Params                   # W_n^t
    telemetry_idx: int               # row into the telemetry arrays
    num_samples: int
    mask: Optional[Params] = None    # M_n^t of the previous upload


@dataclasses.dataclass
class RoundRecord:
    """One round of history.  Two distinct time axes — do not conflate:

    * ``sim_time`` / ``sim_round_time`` are SIMULATED seconds, the paper's
      Eq. (12) clock: what the federated round *would* take on the modelled
      client links/CPUs.  Time-to-accuracy (Fig. 7) is measured on this
      axis.
    * ``host_wall_time`` is REAL seconds the host process spent computing
      the round (training + engine step) — a throughput measure of this
      implementation, never comparable to ``sim_time``.
    """

    round: int
    sim_time: float                  # cumulative simulated secs (Eq. 12)
    host_wall_time: float            # real host secs spent in this round
    mean_loss: float
    dropout_rates: np.ndarray        # rates allocated for the NEXT round
    uploaded_fraction: float         # raw kept bytes / full bytes
    participants: int
    sim_round_time: float = 0.0      # this round's simulated duration
    uploaded_bytes: float = 0.0      # raw kept-parameter mass (density x U)
    wire_bytes: float = 0.0          # actual on-wire uplink bytes: values
                                     # at the codec's precision + measured
                                     # mask/scale overhead (repro.comm).
                                     # == uploaded_bytes with the default
                                     # CommConfig, bit for bit.
    epsilon: Optional[float] = None
    metrics: Optional[Dict] = None
    # --- failure-model fields (repro.sim.faults); the defaults describe
    # a fault-free round, so pre-fault histories are unchanged.
    survivors: int = -1              # clients alive on the round clock
                                     # (scheduled minus crashed; -1 when
                                     # the driver does not track it)
    retries: int = 0                 # uplink chunk retransmits this round
    abandoned_bytes: float = 0.0     # wire bytes sent but never used:
                                     # crashed/aborted/cut transfers,
                                     # quorum-discarded arrivals
    quarantined_bytes: float = 0.0   # wire bytes of arrivals the payload
                                     # validation screened out of Eq. (4)
    skipped: bool = False            # quorum miss: global held, no step


@dataclasses.dataclass
class RunResult:
    history: List[RoundRecord]
    global_params: Params

    def time_to_accuracy(self, target: float, key: str = "accuracy"
                         ) -> Optional[float]:
        for rec in self.history:
            if rec.metrics and rec.metrics.get(key, -1.0) >= target:
                return rec.sim_time
        return None


def _tree_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params))


class _RoundData(NamedTuple):
    """What one executed round reports back to the shared driver loop."""

    losses: np.ndarray               # server-side loss view after the round
    uploaded_bytes: float            # raw kept bytes uploaded this round
    active: np.ndarray               # (N,) bool: clients on the Eq. (12) clock
    epsilon: Optional[float]         # Assumption-3 estimate (loop only)
    wire_bytes: float                # on-wire bytes (== uploaded_bytes for
                                     # the default CommConfig)


class _RoundExecutor:
    """One round-execution strategy.

    The server's :meth:`FedDDServer.run` owns everything scheme-agnostic —
    the RNG schedule, the allocation LP, the Eq. (12) clock, and history —
    and delegates the round's device math (training dispatch, masks,
    aggregation, client updates) to one of these.  All strategies implement
    the identical Algorithm-1 maths; the engine-backed ones are pinned
    bit-identical (feddd) / float-close (baselines) to the reference loop.
    """

    def __init__(self, server: "FedDDServer", local_train_fn,
                 batched_train_fn):
        self.srv = server
        self.local_train_fn = local_train_fn
        self.batched_train_fn = batched_train_fn

    def run_round(self, t: int, rk: jax.Array, losses: np.ndarray,
                  d_used: np.ndarray) -> _RoundData:
        raise NotImplementedError

    def finalize(self) -> None:
        """Sync any executor-held client state back into server.clients."""

    # -- crash-resume hooks (repro.checkpoint) ------------------------------

    def snapshot_arrays(self):
        """The executor-held client state as a checkpointable pytree."""
        raise NotImplementedError(
            "checkpointing / resume supports the batched-engine and "
            "reference-loop executors; grouped and sharded runs hold "
            "per-group / per-shard device state this snapshot does not "
            "capture yet")

    def restore_arrays(self, arrays) -> None:
        raise NotImplementedError


class _EngineExecutor(_RoundExecutor):
    """Homogeneous fleets: one BatchedRoundEngine jit step per round.

    Client state stays STACKED across rounds (lazy device slices feed the
    per-client python trainer; nothing re-stacks the old params) and syncs
    back into ``server.clients`` on :meth:`finalize`.  Baselines run in
    ``dense_masks`` mode with non-participation as a 0 aggregation weight.
    With ``batched_train_fn`` local training fuses into the device side too;
    for baselines the vmapped trainer runs every row, so non-participants'
    results are masked back to their stale params/losses — reported losses
    and the aggregate reflect actual participation.
    """

    def __init__(self, server, local_train_fn, batched_train_fn):
        super().__init__(server, local_train_fn, batched_train_fn)
        self.engine = round_engine.BatchedRoundEngine(
            server.cfg.selection, server.cfg.comm,
            robust_agg=server.cfg.robust_agg)
        self.weights = np.asarray(
            [cs.num_samples for cs in server.clients], float)
        self.stacked = round_engine.stack_pytrees(
            [cs.params for cs in server.clients])

    def run_round(self, t, rk, losses, d_used) -> _RoundData:
        srv, cfg = self.srv, self.srv.cfg
        obs = srv.obs
        n = srv.tel.num_clients
        dense = cfg.scheme != "feddd"
        part = (np.ones(n, bool) if not dense
                else srv._participants(losses))
        with obs.span("local_train", round=t):
            if self.batched_train_fn is not None:
                stacked_new, loss_dev = self.batched_train_fn(self.stacked,
                                                              rk)
                if dense:
                    # Non-participants must not train this round: keep
                    # their stale params out of the aggregate and their
                    # stale losses in the server's view (the vmapped
                    # trainer computed every row; participation masks the
                    # results).
                    pvec = jnp.asarray(part)
                    stacked_new = jax.tree_util.tree_map(
                        lambda new, old: jnp.where(
                            pvec.reshape((-1,) + (1,) * (new.ndim - 1)),
                            new, old),
                        stacked_new, self.stacked)
                    loss_dev = jnp.where(pvec, jnp.asarray(loss_dev),
                                         jnp.asarray(losses))
            else:
                per_client = round_engine.unstack_pytree(self.stacked, n)
                new_list: List[Params] = [None] * n
                loss_dev: List = [None] * n
                for i, p_i in enumerate(per_client):
                    if part[i]:
                        p, l = self.local_train_fn(
                            p_i, i, jax.random.fold_in(rk, i))
                    else:       # baseline non-participant: stale state
                        p, l = p_i, losses[i]
                    new_list[i] = p
                    loss_dev[i] = l
                stacked_new = round_engine.stack_pytrees(new_list)
        with obs.span("engine_step", round=t):
            out = self.engine.step(self.stacked, stacked_new,
                                   srv.global_params, d_used,
                                   self.weights * part, rk,
                                   full_round=(t % cfg.h == 0) or dense,
                                   dense_masks=dense)
        srv.global_params = out.global_params
        self.stacked = out.client_params
        # the ONE device->host transfer of the round (wire_overhead is
        # None with the default comm config — no extra sync either way)
        with obs.span("host_transfer", round=t):
            dens, oh, loss_host = jax.device_get(
                (out.densities, out.wire_overhead, loss_dev))
        new_losses = np.asarray(loss_host, float)
        uploaded, wire = account_uplink(dens, part, srv.tel.model_bytes,
                                        oh, cfg.comm, obs=obs)
        return _RoundData(new_losses, uploaded, part, None, wire)

    def finalize(self) -> None:
        n = self.srv.tel.num_clients
        for cs, p in zip(self.srv.clients,
                         round_engine.unstack_pytree(self.stacked, n)):
            cs.params = p

    def snapshot_arrays(self):
        return {"stacked": self.stacked}

    def restore_arrays(self, arrays) -> None:
        self.stacked = jax.tree_util.tree_map(jnp.asarray,
                                              arrays["stacked"])

    # -- multi-round scanned dispatch (rounds_per_dispatch > 1) -------------

    def run_chunk(self, t_start: int, count: int,
                  losses: np.ndarray) -> round_engine.ScanTrace:
        """Run rounds ``t_start .. t_start+count-1`` as ONE lax.scan
        dispatch (:meth:`BatchedRoundEngine.run`), rebinding the stacked
        client state / global params / PRNG key from the final carry and
        returning the host-fetched :class:`ScanTrace` — the chunk's single
        device->host transfer.  The scanned carry donates BOTH model
        buffers (stacked client params and the global params — in-place
        updates where the backend supports donation); the user-provided
        global pytree is copied once before the first chunk so donation
        never invalidates caller-held arrays.
        """
        srv, cfg = self.srv, self.srv.cfg
        if not hasattr(self, "_scan_static"):
            # own the global params before the first donating dispatch:
            # the executor's carry must not alias the caller's pytree
            srv.global_params = jax.tree_util.tree_map(
                lambda x: jnp.array(x, copy=True), srv.global_params)
            # static per run: the staged telemetry, the loss-independent
            # fedcs selection, and oort's system penalty / byte budget
            static_part, pen, budget = None, None, 0.0
            if cfg.scheme == "fedcs":
                static_part = baselines.select_fedcs(srv.tel,
                                                     a_server=cfg.a_server)
            elif cfg.scheme == "oort":
                pen = baselines.oort_system_penalty(srv.tel)
                budget = cfg.a_server * float(np.sum(srv.tel.model_bytes))
            self._scan_static = (
                round_engine.ScanTelemetry.from_host(srv.tel),
                static_part, pen, budget)
        scan_tel, static_part, pen, budget = self._scan_static
        state = round_engine.ScanState(
            client_params=self.stacked,
            global_params=srv.global_params,
            losses=jnp.asarray(losses, jnp.float32),
            dropout=jnp.asarray(srv.dropout, jnp.float32),
            rng=srv.rng,
            sim_time=jnp.zeros((), jnp.float32))
        out, trace = self.engine.run(
            state, scan_tel, num_rounds=count,
            batched_train_fn=self.batched_train_fn, weights=self.weights,
            h=cfg.h, a_server=cfg.a_server, d_max=cfg.d_max,
            delta=cfg.delta,
            global_model_bytes=_tree_bytes(srv.global_params),
            t_start=t_start, scheme=cfg.scheme,
            static_participants=static_part, oort_penalty=pen,
            oort_budget=budget)
        self.stacked = out.client_params
        srv.global_params = out.global_params
        srv.rng = out.rng
        with srv.obs.span("host_transfer", round=t_start):
            return jax.device_get(trace)


class _ShardedEngineExecutor(_EngineExecutor):
    """Homogeneous fleets over a 1-D ``clients`` device mesh: one
    ShardedRoundEngine ``shard_map`` step per round.

    Identical driver flow to :class:`_EngineExecutor` (it inherits
    ``run_round``); only the engine changes — each device owns N/P client
    rows, and the Eq. (4) reduction is the single cross-device exchange
    (dense psum, or the compacted top-K collective of
    core/sparse_collective.py).  The persistent stacked state is placed on
    its shards once, so per-round dispatches never re-shard host arrays;
    with ``batched_train_fn`` the jitted trainer picks the sharding up
    from its inputs and trains shard-local too (GSPMD propagation).
    """

    def __init__(self, server, local_train_fn, batched_train_fn):
        super().__init__(server, local_train_fn, batched_train_fn)
        from repro.launch.mesh import resolve_client_mesh  # launch -> core
        cfg = server.cfg
        mesh = resolve_client_mesh(cfg.mesh)
        self.engine = round_engine.ShardedRoundEngine(
            cfg.selection, cfg.comm, mesh=mesh,
            collective=cfg.mesh_collective,
            keep_fraction=cfg.mesh_keep_fraction,
            robust_agg=cfg.robust_agg)
        n = server.tel.num_clients
        if n % self.engine.num_shards == 0:
            self.stacked = jax.device_put(self.stacked,
                                          self.engine.shard_spec())
        self._spec = WireSpec.from_params(server.global_params,
                                          cfg.selection.channel_axis)

    def run_round(self, t, rk, losses, d_used):
        data = super().run_round(t, rk, losses, d_used)
        # cross-device Eq. (4) bytes: the analytic model of this round's
        # one collective, through the shared accounting hook (host-side
        # arithmetic only — no extra device syncs)
        account_collective(
            self._spec, self.engine.num_shards,
            mode=self.srv.cfg.mesh_collective,
            k_fraction=self.srv.cfg.mesh_keep_fraction, obs=self.srv.obs)
        return data

    def run_chunk(self, t_start, count, losses):
        raise ValueError("rounds_per_dispatch > 1 does not shard "
                         "(ProtocolConfig rejects the combination)")

    def snapshot_arrays(self):
        # placed-on-mesh state would need re-sharding on restore; fall
        # back to the base "unsupported" signal
        return _RoundExecutor.snapshot_arrays(self)


class _GroupedEngineExecutor(_RoundExecutor):
    """Ragged fleets: one GroupedRoundEngine jit step per round.

    Clients are partitioned by sub-model shape (repro.fl.heterogeneity
    .group_by_shape); each group's state stays stacked across rounds.
    Coverage pytrees are computed once per group (members share widths, so
    they share the CR slice) and the per-client mask keys fold the members'
    FLEET positions — grouped rounds are bit-identical to the per-client
    reference loop (tests/test_grouped_engine.py).
    """

    def __init__(self, server, local_train_fn, batched_train_fn):
        super().__init__(server, local_train_fn, batched_train_fn)
        from repro.fl.heterogeneity import group_by_shape  # fl -> core dep
        cfg = server.cfg
        self.weights = np.asarray(
            [cs.num_samples for cs in server.clients], float)
        client_params = [cs.params for cs in server.clients]
        groups = group_by_shape(client_params)
        coverage = [
            cov_mod.coverage_pytree(client_params[g.indices[0]],
                                    server.cr, cfg.selection.channel_axis)
            for g in groups
        ]
        mesh = None
        if cfg.mesh is not None:
            from repro.launch.mesh import resolve_client_mesh
            if cfg.mesh_collective != "dense":
                raise ValueError(
                    "sparse cross-device compaction rides the homogeneous "
                    "sharded engine; ragged (grouped) fleets reduce with "
                    "the dense psum collective")
            mesh = resolve_client_mesh(cfg.mesh)
        self.fleet = round_engine.GroupedFleetState(
            groups, coverage, client_params, cfg.selection,
            server.tel.num_clients, cfg.comm, mesh=mesh,
            robust_agg=cfg.robust_agg)

    def run_round(self, t, rk, losses, d_used) -> _RoundData:
        srv, cfg = self.srv, self.srv.cfg
        obs = srv.obs
        n = srv.tel.num_clients
        dense = cfg.scheme != "feddd"
        part = (np.ones(n, bool) if not dense
                else srv._participants(losses))
        with obs.span("local_train", round=t):
            loss_dev = self.fleet.train(self.local_train_fn, rk, part,
                                        losses, d_used, dense=dense)
        with obs.span("engine_step", round=t):
            srv.global_params, densities, wire_oh = self.fleet.step(
                srv.global_params, self.weights * part, rk,
                full_round=(t % cfg.h == 0) or dense, dense=dense)
        with obs.span("host_transfer", round=t):
            dens, oh, loss_host = jax.device_get(
                (densities, wire_oh, loss_dev))
        new_losses = np.asarray(loss_host, float)
        uploaded, wire = account_uplink(dens, part, srv.tel.model_bytes,
                                        oh, cfg.comm, obs=obs)
        return _RoundData(new_losses, uploaded, part, None, wire)

    def finalize(self) -> None:
        for cs, p in zip(self.srv.clients, self.fleet.export()):
            cs.params = p


class _ReferenceLoopExecutor(_RoundExecutor):
    """The per-client Python loop — Algorithm 1 verbatim.

    Kept as the bit-exactness oracle for both engines, and as the only
    path producing the per-client mask pytrees ``track_epsilon`` needs.
    Slow by design: per-client build_masks dispatches, per-leaf ``float``
    host syncs, list-based padding and aggregation.
    """

    def snapshot_arrays(self):
        return {"clients": [cs.params for cs in self.srv.clients]}

    def restore_arrays(self, arrays) -> None:
        for cs, p in zip(self.srv.clients, arrays["clients"]):
            cs.params = jax.tree_util.tree_map(jnp.asarray, p)

    def run_round(self, t, rk, losses, d_used) -> _RoundData:
        srv, cfg = self.srv, self.srv.cfg
        obs = srv.obs
        n = srv.tel.num_clients
        losses = losses.copy()
        part = srv._participants(losses)
        eps_val = None

        # --- Step 1: local training (participants only for baselines;
        # in FedDD everyone trains — that is the paper's key point).
        new_params: List[Params] = [None] * n
        with obs.span("local_train", round=t):
            for i, cs in enumerate(srv.clients):
                if cfg.scheme == "feddd" or part[i]:
                    p, l = self.local_train_fn(cs.params, i,
                                               jax.random.fold_in(rk, i))
                    new_params[i] = p
                    losses[i] = float(l)

        # --- Steps 2-3: mask building + (simulated) upload.  Per-client
        # densities / wire overheads collect into vectors so the byte
        # accounting below runs through the ONE shared reduction
        # (repro.comm.payload.account_uplink) every executor uses.
        densities = np.zeros(n)
        wire_oh = (None if cfg.comm.is_default else np.zeros(n))
        client_masks: List[Params] = [None] * n
        with obs.span("encode", round=t):
            if cfg.scheme == "feddd":
                for i, cs in enumerate(srv.clients):
                    cov = (cov_mod.coverage_pytree(
                               cs.params, srv.cr,
                               cfg.selection.channel_axis)
                           if srv.heterogeneous else None)
                    m = selection.build_masks(
                        cs.params, new_params[i],
                        jnp.asarray(d_used[i], jnp.float32),
                        config=cfg.selection, coverage=cov,
                        rng=jax.random.fold_in(rk, 10_000 + i))
                    client_masks[i] = m
                    densities[i] = float(
                        selection.mask_density(new_params[i], m))
            else:
                for i in range(n):
                    if part[i]:
                        client_masks[i] = jax.tree_util.tree_map(
                            lambda w: jnp.ones((1,) * w.ndim, w.dtype),
                            new_params[i])
                        densities[i] = 1.0
            uploads = np.asarray([m is not None for m in client_masks])
            if wire_oh is not None:
                for i in np.flatnonzero(uploads):
                    # baseline full uploads carry collapsed all-ones
                    # masks; their overhead is the closed-form full-upload
                    # constant at true widths (the engines charge the
                    # same)
                    wire_oh[i] = (
                        wire_codecs.mask_overhead_bytes(
                            client_masks[i], new_params[i], cfg.comm)
                        if cfg.scheme == "feddd" else
                        wire_codecs.full_upload_overhead_bytes(
                            srv.wire_specs[i], cfg.comm))

        # --- Step 4: aggregation (over uploaded clients only).  The
        # server aggregates what it DECODED: with qbits < 32 the uploads
        # are quantize->dequantized per client (same PRNG fold as the
        # engines — repro.comm.quantize); Eq. (5)/(6) below keep each
        # client's own full-precision params.
        idxs = [i for i in range(n) if client_masks[i] is not None]
        with obs.span("aggregate", round=t):
            agg_src = {
                i: (new_params[i] if cfg.comm.qbits == 32 else
                    wire_quant.quantize_dequantize(
                        new_params[i], wire_quant.client_quant_key(rk, i),
                        cfg.comm.qbits))
                for i in idxs
            }
            agg_params = [srv._pad_to_global(agg_src[i], i) for i in idxs]
            agg_masks = [srv._pad_mask_to_global(client_masks[i],
                                                 new_params[i])
                         for i in idxs]
            agg_weights = [srv.clients[i].num_samples for i in idxs]
            if cfg.track_epsilon:
                eps_val = float(estimate_epsilon(agg_params, agg_masks))
            srv.global_params = aggregation.aggregate_sparse(
                agg_params, agg_masks, agg_weights,
                prev_global=srv.global_params)

        # --- Steps 6-7: download + local model update
        full_round = (t % cfg.h == 0) or cfg.scheme != "feddd"
        with obs.span("client_update", round=t):
            for i, cs in enumerate(srv.clients):
                if new_params[i] is None:  # non-participant (baselines)
                    if full_round:
                        cs.params = srv._slice_to_local(cs.params)
                    continue
                if full_round or client_masks[i] is None:
                    cs.params = srv._slice_to_local(new_params[i],
                                                    use_global=True)
                else:
                    g_local = srv._slice_like(srv.global_params,
                                              new_params[i])
                    cs.params = aggregation.client_update_sparse(
                        g_local, new_params[i], client_masks[i])

        uploaded, wire = account_uplink(densities, uploads,
                                        srv.tel.model_bytes, wire_oh,
                                        cfg.comm, obs=obs)
        active = (np.ones(n, bool) if cfg.scheme == "feddd" else part)
        return _RoundData(losses, uploaded, active, eps_val, wire)


class FedDDServer:
    """Parameter server for FedDD + the three baselines."""

    def __init__(self, global_params: Params, cfg: ProtocolConfig,
                 telemetry: ClientTelemetry,
                 client_params: Optional[Sequence[Params]] = None):
        self.cfg = cfg
        self.global_params = global_params
        self.tel = telemetry
        n = telemetry.num_clients
        # heterogeneous models: clients may hold pruned sub-models
        if client_params is None:
            client_params = [global_params] * n
        self.clients = [
            ClientState(params=jax.tree_util.tree_map(jnp.asarray, p),
                        telemetry_idx=i,
                        num_samples=int(telemetry.num_samples[i]))
            for i, p in enumerate(client_params)
        ]
        full_w = cov_mod.channel_widths(global_params,
                                        cfg.selection.channel_axis)
        cw = [cov_mod.channel_widths(p, cfg.selection.channel_axis)
              for p in client_params]
        self.cr = cov_mod.coverage_rates(cw, full_w)
        self.heterogeneous = any(w != full_w for w in cw)
        # static per-client wire-format shape specs (repro.comm): the
        # analytic byte model behind the Eq. (12) uplink charge and the
        # overhead-aware allocation
        self.wire_specs = [
            WireSpec.from_params(p, cfg.selection.channel_axis)
            for p in client_params
        ]
        self.dropout = np.zeros(n)           # D_n^1 = 0 (Algorithm 1)
        self.rng = jax.random.PRNGKey(cfg.seed)
        # observability hook: inert singleton until run() builds a live
        # recorder for an active cfg.obs (repro.obs)
        self.obs = obs_mod.NULL_RECORDER

    # -- per-round server logic ---------------------------------------------

    def allocate(self, losses: np.ndarray) -> AllocationResult:
        tel = dataclasses.replace(self.tel, train_loss=losses)
        if self.cfg.comm.overhead_aware_allocation:
            return solve_dropout_rates_overhead_aware(
                tel, self.wire_specs, comm=self.cfg.comm,
                a_server=self.cfg.a_server, d_max=self.cfg.d_max,
                delta=self.cfg.delta,
                global_model_bytes=_tree_bytes(self.global_params))
        return solve_dropout_rates_with(
            self.cfg.allocator, tel,
            a_server=self.cfg.a_server, d_max=self.cfg.d_max,
            delta=self.cfg.delta,
            global_model_bytes=_tree_bytes(self.global_params))

    def _participants(self, losses: np.ndarray) -> np.ndarray:
        if self.cfg.scheme == "fedavg":
            return baselines.select_fedavg(self.tel)
        if self.cfg.scheme == "fedcs":
            return baselines.select_fedcs(self.tel,
                                          a_server=self.cfg.a_server)
        if self.cfg.scheme == "oort":
            tel = dataclasses.replace(self.tel, train_loss=losses)
            return baselines.select_oort(tel, a_server=self.cfg.a_server)
        return np.ones(self.tel.num_clients, bool)   # feddd: everyone

    # -- executor routing -----------------------------------------------------

    def _executor_kind(self, batched_train_fn) -> str:
        """Route a run to its executor (see the module routing table).

        ``track_epsilon`` needs the reference loop's per-client mask
        pytrees; ``batched=False`` forces the loop as the oracle.  A
        homogeneous engine run may fuse training via ``batched_train_fn``
        (any scheme — baselines mask non-participants); the grouped and
        loop paths cannot accept it (client data shards are ragged /
        per-client by construction).
        """
        if self.cfg.track_epsilon or not self.cfg.batched:
            kind = "loop"
        elif self.heterogeneous:
            kind = "grouped"
        else:
            kind = "engine"
        if self.cfg.mesh is not None:
            if kind == "loop":
                raise ValueError(
                    "mesh (client-sharded SPMD) requires engine-backed "
                    "execution; track_epsilon / batched=False route to "
                    "the per-client reference loop, which does not shard")
            if kind == "engine":
                kind = "sharded"
            # grouped: the GroupedRoundEngine itself shards each group's
            # member axis when cfg.mesh is set (see _GroupedEngineExecutor)
        if batched_train_fn is not None and kind not in ("engine",
                                                         "sharded"):
            raise ValueError(
                "batched_train_fn requires a homogeneous run with "
                "batched=True and track_epsilon=False")
        if str(self.cfg.robust_agg) != "mean" and kind == "loop":
            raise ValueError(
                "robust_agg variants are fused into the engine-backed "
                "stacked Eq. (4) step; the reference loop aggregates "
                "per-client lists with the plain weighted mean (run with "
                "batched=True and track_epsilon=False)")
        return kind

    _EXECUTORS = {"engine": _EngineExecutor,
                  "sharded": _ShardedEngineExecutor,
                  "grouped": _GroupedEngineExecutor,
                  "loop": _ReferenceLoopExecutor}

    @property
    def executor_kind(self) -> str:
        """The executor a plain ``run(local_train_fn)`` will route to —
        "engine" (homogeneous batched), "grouped" (ragged fleet), or
        "loop" (the per-client reference)."""
        return self._executor_kind(None)

    # -- the full run ---------------------------------------------------------

    def run(self,
            local_train_fn: Optional[Callable[[Params, int, jax.Array],
                                              "tuple[Params, float]"]] = None,
            eval_fn: Optional[Callable[[Params], Dict]] = None,
            rounds: Optional[int] = None,
            batched_train_fn: Optional[Callable] = None) -> RunResult:
        """Run the protocol.

        Args:
          local_train_fn: per-client ``(params, client_idx, rng) ->
            (params, loss)`` — required unless ``batched_train_fn`` given.
          batched_train_fn: optional ``(stacked_params, rng) ->
            (stacked_params, (N,) losses)`` operating on client-STACKED
            pytrees; when provided (homogeneous engine runs only) local
            training fuses into the device-side round and client state
            stays stacked across rounds.
        """
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        n = self.tel.num_clients
        if local_train_fn is None and batched_train_fn is None:
            raise ValueError("need local_train_fn or batched_train_fn")
        losses = np.ones(n)
        sim_time = 0.0
        history: List[RoundRecord] = []
        full_bytes = float(np.sum(self.tel.model_bytes))

        kind = self._executor_kind(batched_train_fn)
        executor = self._EXECUTORS[kind](self, local_train_fn,
                                         batched_train_fn)

        if cfg.rounds_per_dispatch > 1:
            if kind != "engine":
                raise ValueError(
                    "rounds_per_dispatch > 1 requires the homogeneous "
                    "batched engine (batched=True, track_epsilon=False, "
                    f"homogeneous fleet); this run routes to {kind!r}")
            if batched_train_fn is None:
                raise ValueError(
                    "rounds_per_dispatch > 1 requires batched_train_fn: "
                    "local training must be device-fused for the round "
                    "loop to scan")
            if eval_fn is not None:
                raise ValueError(
                    "eval_fn evaluates every round on the host, but with "
                    "rounds_per_dispatch > 1 params only reach the host "
                    "at dispatch boundaries; use rounds_per_dispatch=1 "
                    "for per-round eval")

        # --- crash-resume (repro.checkpoint): restore a snapshot before
        # the loop, save one every checkpoint_every completed rounds.
        # checkpoint_every=None and resume_from=None touch nothing.
        start_t = 1
        if cfg.resume_from:
            from repro import checkpoint as ckpt_mod   # checkpoint -> core
            st = ckpt_mod.load_run_state(
                cfg.resume_from, self._snapshot_arrays(executor, losses))
            losses = self._restore_arrays(executor, st.arrays)
            history = st.history
            sim_time = float(st.extra.get("sim_time", 0.0))
            start_t = st.round + 1

        self.obs = obs_mod.make_recorder(
            cfg.obs, driver="protocol", scheme=cfg.scheme, executor=kind
            if cfg.rounds_per_dispatch == 1 else "scanned",
            clients=n, rounds=rounds)
        try:
            if cfg.rounds_per_dispatch > 1:
                self._run_scanned(executor, rounds, history, full_bytes)
                executor.finalize()
                return RunResult(history, self.global_params)

            for t in range(start_t, rounds + 1):
                t0 = time.perf_counter()
                self.rng, rk = jax.random.split(self.rng)
                d_used = self.dropout.copy()  # D_t: what uploads use

                rd = executor.run_round(t, rk, losses, d_used)
                losses = rd.losses

                # --- Step 5: dropout-rate allocation for round t+1
                if cfg.scheme == "feddd":
                    with self.obs.span("allocate", round=t):
                        alloc = self.allocate(np.maximum(losses, 1e-6))
                    self.dropout = alloc.dropout_rates

                # --- simulated wall clock (paper Eq. (12))
                sim_time, round_t, metrics, t_all = self._finish_round(
                    rd.active, sim_time, eval_fn, d_used)
                history.append(self._record(t, t0, sim_time, round_t,
                                            losses, rd.uploaded_bytes,
                                            rd.wire_bytes, full_bytes,
                                            rd.active, rd.epsilon,
                                            metrics))
                if self.obs.active:
                    self.obs.round(
                        history[-1], path=kind, scheme=cfg.scheme,
                        client_times=np.where(rd.active, t_all, np.nan))
                if (cfg.checkpoint_every is not None
                        and t % cfg.checkpoint_every == 0):
                    from repro import checkpoint as ckpt_mod
                    ckpt_mod.save_run_state(
                        cfg.checkpoint_path,
                        ckpt_mod.RunState(
                            round=t,
                            arrays=self._snapshot_arrays(executor, losses),
                            history=history,
                            extra={"sim_time": sim_time}))

            executor.finalize()
            return RunResult(history, self.global_params)
        finally:
            self.obs.close()
            self.obs = obs_mod.NULL_RECORDER

    # -- crash-resume snapshot plumbing (repro.checkpoint) -------------------

    def _snapshot_arrays(self, executor: _RoundExecutor,
                         losses: np.ndarray) -> Dict:
        """Everything round t+1 reads, as one checkpointable pytree.

        The executor contributes the client state it holds; the server
        adds the global params, the protocol PRNG key (split stream —
        uint32, persisted exactly), the loss view, and the allocated
        dropout rates D_{t+1}.  Fault/outage/network draws are keyed per
        epoch and replay free (see repro.checkpoint.run_state).
        """
        return {"executor": executor.snapshot_arrays(),
                "global": self.global_params,
                "rng": self.rng,
                "losses": np.asarray(losses, np.float64),
                "dropout": np.asarray(self.dropout, np.float64)}

    def _restore_arrays(self, executor: _RoundExecutor,
                        arrays: Dict) -> np.ndarray:
        """Inverse of :meth:`_snapshot_arrays`; returns the loss view."""
        executor.restore_arrays(arrays["executor"])
        self.global_params = jax.tree_util.tree_map(jnp.asarray,
                                                    arrays["global"])
        self.rng = jnp.asarray(arrays["rng"])
        self.dropout = np.asarray(arrays["dropout"], np.float64)
        return np.asarray(arrays["losses"], np.float64)

    def _run_scanned(self, executor: "_EngineExecutor", rounds: int,
                     history: List[RoundRecord], full_bytes: float) -> None:
        """Chunked multi-round execution: ``rounds_per_dispatch`` rounds
        per ``lax.scan`` device dispatch, spliced back into the per-round
        :class:`RoundRecord` stream.

        The scan carries the f32 device rendering of the round clock; the
        RECORDS recompute allocation clipping and the Eq. (12) clock
        host-side in float64 from the traced rates/participants — exactly
        the sequential driver's arithmetic — so a scanned history matches
        per-round dispatch bit for bit wherever the in-scan allocator
        does (always for the learning state; rates to f32-ulp scale —
        tests/test_round_engine.py).  ``host_wall_time`` is the chunk
        wall time amortised over its rounds (individual rounds are not
        host-observable by design).
        """
        cfg = self.cfg
        losses = np.ones(self.tel.num_clients)
        sim_time = 0.0
        t = 1
        while t <= rounds:
            k = min(cfg.rounds_per_dispatch, rounds - t + 1)
            t0 = time.perf_counter()
            with self.obs.span("chunk_dispatch", round=t):
                trace = executor.run_chunk(t, k, losses)
            wall = (time.perf_counter() - t0) / k
            tr_losses = np.asarray(trace.losses, float)
            tr_dens = np.asarray(trace.densities, float)
            tr_dnext = np.asarray(trace.next_dropout, np.float64)
            tr_part = np.asarray(trace.participants, bool)
            tr_oh = (None if trace.wire_overhead is None
                     else np.asarray(trace.wire_overhead))
            for j in range(k):
                d_used = self.dropout.copy()
                part = tr_part[j]
                losses = tr_losses[j]
                if cfg.scheme == "feddd":
                    # the sequential driver clips the device rates in
                    # float64 (solve_dropout_rates_with); replay that on
                    # the traced rates so records match bit for bit
                    self.dropout = np.clip(tr_dnext[j], 0.0, cfg.d_max)
                uploaded, wire = account_uplink(
                    tr_dens[j], part, self.tel.model_bytes,
                    None if tr_oh is None else tr_oh[j], cfg.comm,
                    obs=self.obs)
                sim_time, round_t, _, t_all = self._finish_round(
                    part, sim_time, None, d_used)
                history.append(RoundRecord(
                    round=t + j, sim_time=sim_time,
                    sim_round_time=round_t, host_wall_time=wall,
                    mean_loss=float(np.mean(losses)),
                    dropout_rates=self.dropout.copy(),
                    uploaded_fraction=uploaded / max(full_bytes, 1e-9),
                    uploaded_bytes=uploaded, wire_bytes=wire,
                    participants=int(np.sum(part)),
                    survivors=int(np.sum(part))))
                if self.obs.active:
                    self.obs.round(
                        history[-1], path="scanned", scheme=cfg.scheme,
                        client_times=np.where(part, t_all, np.nan))
            t += k

    def _record(self, t: int, t0: float, sim_time: float,
                sim_round_time: float, losses: np.ndarray,
                uploaded_bytes: float, wire_bytes: float, full_bytes: float,
                active: np.ndarray, eps_val: Optional[float],
                metrics: Optional[Dict]) -> RoundRecord:
        return RoundRecord(
            round=t, sim_time=sim_time, sim_round_time=sim_round_time,
            host_wall_time=time.perf_counter() - t0,
            mean_loss=float(np.mean(losses)),
            dropout_rates=self.dropout.copy(),
            uploaded_fraction=uploaded_bytes / max(full_bytes, 1e-9),
            uploaded_bytes=uploaded_bytes, wire_bytes=wire_bytes,
            participants=int(np.sum(active)),
            survivors=int(np.sum(active)),
            epsilon=eps_val, metrics=metrics)

    def _finish_round(self, active: np.ndarray, sim_time: float, eval_fn,
                      dropout_used: np.ndarray
                      ) -> "tuple[float, float, Optional[Dict], np.ndarray]":
        """Simulated wall clock (paper Eq. (12)) + optional eval.

        ``dropout_used`` is D_t — the rates this round's uploads actually
        used (NOT the freshly allocated D_{t+1}; the allocation for the
        next round happens before the clock update).

        With a non-default wire format the UPLINK leg charges the codec's
        analytic byte model (mask overhead + value precision,
        repro.comm.payload.analytic_wire_bytes) instead of the idealized
        ``U(1-D)``; the downlink broadcast stays idealized.

        Also returns ``t_all`` — the per-client Eq. (12) round times the
        max ran over; the recorder logs them (masked to active clients)
        as the straggler timeline.
        """
        d_for_time = (dropout_used if self.cfg.scheme == "feddd"
                      else np.zeros(self.tel.num_clients))
        up = (None if self.cfg.comm.is_default else
              analytic_uplink_vector(self.wire_specs, d_for_time,
                                     self.cfg.comm))
        t_all = baselines.round_times(self.tel, d_for_time,
                                      uplink_bytes=up)
        round_t = float(np.max(t_all[active]))
        sim_time += round_t
        if eval_fn:
            with self.obs.span("eval"):
                metrics = eval_fn(self.global_params)
        else:
            metrics = None
        return sim_time, round_t, metrics, t_all

    # -- heterogeneous-model plumbing  (HeteroFL-style width slicing) --------

    def _pad_to_global(self, params, client_idx):
        """Zero-pad a client sub-model up to global widths."""
        def _pad(p, g):
            if p.shape == g.shape:
                return p
            pads = [(0, gs - ps) for ps, gs in zip(p.shape, g.shape)]
            return jnp.pad(p, pads)
        return jax.tree_util.tree_map(_pad, params, self.global_params)

    def _pad_mask_to_global(self, masks, params):
        """Masks are channel-shaped; pad with zeros so padded (absent)
        channels never contribute to the aggregate."""
        def _pad(m, p, g):
            m_full = jnp.broadcast_to(m, p.shape)
            if p.shape == g.shape:
                return m_full
            pads = [(0, gs - ps) for ps, gs in zip(p.shape, g.shape)]
            return jnp.pad(m_full, pads)
        return jax.tree_util.tree_map(_pad, masks, params,
                                      self.global_params)

    def _slice_like(self, global_params, local_params):
        return round_engine.slice_pytree(global_params, local_params)

    def _slice_to_local(self, local_params, use_global: bool = True):
        src = self.global_params if use_global else local_params
        return self._slice_like(src, local_params)


def run_scheme(scheme: str, global_params, telemetry, local_train_fn,
               eval_fn=None, client_params=None, *, sim=None, network=None,
               faults=None, population=None, cohort_size=None,
               **cfg_kw) -> RunResult:
    """One-call convenience wrapper used by benchmarks and examples.

    Passing ``sim`` (a :class:`repro.sim.runner.SimConfig`, or ``True``
    for defaults) and/or ``network`` (a :class:`repro.sim.network
    .NetworkModel`) routes the run through the event-driven simulator
    instead of the closed-form Eq. (12) clock: dynamic per-round network
    conditions, observed-telemetry LP re-solves, and sync / deadline /
    async aggregation policies.  ``faults`` (a
    :class:`repro.sim.faults.FaultModel`) additionally injects client
    crashes, lossy uplinks, and corrupted payloads, and enables the
    server's quarantine/quorum degradation (wave policies only; the
    async policy gets crash/loss + staleness-budget semantics, while
    corruption stays wave-only).  Ragged ``client_params`` fleets run
    the grouped engine on either path (see the routing table in the
    module docstring).

    The survivability knobs ride ``**cfg_kw`` onto either path:
    ``robust_agg=`` selects the Byzantine-robust Eq. (4) variant, and
    ``checkpoint_every=`` / ``checkpoint_path=`` / ``resume_from=``
    drive bit-identical crash-resume (repro.checkpoint).

    ``population`` (a :class:`repro.population.Population`) +
    ``cohort_size`` switch to population-scale serving: ``telemetry``
    covers the registered population and each round materializes only a
    sampled cohort (availability churn + samplers live on the Population
    object).  Population runs always route through the simulator.
    """
    if (sim is not None or network is not None or faults is not None
            or population is not None):
        from repro.sim import runner as sim_runner   # local: sim -> core
        if sim is None or sim is True:
            sim = sim_runner.SimConfig()
        return sim_runner.run_sim(scheme, global_params, telemetry,
                                  local_train_fn, eval_fn, sim=sim,
                                  network=network, faults=faults,
                                  client_params=client_params,
                                  population=population,
                                  cohort_size=cohort_size, **cfg_kw)
    cfg = ProtocolConfig(scheme=scheme, **cfg_kw)
    server = FedDDServer(global_params, cfg, telemetry, client_params)
    return server.run(local_train_fn, eval_fn)
