"""Parameter importance indices — FedDD §4.2, Eq. (20)/(21).

The index for channel/neuron ``k`` of a layer is

    I_n^k      = || dW * (W + dW) / W ||_(k)                (homogeneous)
    I~_n^k     = I_n^k / CR(k)                              (heterogeneous)

where the norm ``||.||_(k)`` groups parameters by output channel (row of a
dense matrix / output channel of a conv).  ``CR(k)`` is the coverage rate —
the fraction of clients whose local sub-model contains channel ``k``.

Conventions used throughout the code base:

* Every parameter tensor is viewed as ``(channels, fan_in...)``: for a dense
  kernel stored ``(in, out)`` we reduce over ``in`` (axis 0 is fan-in, the
  *output* dimension indexes channels);  utilities below take an explicit
  ``channel_axis``.
* A small ``eps`` guards the division by ``W`` (the paper implicitly assumes
  non-zero weights).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-8


def elementwise_importance(w_old: jax.Array, w_new: jax.Array,
                           eps: float = _EPS) -> jax.Array:
    """|dW * (W + dW) / W| per element (inner term of Eq. (20)).

    ``w_old`` is W_n^t (before local update), ``w_new`` is W_n^t + dW.
    """
    dw = w_new - w_old
    denom = jnp.where(jnp.abs(w_old) < eps,
                      jnp.where(w_old < 0, -eps, eps), w_old)
    return jnp.abs(dw * w_new / denom)


def channel_importance(w_old: jax.Array, w_new: jax.Array, *,
                       channel_axis: int = -1,
                       coverage: Optional[jax.Array] = None,
                       eps: float = _EPS) -> jax.Array:
    """Per-channel importance: L2 norm of elementwise importance over all
    non-channel axes, optionally divided by the coverage rate (Eq. (21)).

    Returns shape ``(num_channels,)``.
    """
    imp = elementwise_importance(w_old, w_new, eps)
    axes = tuple(a for a in range(imp.ndim)
                 if a != (channel_axis % imp.ndim))
    score = jnp.sqrt(jnp.sum(imp * imp, axis=axes))
    if coverage is not None:
        score = score / jnp.maximum(coverage, eps)
    return score


# --- batched (client-stacked) variants -------------------------------------
#
# The batched round engine stacks client parameters along a leading axis and
# scores every client's channels in one traced computation.  These mirror the
# per-client functions exactly: the reduction runs over the same per-client
# axes, so results are bit-identical to looping channel_importance over
# clients (the round-engine parity tests assert this).

def _leaf_axes(ndim: int, channel_axis: int):
    """Reduction axes of a (N, *leaf) stacked tensor: everything except the
    client axis (0) and the channel axis (shifted by the client axis)."""
    ax = channel_axis % (ndim - 1) + 1
    return ax, tuple(a for a in range(1, ndim) if a != ax)


def channel_importance_batched(w_old: jax.Array, w_new: jax.Array, *,
                               channel_axis: int = -1,
                               coverage: Optional[jax.Array] = None,
                               eps: float = _EPS) -> jax.Array:
    """Eq. (20)/(21) over a leading client axis: (N, *leaf) -> (N, C) fp32."""
    imp = elementwise_importance(w_old, w_new, eps)
    _, axes = _leaf_axes(imp.ndim, channel_axis)
    score = jnp.sqrt(jnp.sum(imp * imp, axis=axes))
    if coverage is not None:
        score = score / jnp.maximum(coverage, eps)
    return score


def channel_score_max_batched(w_old: jax.Array, w_new: jax.Array, *,
                              channel_axis: int = -1) -> jax.Array:
    del w_old
    _, axes = _leaf_axes(w_new.ndim, channel_axis)
    return jnp.sqrt(jnp.sum(w_new * w_new, axis=axes))


def channel_score_delta_batched(w_old: jax.Array, w_new: jax.Array, *,
                                channel_axis: int = -1) -> jax.Array:
    dw = w_new - w_old
    _, axes = _leaf_axes(dw.ndim, channel_axis)
    return jnp.sqrt(jnp.sum(dw * dw, axis=axes))


# --- ablation variants (paper §6.2 "FedDD w. X selection") -----------------

def channel_score_max(w_old: jax.Array, w_new: jax.Array, *,
                      channel_axis: int = -1) -> jax.Array:
    """'max selection': rank channels by parameter magnitude |W+dW|."""
    axes = tuple(a for a in range(w_new.ndim)
                 if a != (channel_axis % w_new.ndim))
    return jnp.sqrt(jnp.sum(w_new * w_new, axis=axes))


def channel_score_delta(w_old: jax.Array, w_new: jax.Array, *,
                        channel_axis: int = -1) -> jax.Array:
    """'delta selection' (Aji & Heafield): rank channels by |dW|."""
    dw = w_new - w_old
    axes = tuple(a for a in range(dw.ndim)
                 if a != (channel_axis % dw.ndim))
    return jnp.sqrt(jnp.sum(dw * dw, axis=axes))


def channel_score_random(key: jax.Array, num_channels: int) -> jax.Array:
    """'random selection': uniform random scores."""
    return jax.random.uniform(key, (num_channels,))


def channel_score_ordered(num_channels: int) -> jax.Array:
    """'ordered selection' (FjORD-style): a fixed prefix order — channel 0
    always most important."""
    return jnp.arange(num_channels, 0, -1).astype(jnp.float32)
