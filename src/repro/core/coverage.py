"""Coverage rates CR(k) for heterogeneous client models — FedDD §4.2.

When clients run sub-models pruned from a common full model (HeteroFL-style:
same layer structure, shrunk channel counts), a channel ``k`` of the full
model is *covered* by client ``n`` iff ``k < width_n(layer)``.  The server
computes CR(k) = (#clients covering k) / N once from the clients' reported
widths (first round: full upload) and broadcasts it.

In FedDD the importance index is divided by CR(k) (Eq. (21)) so that rarely-
covered channels are preferentially uploaded by the few clients that own
them, boosting global-model generalisation.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def channel_widths(params, channel_axis: int = -1) -> Dict[str, int]:
    """Map flattened leaf-path -> channel count for a parameter pytree."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = jax.tree_util.keystr(path)
        ax = channel_axis % max(leaf.ndim, 1)
        out[name] = int(leaf.shape[ax]) if leaf.ndim > 0 else 1
    return out


def coverage_rates(client_widths: Sequence[Dict[str, int]],
                   full_widths: Dict[str, int]) -> Dict[str, np.ndarray]:
    """CR per layer: for each full-model layer, a (full_width,) array of
    fractions of clients whose sub-model contains each channel.

    Clients that lack a layer entirely contribute zero coverage for it.
    """
    n = len(client_widths)
    out = {}
    for name, full_w in full_widths.items():
        counts = np.zeros(full_w, np.float32)
        for cw in client_widths:
            w = cw.get(name, 0)
            counts[: min(w, full_w)] += 1.0
        out[name] = counts / max(n, 1)
    return out


def coverage_pytree(params, cr_by_name: Dict[str, np.ndarray],
                    channel_axis: int = -1):
    """Build a pytree matching ``params``' structure whose leaves are the
    (client-local slice of the) coverage arrays, shaped (local_channels,)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        ax = channel_axis % max(leaf.ndim, 1)
        nch = int(leaf.shape[ax]) if leaf.ndim > 0 else 1
        cr = cr_by_name.get(name)
        if cr is None:
            leaves.append(jnp.ones(nch, jnp.float32))
        else:
            leaves.append(jnp.asarray(cr[:nch], jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
