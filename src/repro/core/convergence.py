"""Convergence diagnostics — FedDD §5 (Theorem 2).

Provides
  * an empirical estimator of the mask-induced aggregation error ``epsilon``
    of Assumption 3,
  * a numerical evaluator of the Theorem-2 bound (Eq. (22)) so benchmarks can
    check the qualitative predictions (residual error monotone in h and in
    epsilon; O(1/T) leading term),
  * the learning-rate feasibility condition eta < 2 / (L + L*eps + 4(eps+1)eps).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


def estimate_epsilon(client_params: Sequence, client_masks: Sequence) -> jax.Array:
    """Empirical Assumption-3 ratio:

        || masked_avg - plain_avg ||^2  /  || plain_avg ||^2

    computed over the flattened concatenation of all leaves (uniform client
    weighting, matching the assumption's statement).
    """
    n = len(client_params)
    num = 0.0
    den = 0.0
    nleaves = len(jax.tree_util.tree_leaves(client_params[0]))
    pl = [jax.tree_util.tree_leaves(p) for p in client_params]
    ml = [jax.tree_util.tree_leaves(m) for m in client_masks]
    for li in range(nleaves):
        stack = jnp.stack([pl[c][li].astype(jnp.float32) for c in range(n)])
        masks = jnp.stack([jnp.broadcast_to(ml[c][li], pl[c][li].shape)
                           .astype(jnp.float32) for c in range(n)])
        plain = jnp.mean(stack, axis=0)
        msum = jnp.sum(masks, axis=0)
        masked = jnp.sum(stack * masks, axis=0) / jnp.maximum(msum, 1e-12)
        masked = jnp.where(msum > 1e-12, masked, plain)
        num = num + jnp.sum((masked - plain) ** 2)
        den = den + jnp.sum(plain ** 2)
    return num / jnp.maximum(den, 1e-30)


def eta_max(L: float, eps: float) -> float:
    """Largest admissible learning rate of Theorem 2."""
    return 2.0 / (L + L * eps + 4.0 * (eps + 1.0) * eps)


@dataclasses.dataclass(frozen=True)
class BoundInputs:
    L: float          # smoothness
    eta: float        # learning rate
    eps: float        # Assumption-3 epsilon
    sigma_sq_mean: float   # (1/N) sum sigma_n^2
    f0_minus_fstar: float  # F(W^0) - F(W*)
    h: int            # full-broadcast period
    T: int            # total rounds (T = K*h)


def theorem2_bound(b: BoundInputs) -> float:
    """Numerical RHS of Eq. (22). Returns +inf if eta violates feasibility."""
    L, eta, eps, h = b.L, b.eta, b.eps, float(b.h)
    denom_core = (2.0 * eta - L * eta**2 - L * eps * eta**2
                  - 4.0 * (eps + 1.0) * eps * eta**2)
    if denom_core <= 0:
        return float("inf")
    term1 = 2.0 * b.f0_minus_fstar / (b.T * denom_core)
    poly = (2.0 * eps + 2.0 * eps * eta**2 * L**2
            + 2.0 * eta**2 * L**2 + 3.0)
    term2 = (L * eps * eta**2 * b.sigma_sq_mean * (h - 1.0) * poly
             / (h * denom_core))
    term3 = L * eps * eta**2 * b.sigma_sq_mean / (h * denom_core)
    return term1 + term2 + term3


def residual_error(b: BoundInputs) -> float:
    """Terms 2+3 of Eq. (22) (the non-vanishing residual)."""
    full = theorem2_bound(b)
    if full == float("inf"):
        return full
    t1 = theorem2_bound(dataclasses.replace(b, eps=0.0, T=b.T))
    return full - t1
