"""Sparse global aggregation and local-model update rules — FedDD Eq. (4)-(6).

Step 4 (server):      W^t     = sum_n m_n * What_n ⊙ M_n  /  sum_n m_n * M_n
Step 7 (client, t mod h != 0): W_n^{t+1} = W^t ⊙ M_n + What_n ⊙ (1 - M_n)
Step 7 (client, t mod h == 0): W_n^{t+1} = W^t

Element-wise division: positions received from NO client keep the previous
global value (the paper's Eq. (4) is undefined there; keeping W^{t-1} is the
natural continuous extension and is what makes the h-periodic broadcast
meaningful).

Byzantine-robust variants (``robust=`` on the stacked/grouped entry
points, routed from ``ProtocolConfig.robust_agg``): the masked mean of
Eq. (4) is a weighted average, so a single corrupt-but-finite client can
drag every coordinate it uploads arbitrarily far.  Two standard
hardenings, both fused into the same jitted aggregation step:

* ``"trimmed[:beta]"`` — coordinate-wise trimmed mean (Yin et al.,
  1803.01498, adapted to masked/weighted sparse uploads): per
  coordinate, among the clients that actually uploaded it with positive
  weight, drop the ``floor(beta * n_valid)`` largest and smallest values
  and weighted-average the rest (default beta 0.1).  A coordinate left
  with no survivors falls back to the previous global, like an
  un-uploaded position.
* ``"clip[:factor]"`` — per-client norm clipping: each client's masked
  update ``(What_n - W^{t-1}) ⊙ M_n`` is scaled down to at most
  ``factor`` x the median participant update norm (default factor 1.0)
  before the standard Eq. (4) mean.  Requires ``prev_global``.

``"mean"`` (the default) takes the EXACT pre-existing code path — the
bit-identity contract tests/test_robust_agg.py pins on all engines.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12

ROBUST_AGGS = ("mean", "trimmed", "clip")


def parse_robust_agg(spec: Optional[str]) -> Tuple[str, float]:
    """``"mean" | "trimmed[:beta]" | "clip[:factor]"`` -> (kind, param).

    The spec stays a plain string end-to-end (hashable, so it can ride
    through jit static args and lru_cache keys unchanged); parameters are
    parsed here at trace time.
    """
    if spec is None:
        spec = "mean"
    name, _, arg = str(spec).partition(":")
    if name == "mean":
        if arg:
            raise ValueError("robust_agg 'mean' takes no parameter")
        return "mean", 0.0
    if name == "trimmed":
        beta = float(arg) if arg else 0.1
        if not 0.0 <= beta < 0.5:
            raise ValueError(f"trimmed beta must be in [0,0.5), got {beta}")
        return "trimmed", beta
    if name == "clip":
        c = float(arg) if arg else 1.0
        if c <= 0.0:
            raise ValueError(f"clip factor must be > 0, got {c}")
        return "clip", c
    raise ValueError(f"unknown robust_agg {spec!r} — expected one of "
                     f"{ROBUST_AGGS} (optionally 'trimmed:<beta>' / "
                     "'clip:<factor>')")


def leaf_masked_partials(stack_w: jax.Array, stack_m: jax.Array,
                         w: jax.Array, use_kernel: bool = False):
    """Eq. (4) numerator/denominator partials for one client-stacked leaf.

    (N, *leaf) -> (num (*leaf,) f32, den (*leaf,) f32).  Split out of
    :func:`_leaf_masked_mean` so the client-sharded engine can reduce the
    SAME partial sums across shards (psum / compacted all-gather) before
    :func:`finish_masked_mean` — on one shard the composition is, by
    construction, arithmetic-identical to the fused single-device path.
    """
    n = stack_w.shape[0]
    if use_kernel and stack_w.ndim >= 2 and stack_w.size >= 1024:
        from repro.kernels.sparse_agg import ops as agg_ops
        return agg_ops.masked_weighted_sum(stack_w, stack_m, w)
    wts = w.reshape((n,) + (1,) * (stack_w.ndim - 1))
    num = jnp.sum(stack_w.astype(jnp.float32) * stack_m * wts, axis=0)
    den = jnp.sum(stack_m * wts, axis=0)
    return num, den


def finish_masked_mean(num: jax.Array, den: jax.Array, gprev,
                       dtype) -> jax.Array:
    """Eq. (4) division + prev-global fill over reduced (num, den)."""
    agg = num / jnp.maximum(den, _EPS)
    if gprev is not None:
        agg = jnp.where(den > _EPS, agg, gprev.astype(jnp.float32))
    return agg.astype(dtype)


def _leaf_masked_mean(stack_w: jax.Array, stack_m: jax.Array, w: jax.Array,
                      gprev, use_kernel: bool) -> jax.Array:
    """Eq. (4) for one client-stacked leaf: (N, *leaf) -> (*leaf).

    Shared by the list-of-pytrees path (:func:`aggregate_sparse`) and the
    batched round engine (:func:`aggregate_sparse_stacked`) so the two are
    bit-identical.
    """
    num, den = leaf_masked_partials(stack_w, stack_m, w, use_kernel)
    return finish_masked_mean(num, den, gprev, stack_w.dtype)


def leaf_trimmed_partials(stack_w: jax.Array, stack_m: jax.Array,
                          w: jax.Array, beta: float):
    """Coordinate-wise trimmed (num, den) partials for one stacked leaf.

    Per coordinate, the valid contributors are the clients with mask 1
    AND positive weight; rank them by value (stable argsort-of-argsort,
    invalid rows keyed to +inf so they always rank past the valid tail)
    and drop the ``floor(beta * n_valid)`` lowest and highest before the
    weighted Eq. (4) sums.  NOT shard-composable: the ranks need every
    client's value per coordinate, so the sharded engine all-gathers the
    client axis first (dense-gather fallback — see round_engine).
    """
    n = stack_w.shape[0]
    wts = w.reshape((n,) + (1,) * (stack_w.ndim - 1))
    vals = stack_w.astype(jnp.float32)
    valid = (stack_m > 0) & (wts > 0)
    n_valid = jnp.sum(valid, axis=0)
    k = jnp.floor(beta * n_valid).astype(jnp.int32)
    order = jnp.argsort(jnp.where(valid, vals, jnp.inf), axis=0)
    rank = jnp.argsort(order, axis=0)
    keep = valid & (rank >= k) & (rank < n_valid - k)
    ww = stack_m * wts * keep
    return jnp.sum(vals * ww, axis=0), jnp.sum(ww, axis=0)


def _clip_scales(deltas, w: jax.Array, factor: float) -> jax.Array:
    """(N,) per-client clip scales from the masked-update leaf deltas.

    Each client's whole-tree update norm is clipped to ``factor`` x the
    median norm among positive-weight participants; clean fleets (every
    norm <= the threshold) pass through with scale 1.
    """
    sq = None
    for d in deltas:
        s = jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim)))
        sq = s if sq is None else sq + s
    norms = jnp.sqrt(sq)
    ref = jnp.nanmedian(jnp.where(w > 0, norms, jnp.nan))
    scale = jnp.minimum(1.0, factor * ref / jnp.maximum(norms, _EPS))
    return jnp.where(jnp.isfinite(scale), scale, 1.0)


def robust_leaf_stacks(stacks_w, stacks_m, w: jax.Array, gleaves,
                       kind: str, arg: float, use_kernel: bool = False):
    """Robust Eq. (4) over a LIST of broadcast (N, *leaf) stacks.

    The shared core of the stacked/grouped/sharded robust paths: masks
    already broadcast to value shape, one entry per tree leaf (the clip
    variant needs the whole tree at once for its per-client norms).
    ``kind="mean"`` routes through :func:`_leaf_masked_mean` unchanged.
    """
    if kind == "mean":
        return [_leaf_masked_mean(sw, sm, w, gp, use_kernel)
                for sw, sm, gp in zip(stacks_w, stacks_m, gleaves)]
    if kind == "trimmed":
        out = []
        for sw, sm, gp in zip(stacks_w, stacks_m, gleaves):
            num, den = leaf_trimmed_partials(sw, sm, w, arg)
            out.append(finish_masked_mean(num, den, gp, sw.dtype))
        return out
    if kind == "clip":
        if any(gp is None for gp in gleaves):
            raise ValueError("robust_agg 'clip' needs prev_global (the "
                             "clipped quantity is the update vs W^{t-1})")
        n = stacks_w[0].shape[0]
        deltas = [(sw.astype(jnp.float32) - gp.astype(jnp.float32)) * sm
                  for sw, sm, gp in zip(stacks_w, stacks_m, gleaves)]
        scale = _clip_scales(deltas, w, arg)
        out = []
        for d, sw, sm, gp in zip(deltas, stacks_w, stacks_m, gleaves):
            s = scale.reshape((n,) + (1,) * (d.ndim - 1))
            vals = gp.astype(jnp.float32) + d * s
            num, den = leaf_masked_partials(vals, sm, w, use_kernel)
            out.append(finish_masked_mean(num, den, gp, sw.dtype))
        return out
    raise ValueError(f"unknown robust kind {kind!r}")


def aggregate_sparse_stacked(
    stacked_params,
    stacked_masks,
    client_weights: Sequence[float] | jax.Array,
    *,
    prev_global: Optional[object] = None,
    use_kernel: bool = False,
    robust: str = "mean",
):
    """Eq. (4) over client-STACKED pytrees (leaves shaped (N, *leaf)).

    The batched round engine's aggregation: no per-client list handling, no
    jnp.stack — leaves arrive already stacked along the client axis, and the
    whole reduction traces into the engine's single jitted round step.
    ``stacked_masks`` leaves are channel-shaped (N, 1, ..., C, ..., 1) and
    broadcast against the parameters.  ``robust`` selects the Eq. (4)
    variant (module docstring); ``"mean"`` is the bit-identical default.
    """
    leaves = jax.tree_util.tree_leaves(stacked_params)
    mleaves = jax.tree_util.tree_leaves(stacked_masks)
    treedef = jax.tree_util.tree_structure(stacked_params)
    gleaves = (jax.tree_util.tree_leaves(prev_global)
               if prev_global is not None else [None] * len(leaves))
    n = leaves[0].shape[0]
    w = jnp.asarray(client_weights, jnp.float32)
    if w.shape[0] != n:
        raise ValueError("weights count mismatch")
    kind, arg = parse_robust_agg(robust)
    if kind == "mean":
        out = [
            _leaf_masked_mean(sw, jnp.broadcast_to(sm, sw.shape), w, gprev,
                              use_kernel)
            for sw, sm, gprev in zip(leaves, mleaves, gleaves)
        ]
    else:
        out = robust_leaf_stacks(
            leaves, [jnp.broadcast_to(sm, sw.shape)
                     for sw, sm in zip(leaves, mleaves)],
            w, gleaves, kind, arg, use_kernel)
    return jax.tree_util.tree_unflatten(treedef, out)


def aggregate_sparse_grouped(
    group_params: Sequence,
    group_masks: Sequence,
    group_indices: Sequence[jax.Array],
    client_weights: Sequence[float] | jax.Array,
    global_template,
    *,
    prev_global: Optional[object] = None,
    use_kernel: bool = False,
    single_canvas: bool = True,
    robust: str = "mean",
):
    """Eq. (4) over a shape-GROUPED ragged fleet: scatter every group's
    stacked sub-model leaves into a full-width client canvas, then run the
    shared leaf reduction.

    The heterogeneous reference loop zero-pads each client to global widths
    and stacks all N padded clients before reducing
    (:meth:`repro.core.protocol.FedDDServer._pad_to_global` +
    :func:`aggregate_sparse`).  This function builds the IDENTICAL
    (N, *global_leaf) stacks — group rows land at their fleet positions, the
    un-owned tail channels stay zero (a zero mask contributes to neither
    Eq. (4) sum) — and feeds them to the same :func:`_leaf_masked_mean`, so
    grouped aggregation is bit-identical to the padded per-client loop.

    Canvas rows must be distinct across groups (each client/buffer slot
    belongs to exactly one shape group); the default ``single_canvas`` path
    zero-pads every group's stack to global widths, concatenates the groups
    along the member axis, and lands all N rows with ONE scatter per leaf —
    the padding is exactly the zero tail the sequential per-group scatters
    left untouched, so the two paths are bit-identical (pinned by
    tests/test_grouped_engine.py) while the traced graph shrinks from
    O(groups) chained scatters per leaf to one.

    Args:
      group_params: per group, a stacked pytree with leaves (n_g, *local).
      group_masks: per group, channel-shaped stacked masks
        (n_g, 1, ..., C_local, ..., 1).
      group_indices: per group, the members' canvas rows as an (n_g,) int
        array (fleet positions; may be traced).
      client_weights: (N,) aggregation weights m_n indexed by canvas row —
        zero drops that client from both sums.
      global_template: pytree whose leaves carry the full-model shapes.
      prev_global: pytree used to fill positions no client uploaded.
      single_canvas: fuse all groups into one full-width scatter per leaf
        (default); ``False`` keeps the sequential per-group scatters as
        the reference for the equivalence tests.
      robust: Eq. (4) variant (module docstring) — the canvases are
        exactly the stacked layout, so the robust reductions reuse
        :func:`robust_leaf_stacks` unchanged.

    Returns the aggregated full-width global pytree.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(global_template)
    gprev = (jax.tree_util.tree_leaves(prev_global)
             if prev_global is not None else [None] * len(g_leaves))
    leaves = [jax.tree_util.tree_leaves(p) for p in group_params]
    mleaves = [jax.tree_util.tree_leaves(m) for m in group_masks]
    w = jnp.asarray(client_weights, jnp.float32)
    n = w.shape[0]
    all_rows = (jnp.concatenate([jnp.asarray(i) for i in group_indices])
                if single_canvas else None)
    kind, arg = parse_robust_agg(robust)
    canvases = []  # retained (value, mask) canvases for robust != mean

    out = []
    for li, gl in enumerate(g_leaves):
        stack_w = jnp.zeros((n,) + gl.shape, gl.dtype)
        stack_m = jnp.zeros((n,) + gl.shape, gl.dtype)
        if single_canvas:
            pads_w, pads_m = [], []
            for gi in range(len(group_indices)):
                lw = leaves[gi][li]                        # (n_g, *local)
                lm = jnp.broadcast_to(mleaves[gi][li], lw.shape)
                pads = [(0, 0)] + [(0, gs - ls)
                                   for gs, ls in zip(gl.shape, lw.shape[1:])]
                pads_w.append(jnp.pad(lw.astype(gl.dtype), pads))
                pads_m.append(jnp.pad(lm.astype(gl.dtype), pads))
            stack_w = stack_w.at[all_rows].set(jnp.concatenate(pads_w))
            stack_m = stack_m.at[all_rows].set(jnp.concatenate(pads_m))
        else:
            for gi, idx in enumerate(group_indices):
                lw = leaves[gi][li]                        # (n_g, *local)
                lm = jnp.broadcast_to(mleaves[gi][li], lw.shape)
                rows = (jnp.asarray(idx),) + tuple(slice(0, s)
                                                   for s in lw.shape[1:])
                stack_w = stack_w.at[rows].set(lw.astype(gl.dtype))
                stack_m = stack_m.at[rows].set(lm.astype(gl.dtype))
        if kind == "mean":
            out.append(_leaf_masked_mean(stack_w, stack_m, w, gprev[li],
                                         use_kernel))
        else:
            canvases.append((stack_w, stack_m))
    if kind != "mean":
        out = robust_leaf_stacks([c[0] for c in canvases],
                                 [c[1] for c in canvases],
                                 w, gprev, kind, arg, use_kernel)
    return jax.tree_util.tree_unflatten(treedef, out)


def aggregate_sparse(
    client_params: Sequence,
    client_masks: Sequence,
    client_weights: Sequence[float] | jax.Array,
    *,
    prev_global: Optional[object] = None,
    use_kernel: bool = False,
):
    """Eq. (4): masked weighted average across clients.

    Args:
      client_params: list of parameter pytrees (What_n), identical structure.
      client_masks: list of mask pytrees (broadcastable to params).
      client_weights: m_n (sample counts), length N.
      prev_global: pytree used to fill positions no client uploaded.
      use_kernel: route the hot inner loop through the Pallas sparse_agg
        kernel (stacked client tensors) instead of the pure-jnp path.

    Returns the aggregated global pytree.
    """
    n = len(client_params)
    if len(client_masks) != n:
        raise ValueError("params/masks count mismatch")
    w = jnp.asarray(client_weights, jnp.float32)
    if w.shape[0] != n:
        raise ValueError("weights count mismatch")

    leaves = [jax.tree_util.tree_leaves(p) for p in client_params]
    mleaves = [jax.tree_util.tree_leaves(m) for m in client_masks]
    treedef = jax.tree_util.tree_structure(client_params[0])
    gleaves = (jax.tree_util.tree_leaves(prev_global)
               if prev_global is not None else [None] * len(leaves[0]))

    out = []
    for li, gprev in enumerate(gleaves):
        stack_w = jnp.stack([leaves[ci][li] for ci in range(n)])     # (N, ...)
        stack_m = jnp.stack([jnp.broadcast_to(mleaves[ci][li],
                                              leaves[ci][li].shape)
                             for ci in range(n)])
        out.append(_leaf_masked_mean(stack_w, stack_m, w, gprev, use_kernel))
    return jax.tree_util.tree_unflatten(treedef, out)


def truncate_masks_to_prefix(stacked_masks, delivered):
    """Keep only each client's first ``delivered[leaf][n]`` kept channels.

    Partial aggregation for deadline-cut uploads (sim/faults.py): kept
    channels serialize in ascending channel index
    (repro.comm.payload.encode_upload), so the bytes that landed before
    the cut correspond per leaf to the PREFIX of the mask's kept set.
    ``stacked_masks`` leaves are channel-shaped (N, 1, ..., C, ..., 1);
    ``delivered`` is one (N,) int32 array per mask leaf (flatten order).
    A count >= the leaf's kept total leaves that client's mask untouched,
    so fully-arrived clients ride through unchanged.
    """
    mleaves, treedef = jax.tree_util.tree_flatten(stacked_masks)
    if len(delivered) != len(mleaves):
        raise ValueError("delivered counts / mask leaves mismatch")
    out = []
    for m, k in zip(mleaves, delivered):
        k = jnp.asarray(k, jnp.float32)
        if m.ndim <= 1:                      # scalar leaf: one channel
            keep = (k >= 1.0).astype(m.dtype)
            out.append(m * keep.reshape(m.shape))
            continue
        ax = next((a for a in range(1, m.ndim) if m.shape[a] > 1),
                  m.ndim - 1)
        rank = jnp.cumsum(m, axis=ax)        # kept channels rank 1..kept
        kb = k.reshape((-1,) + (1,) * (m.ndim - 1))
        out.append(m * (rank <= kb).astype(m.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def client_update_sparse(global_params, local_params, mask):
    """Eq. (5): W_n^{t+1} = W^t ⊙ M_n + What_n ⊙ (1 - M_n)."""
    return jax.tree_util.tree_map(
        lambda g, l, m: (g * m + l * (1.0 - m)).astype(l.dtype),
        global_params, local_params, mask)


def client_update_full(global_params, local_params):
    """Eq. (6): W_n^{t+1} = W^t (full broadcast round)."""
    del local_params
    return jax.tree_util.tree_map(lambda g: g, global_params)


def fedavg_aggregate(client_params: Sequence,
                     client_weights: Sequence[float] | jax.Array):
    """Classic Eq. (3) dense FedAvg (baseline)."""
    w = jnp.asarray(client_weights, jnp.float32)
    w = w / jnp.sum(w)

    def _avg(*leaves):
        stack = jnp.stack([l.astype(jnp.float32) for l in leaves])
        wts = w.reshape((-1,) + (1,) * (stack.ndim - 1))
        return jnp.sum(stack * wts, axis=0).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(_avg, *client_params)
