"""Compacted sparse collectives — FedDD's upload step mapped to TPU pods.

In the WAN setting a client uploads ``W ⊙ M`` over its slow uplink.  On a
multi-pod TPU system the analogous expensive hop is the cross-pod link, and
the analogous operation is the cross-pod aggregation of per-pod model deltas.

A dense cross-pod ``all-reduce`` of a tensor of U bytes moves ~2·U·(P-1)/P
bytes per link (ring).  FedDD's channel-structured dropout lets us move only
the *kept* channels: every pod

  1. ranks its channels with the importance kernel and keeps
     ``K = ceil(C · (1-D))`` of them (static K ⇒ static shapes, TPU-friendly);
  2. compacts the kept channels with a `take` gather into a ``(K, fan_in)``
     buffer plus a ``(K,)`` int32 index vector;
  3. ``all_gather``s the compacted buffers over the pod axis
     (``P·K·fan_in`` values + ``P·K`` indices);
  4. scatter-adds into a dense accumulator and divides by the per-position
     mask count (Eq. (4)).

Per-link bytes therefore scale with ``(1-D)`` — the communication-efficiency
axis of the paper, measurable in the dry-run's collective term.

The functions below are written for use inside ``shard_map`` over a 1-D
collective axis (the ``pod`` axis of the production mesh, or ``data`` when
clients = data-parallel groups).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compact_topk(values: jax.Array, scores: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Select the top-``k`` channels (axis 0 rows) of ``values`` by ``scores``.

    Args:
      values: (C, ...) tensor, channel-major.
      scores: (C,) channel scores.
      k: static keep count.
    Returns (compacted (k, ...), indices (k,) int32).
    """
    _, idx = lax.top_k(scores, k)
    idx = idx.astype(jnp.int32)
    return jnp.take(values, idx, axis=0), idx


def scatter_accumulate(dense_shape: Tuple[int, ...],
                       compact: jax.Array, idx: jax.Array,
                       weights: jax.Array | float = 1.0
                       ) -> Tuple[jax.Array, jax.Array]:
    """Scatter-add ``compact`` rows into a dense (C, ...) accumulator.

    Returns (sum, count) where count[c] = total weight of contributions to
    channel c (for the Eq. (4) division).
    """
    num = jnp.zeros(dense_shape, jnp.float32)
    cnt = jnp.zeros((dense_shape[0],), jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(weights, jnp.float32), idx.shape)
    wshape = (idx.shape[0],) + (1,) * (compact.ndim - 1)
    num = num.at[idx].add(compact.astype(jnp.float32) * w.reshape(wshape))
    cnt = cnt.at[idx].add(w)
    return num, cnt


def sparse_allgather_mean(local: jax.Array, scores: jax.Array, k: int,
                          axis_name: str,
                          weight: jax.Array | float = 1.0,
                          k_local: Optional[jax.Array] = None) -> jax.Array:
    """FedDD aggregation over a named mesh axis with compacted transfer.

    For use inside shard_map.  Each participant contributes its top-k
    channels; positions nobody contributed keep the LOCAL value (the caller
    overlays h-periodic dense sync separately).

    Args:
      local:  (C, ...) local updated tensor (What_n), channel-major.
      scores: (C,) importance scores.
      k: static channels kept per participant (buffer size; SPMD-static).
      axis_name: mesh axis over which clients/pods aggregate.
      weight: this participant's aggregation weight (m_n).
      k_local: optional traced per-participant keep count <= k.  This is
        how DIFFERENTIAL dropout survives SPMD staticness: the buffer is
        sized by the largest allocation while each participant zero-weights
        rows beyond its own ceil(C*(1-D_n)).
    Returns the aggregated dense tensor, same shape/dtype as ``local``.
    """
    compact, idx = compact_topk(local, scores, k)
    w_rows = jnp.full((k,), jnp.asarray(weight, jnp.float32))
    if k_local is not None:
        w_rows = w_rows * (jnp.arange(k) < k_local)
    # The only cross-participant traffic: compacted values + indices + weights.
    all_compact = lax.all_gather(compact, axis_name)          # (P, k, ...)
    all_idx = lax.all_gather(idx, axis_name)                  # (P, k)
    all_w = lax.all_gather(w_rows, axis_name)                 # (P, k)

    p = all_idx.shape[0]
    flat_vals = all_compact.reshape((p * k,) + compact.shape[1:])
    flat_idx = all_idx.reshape(p * k)
    flat_w = all_w.reshape(p * k)
    num, cnt = scatter_accumulate(local.shape, flat_vals, flat_idx, flat_w)
    wshape = (local.shape[0],) + (1,) * (local.ndim - 1)
    agg = num / jnp.maximum(cnt, 1e-12).reshape(wshape)
    keep_local = (cnt <= 1e-12).reshape(wshape)
    return jnp.where(keep_local, local, agg.astype(local.dtype)).astype(local.dtype)


def dense_allreduce_mean(local: jax.Array, axis_name: str,
                         weight: jax.Array | float = 1.0) -> jax.Array:
    """FedAvg reference path: dense weighted psum over the axis."""
    w = jnp.asarray(weight, jnp.float32)
    num = lax.psum(local.astype(jnp.float32) * w, axis_name)
    den = lax.psum(w, axis_name)
    return (num / den).astype(local.dtype)


def make_federated_allreduce(k_fraction: float, axis_name: str):
    """Returns f(local, scores, weight, k_local) using the sparse path when
    k_fraction < 1 else the dense path.  ``k_fraction = 1 - D``.

    ``k_local`` (optional, traced, <= the static buffer size) is forwarded
    to :func:`sparse_allgather_mean` — this is how differential per-client
    dropout rates ride on the SPMD-static buffer."""
    if not 0.0 < k_fraction <= 1.0:
        raise ValueError(f"k_fraction must be in (0,1], got {k_fraction}")

    def _f(local, scores, weight=1.0, k_local=None):
        if k_fraction >= 1.0:
            return dense_allreduce_mean(local, axis_name, weight)
        k = max(1, int(local.shape[0] * k_fraction))
        return sparse_allgather_mean(local, scores, k, axis_name, weight,
                                     k_local=k_local)

    return _f
