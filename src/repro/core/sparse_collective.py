"""Compacted sparse collectives — FedDD's upload step mapped to TPU pods.

In the WAN setting a client uploads ``W ⊙ M`` over its slow uplink.  On a
multi-pod TPU system the analogous expensive hop is the cross-pod link, and
the analogous operation is the cross-pod aggregation of per-pod model deltas.

A dense cross-pod ``all-reduce`` of a tensor of U bytes moves ~2·U·(P-1)/P
bytes per link (ring).  FedDD's channel-structured dropout lets us move only
the *kept* channels: every pod

  1. ranks its channels with the importance kernel and keeps
     ``K = ceil(C · (1-D))`` of them (static K ⇒ static shapes, TPU-friendly);
  2. compacts the kept channels with a `take` gather into a ``(K, fan_in)``
     buffer plus a ``(K,)`` int32 index vector;
  3. ``all_gather``s the compacted buffers over the pod axis
     (``P·K·fan_in`` values + ``P·K`` indices);
  4. scatter-adds into a dense accumulator and divides by the per-position
     mask count (Eq. (4)).

Per-link bytes therefore scale with ``(1-D)`` — the communication-efficiency
axis of the paper, measurable in the dry-run's collective term.

The functions below are written for use inside ``shard_map`` over a 1-D
collective axis (the ``pod`` axis of the production mesh, or ``data`` when
clients = data-parallel groups).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compact_topk(values: jax.Array, scores: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Select the top-``k`` channels (axis 0 rows) of ``values`` by ``scores``.

    Args:
      values: (C, ...) tensor, channel-major.
      scores: (C,) channel scores.
      k: static keep count.
    Returns (compacted (k, ...), indices (k,) int32).
    """
    _, idx = lax.top_k(scores, k)
    idx = idx.astype(jnp.int32)
    return jnp.take(values, idx, axis=0), idx


def scatter_accumulate(dense_shape: Tuple[int, ...],
                       compact: jax.Array, idx: jax.Array,
                       weights: jax.Array | float = 1.0
                       ) -> Tuple[jax.Array, jax.Array]:
    """Scatter-add ``compact`` rows into a dense (C, ...) accumulator.

    Returns (sum, count) where count[c] = total weight of contributions to
    channel c (for the Eq. (4) division).
    """
    num = jnp.zeros(dense_shape, jnp.float32)
    cnt = jnp.zeros((dense_shape[0],), jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(weights, jnp.float32), idx.shape)
    wshape = (idx.shape[0],) + (1,) * (compact.ndim - 1)
    num = num.at[idx].add(compact.astype(jnp.float32) * w.reshape(wshape))
    cnt = cnt.at[idx].add(w)
    return num, cnt


def sparse_allgather_mean(local: jax.Array, scores: jax.Array, k: int,
                          axis_name: str,
                          weight: jax.Array | float = 1.0,
                          k_local: Optional[jax.Array] = None) -> jax.Array:
    """FedDD aggregation over a named mesh axis with compacted transfer.

    For use inside shard_map.  Each participant contributes its top-k
    channels; positions nobody contributed keep the LOCAL value (the caller
    overlays h-periodic dense sync separately).

    Args:
      local:  (C, ...) local updated tensor (What_n), channel-major.
      scores: (C,) importance scores.
      k: static channels kept per participant (buffer size; SPMD-static).
      axis_name: mesh axis over which clients/pods aggregate.
      weight: this participant's aggregation weight (m_n).
      k_local: optional traced per-participant keep count <= k.  This is
        how DIFFERENTIAL dropout survives SPMD staticness: the buffer is
        sized by the largest allocation while each participant zero-weights
        rows beyond its own ceil(C*(1-D_n)).
    Returns the aggregated dense tensor, same shape/dtype as ``local``.
    """
    compact, idx = compact_topk(local, scores, k)
    w_rows = jnp.full((k,), jnp.asarray(weight, jnp.float32))
    if k_local is not None:
        w_rows = w_rows * (jnp.arange(k) < k_local)
    # The only cross-participant traffic: compacted values + indices + weights.
    all_compact = lax.all_gather(compact, axis_name)          # (P, k, ...)
    all_idx = lax.all_gather(idx, axis_name)                  # (P, k)
    all_w = lax.all_gather(w_rows, axis_name)                 # (P, k)

    p = all_idx.shape[0]
    flat_vals = all_compact.reshape((p * k,) + compact.shape[1:])
    flat_idx = all_idx.reshape(p * k)
    flat_w = all_w.reshape(p * k)
    num, cnt = scatter_accumulate(local.shape, flat_vals, flat_idx, flat_w)
    wshape = (local.shape[0],) + (1,) * (local.ndim - 1)
    agg = num / jnp.maximum(cnt, 1e-12).reshape(wshape)
    keep_local = (cnt <= 1e-12).reshape(wshape)
    return jnp.where(keep_local, local, agg.astype(local.dtype)).astype(local.dtype)


def sparse_numden_allreduce(num: jax.Array, den_ch: jax.Array, k: int,
                            axis_name: str,
                            k_local: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Eq. (4)-faithful compacted reduction of per-shard (num, den) partials.

    The round engines reduce Eq. (4) as numerator/denominator PARTIALS:
    ``num = Σ_n w_n·m_n·Ŵ_n`` (channel-major, (C, ...)) and the channel
    denominator profile ``den_ch[c] = Σ_n w_n·m_n[c]`` ((C,)).  This is the
    sibling of :func:`sparse_allgather_mean` for that pair: instead of
    dividing by a contribution COUNT it returns the globally-reduced
    (num, den) so the caller can apply the paper's weighted division and
    ``prev_global`` fill exactly as the single-device path does.

    Exactness: a channel with ``den_ch[c] == 0`` has every local mask row
    zero there, so its ``num[c]`` rows are exactly zero — compacting the
    top-``k`` channels by ``den_ch`` loses NOTHING whenever the shard's
    nonzero-channel count fits the buffer.  The returned ``overflow``
    (psum of ``max(0, nnz - k)`` over shards) counts channels that did not
    fit; zero overflow certifies the compacted reduction equals the dense
    psum bit-for-bit up to reduction order.

    Args:
      num: (C, ...) local numerator partial, channel-major, float32.
      den_ch: (C,) local denominator channel profile, float32.
      k: static channels per shard on the wire (SPMD-static buffer size).
      axis_name: the 1-D clients mesh axis.
      k_local: optional traced per-shard keep count <= k (differential
        dropout riding the static buffer: rows beyond it are zeroed).
    Returns (num_total (C, ...), den_total (C,), overflow scalar f32).
    """
    c = num.shape[0]
    k = max(1, min(int(k), c))
    nnz = jnp.sum((den_ch > 0).astype(jnp.float32))
    overflow = lax.psum(jnp.maximum(nnz - k, 0.0), axis_name)
    compact, idx = compact_topk(num, den_ch, k)
    den_rows = jnp.take(den_ch, idx)
    if k_local is not None:
        live = (jnp.arange(k) < k_local).astype(jnp.float32)
        compact = compact * live.reshape((k,) + (1,) * (compact.ndim - 1))
        den_rows = den_rows * live
    # The only cross-shard traffic: compacted partials + indices + den rows.
    all_compact = lax.all_gather(compact, axis_name)          # (P, k, ...)
    all_idx = lax.all_gather(idx, axis_name)                  # (P, k)
    all_den = lax.all_gather(den_rows, axis_name)             # (P, k)
    p = all_idx.shape[0]
    flat_vals = all_compact.reshape((p * k,) + compact.shape[1:])
    flat_idx = all_idx.reshape(p * k)
    flat_den = all_den.reshape(p * k)
    num_tot = jnp.zeros(num.shape, jnp.float32).at[flat_idx].add(
        flat_vals.astype(jnp.float32))
    den_tot = jnp.zeros((c,), jnp.float32).at[flat_idx].add(flat_den)
    return num_tot, den_tot, overflow


def make_federated_numden_allreduce(keep_fraction: float, axis_name: str):
    """Returns f(num, den_ch, k_local) -> (num_tot, den_tot, overflow),
    the Eq. (4) partial reducer over the clients axis.

    ``keep_fraction = 1`` routes to a dense psum (exact, zero overflow);
    ``keep_fraction < 1`` sizes the compacted buffer at
    ``K = max(1, ceil(C * keep_fraction))`` channels per shard and uses
    :func:`sparse_numden_allreduce`."""
    if not 0.0 < keep_fraction <= 1.0:
        raise ValueError(
            f"keep_fraction must be in (0,1], got {keep_fraction}")

    def _f(num, den_ch, k_local=None):
        if keep_fraction >= 1.0:
            num_tot = lax.psum(num.astype(jnp.float32), axis_name)
            den_tot = lax.psum(den_ch.astype(jnp.float32), axis_name)
            return num_tot, den_tot, jnp.float32(0.0)
        c = num.shape[0]
        k = max(1, min(c, int(math.ceil(c * keep_fraction))))
        return sparse_numden_allreduce(num, den_ch, k, axis_name,
                                       k_local=k_local)

    return _f


def dense_allreduce_mean(local: jax.Array, axis_name: str,
                         weight: jax.Array | float = 1.0) -> jax.Array:
    """FedAvg reference path: dense weighted psum over the axis."""
    w = jnp.asarray(weight, jnp.float32)
    num = lax.psum(local.astype(jnp.float32) * w, axis_name)
    den = lax.psum(w, axis_name)
    return (num / den).astype(local.dtype)


def make_federated_allreduce(k_fraction: float, axis_name: str):
    """Returns f(local, scores, weight, k_local) using the sparse path when
    k_fraction < 1 else the dense path.  ``k_fraction = 1 - D``.

    ``k_local`` (optional, traced, <= the static buffer size) is forwarded
    to :func:`sparse_allgather_mean` — this is how differential per-client
    dropout rates ride on the SPMD-static buffer."""
    if not 0.0 < k_fraction <= 1.0:
        raise ValueError(f"k_fraction must be in (0,1], got {k_fraction}")

    def _f(local, scores, weight=1.0, k_local=None):
        if k_fraction >= 1.0:
            return dense_allreduce_mean(local, axis_name, weight)
        k = max(1, int(local.shape[0] * k_fraction))
        return sparse_allgather_mean(local, scores, k, axis_name, weight,
                                     k_local=k_local)

    return _f
