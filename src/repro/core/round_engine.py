"""Batched FedDD round engine — the homogeneous hot path, fully on device.

``FedDDServer.run`` executes Algorithm 1 as a Python loop over clients:
per-client ``build_masks`` dispatches, per-leaf ``float(...)`` host syncs in
``mask_density``, list-based padding and aggregation.  At simulation scale
(hundreds of clients) dispatch overhead — not compute — dominates.

This module stacks client parameter pytrees along a leading client axis and
rewrites the round's server side as ONE ``jax.jit``-compiled step:

    importance scoring   — client axis folded into the channel axis, one
                           pass per leaf (Pallas kernel when use_kernel)
    mask building        — full-width ``lax.top_k`` ranks + a dynamic
                           ``rank < keep`` compare, vmapped over clients
    masked aggregation   — Eq. (4) over the already-stacked leaves
                           (Pallas sparse_agg kernel when use_kernel)
    sparse client update — Eq. (5)/(6) broadcast over the client axis

Per-round device->host traffic collapses to one transfer of a small
telemetry struct (per-client upload densities, plus losses when local
training is batched too) instead of O(clients x leaves) ``float()`` calls.

Results are bit-identical to the per-client loop for a fixed seed
(tests/test_round_engine.py asserts this), so ``protocol.py`` routes every
homogeneous FedDD run through this engine and keeps the loop only for
heterogeneous (ragged-width) client models.

The engine also serves the fedavg/fedcs/oort baselines (``dense_masks``:
all-ones masks, no scoring) and the event-driven simulator
(``repro.sim.runner``): non-participation, deadline-dropped stragglers, and
staleness-decayed async merges are all expressed as per-client aggregation
weights — weight 0 excludes a client from the stacked Eq. (4) reduction.

Multi-round fusion (``BatchedRoundEngine.run``): once per-round compute is
one fused step, the round LOOP itself is the remaining overhead — every
round pays a Python dispatch, an allocator call, and a (losses, densities)
device->host transfer before the next step can launch.  With the jit-able
allocator (``allocation.solve_dropout_rates_jax``) the whole train loop —
allocate -> select -> aggregate -> update -> re-allocate — lifts into a
``lax.scan`` over rounds: K rounds run as ONE device dispatch carrying
(params, losses, dropout rates, PRNG key, Eq. (12) clock) and the only
host traffic is one transfer of the stacked :class:`ScanTrace` telemetry
at the end.  ``protocol.py`` routes this via
``ProtocolConfig.rounds_per_dispatch`` and splices the trace back into the
per-round ``RoundRecord`` stream.  Equivalence contract
(tests/test_round_engine.py): the learning state — params, masks, losses,
participation — is bit-identical to K sequential engine steps, and the
Eq. (9)-(11) dropout rates match to the last float32 bit the
``optimization_barrier``-fenced allocator can pin (identical for the test
fixtures; within a few ulps in the worst case, because XLA compiles the
golden-section search per program and its final bit is context
sensitive — see ``allocation.solve_dropout_rates_jax``).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map

from repro.comm import codecs as wire_codecs
from repro.comm import quantize as wire_quant
from repro.comm.payload import CommConfig, WireSpec, analytic_wire_bytes
from repro.core import (aggregation, allocation, baselines, selection,
                        sparse_collective)


class RoundOutputs(NamedTuple):
    """Device-side results of one batched round step."""

    client_params: object      # pytree, leaves (N, *leaf): W_n^{t+1}
    global_params: object      # pytree: W^t
    densities: jax.Array       # (N,) fraction of elements uploaded
    wire_overhead: object = None   # (N,) int32 measured mask/scale bytes
                                   # (repro.comm), or None with the default
                                   # CommConfig (dense codec, no overhead)
    collective_overflow: object = None  # () f32 channels that missed the
                                        # compacted cross-device buffer
                                        # (ShardedRoundEngine, sparse
                                        # collective only; 0 certifies the
                                        # compaction was lossless)


class GroupBatch(NamedTuple):
    """One shape group's device-side inputs to a grouped round step.

    Everything here is traced (a pytree): group MEMBERSHIP changes (async
    buffers, different fleets of the same shape census) re-use the compiled
    step; only the shape census itself keys the jit cache.
    """

    indices: jax.Array         # (n_g,) int32: canvas rows / RNG-fold ids
    stacked_old: object        # pytree, leaves (n_g, *local): W_n^t
    stacked_new: object        # pytree, leaves (n_g, *local): What_n^t
    coverage: object           # CR(k) pytree of (C_local,) leaves, or None
    dropout: jax.Array         # (n_g,) float32 D_n^t


class GroupedRoundOutputs(NamedTuple):
    """Device-side results of one grouped round step."""

    group_client_params: Tuple # per group: pytree, leaves (n_g, *local)
    global_params: object      # full-width pytree: W^t
    densities: jax.Array       # (N,) canvas of upload densities
    wire_overhead: object = None   # (N,) int32 canvas of measured mask /
                                   # scale bytes, or None (default comm)


class ScanTelemetry(NamedTuple):
    """Static per-run client telemetry staged on device for the scanned
    multi-round path: the Eq. (9)-(11) allocator inputs plus the Eq. (12)
    clock coefficients.  ``train_loss`` is deliberately absent — it is
    round-dynamic and lives in the :class:`ScanState` carry.
    """

    model_bytes: jax.Array     # (N,) f32 U_n
    uplink_rate: jax.Array     # (N,) f32 r_n^u
    downlink_rate: jax.Array   # (N,) f32 r_n^d
    compute_latency: jax.Array # (N,) f32 t_n^cmp
    num_samples: jax.Array     # (N,) f32 m_n
    label_coverage: jax.Array  # (N,) f32 Eq. (13) coverage term

    @classmethod
    def from_host(cls, tel) -> "ScanTelemetry":
        """Stage a :class:`repro.core.allocation.ClientTelemetry` (minus
        the dynamic ``train_loss``) as float32 device arrays."""
        return cls(*(jnp.asarray(getattr(tel, f), jnp.float32)
                     for f in cls._fields))


class ScanState(NamedTuple):
    """The ``lax.scan`` carry of the multi-round fused path — everything
    round t hands round t+1, entirely on device."""

    client_params: object      # stacked pytree, leaves (N, *leaf): W_n^t
    global_params: object      # pytree: W^{t-1}
    losses: jax.Array          # (N,) f32 server-side loss view
    dropout: jax.Array         # (N,) f32 D_t (rates the NEXT uploads use)
    rng: jax.Array             # protocol PRNG key (split once per round)
    sim_time: jax.Array        # () f32 cumulative Eq. (12) clock (device
                               # axis; chunk-relative — see ScanTrace)


class ScanTrace(NamedTuple):
    """Per-round telemetry stacked over the scanned chunk — the chunk's ONE
    device->host transfer.  ``round_time`` / ``sim_time`` are the float32
    DEVICE rendering of the Eq. (12) clock (``sim_time`` cumulative from
    the chunk start); the protocol driver recomputes the authoritative
    float64 clock host-side from ``next_dropout`` + ``participants`` so
    spliced ``RoundRecord`` streams stay bit-identical to sequential
    rounds.
    """

    losses: jax.Array          # (K, N) f32 post-round losses
    densities: jax.Array       # (K, N) f32 upload densities
    next_dropout: jax.Array    # (K, N) f32 D_{t+1} (the Eq. (9)-(11) solve)
    participants: jax.Array    # (K, N) bool round participation
    round_time: jax.Array      # (K,) f32 Eq. (12) round duration (device)
    sim_time: jax.Array        # (K,) f32 cumulative device clock
    wire_overhead: object = None   # (K, N) int32 measured mask/scale bytes
                                   # (repro.comm), or None (default comm) —
                                   # integer arithmetic, so the scanned and
                                   # per-round renderings agree exactly


def stack_pytrees(trees: Sequence) -> object:
    """[pytree] x N (identical structure/shapes) -> pytree of (N, *leaf)."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def unstack_pytree(stacked, n: int) -> List:
    """Inverse of :func:`stack_pytrees` (lazy device slices, no host sync)."""
    return [jax.tree_util.tree_map(lambda l: l[i], stacked)
            for i in range(n)]


def _adopt_global(new_global, stacked):
    """Eq. (6): every client adopts the fresh global model (the un-stacked
    global broadcasts against the (N, ...) stacked leaves)."""
    return jax.tree_util.tree_map(
        lambda g, l: jnp.broadcast_to(g, l.shape).astype(l.dtype),
        new_global, stacked)


def _dense_masks(stacked, n: int):
    """All-ones channel masks + unit densities (full-model uploads)."""
    masks = jax.tree_util.tree_map(
        lambda l: jnp.ones((n,) + (1,) * (l.ndim - 1), l.dtype), stacked)
    return masks, jnp.ones((n,), jnp.float32)


def _wire_overhead(masks, stacked_new, comm: CommConfig, channel_axis: int,
                   dense_masks: bool):
    """(N,) int32 measured mask/scale bytes, or None for the default comm.

    Sparse (feddd) masks encode their actual kept sets; dense all-ones
    masks charge the closed-form full-upload constant at true channel
    widths (their in-trace representation collapses the channel dim —
    see ``wire_codecs.full_upload_overhead_bytes``).
    """
    if comm.is_default:
        return None
    n = jax.tree_util.tree_leaves(stacked_new)[0].shape[0]
    if dense_masks:
        const = wire_codecs.full_upload_overhead_bytes(
            WireSpec.from_stacked(stacked_new, channel_axis), comm)
        return jnp.full((n,), const, jnp.int32)
    return wire_codecs.mask_overhead_bytes_stacked(masks, stacked_new,
                                                   comm)


# The whole server side of Algorithm 1 (steps 2-4 + 6-7) in one trace.
# Module-level jit keyed on the (hashable, frozen) SelectionConfig so the
# compile cache is shared across engine instances and server runs.
@functools.partial(jax.jit,
                   static_argnames=("sel_cfg", "full_round", "dense_masks",
                                    "comm", "robust"))
def _round_step(stacked_old, stacked_new, global_params, dropout_rates,
                weights, rng, stacked_upload=None, delivered=None, *,
                sel_cfg: selection.SelectionConfig,
                full_round: bool, dense_masks: bool = False,
                comm: CommConfig = CommConfig(),
                robust: str = "mean") -> RoundOutputs:
    # jax.named_scope blocks are compile-time metadata (operator name
    # prefixes in the HLO / profiler traces — repro.obs vocabulary); they
    # are UNCONDITIONAL, so the compiled program never depends on whether
    # observability is enabled.
    with jax.named_scope("feddd_encode_masks"):
        if dense_masks:
            # Baseline rounds (fedavg/fedcs/oort): participants upload
            # FULL models, so masks are all-ones and no importance
            # scoring runs.  Non-participation is a 0 in ``weights`` — a
            # zero-weight client contributes nothing to either Eq. (4)
            # sum, exactly like being left out of the aggregation list.
            n = jax.tree_util.tree_leaves(stacked_new)[0].shape[0]
            masks, density = _dense_masks(stacked_new, n)
        else:
            masks, density = selection.build_masks_batched(
                stacked_old, stacked_new, dropout_rates, config=sel_cfg,
                rng=rng)
    # Wire format (repro.comm): the server aggregates what it DECODED —
    # with qbits < 32 that is the quantize->dequantize rendering of the
    # uploads (the clients' own Eq. (5) updates keep local full precision,
    # so only the aggregation input changes).  Static branch: the default
    # comm config traces the exact pre-comm graph.  Dense (all-ones)
    # masks carry a collapsed channel dim, so their overhead is the
    # closed-form full-upload constant at TRUE widths, not an encoding of
    # the collapsed shape.
    # Fault injection (repro.sim.faults): ``stacked_upload`` is what the
    # server DECODED off the wire — corrupted rows differ from the
    # client's own ``stacked_new``, which stays clean for Eq. (5);
    # ``delivered`` truncates deadline-cut uploads to the per-leaf prefix
    # of mask channels whose bytes landed (partial aggregation).  Both
    # default to None and then trace the exact pre-fault graph.
    upload_src = stacked_new if stacked_upload is None else stacked_upload
    with jax.named_scope("feddd_encode_wire"):
        stacked_agg = wire_quant.quantize_dequantize_stacked(
            upload_src, rng, comm.qbits)
        wire_oh = _wire_overhead(masks, stacked_new, comm,
                                 sel_cfg.channel_axis, dense_masks)
        agg_masks = (masks if delivered is None
                     else aggregation.truncate_masks_to_prefix(masks,
                                                               delivered))
    with jax.named_scope("feddd_aggregate"):
        new_global = aggregation.aggregate_sparse_stacked(
            stacked_agg, agg_masks, weights, prev_global=global_params,
            use_kernel=sel_cfg.use_kernel, robust=robust)
    with jax.named_scope("feddd_client_update"):
        if full_round:
            new_clients = _adopt_global(new_global, stacked_new)
        else:
            # Eq. (5): the un-stacked global broadcasts against the
            # (N, ...) stacked leaves, so the per-client rule applies
            # verbatim.
            new_clients = aggregation.client_update_sparse(
                new_global, stacked_new, masks)
    return RoundOutputs(new_clients, new_global, density, wire_oh)


@dataclasses.dataclass
class BatchedRoundEngine:
    """One-jit-call FedDD round over client-stacked parameters.

    Args:
      selection_cfg: mask-building config; ``selection_cfg.use_kernel``
        routes BOTH the importance scoring and the Eq. (4) aggregation
        through the Pallas kernels.
      comm: wire-format config (repro.comm).  Non-default codecs add the
        measured mask/scale overhead to the step outputs; ``qbits < 32``
        quantizes the values the aggregation consumes.  The default is
        bit-identical to a comm-less engine.
      robust_agg: Eq. (4) variant — ``"mean"`` (default, bit-identical
        to the pre-robust engine), ``"trimmed[:beta]"`` coordinate-wise
        trimmed mean, ``"clip[:factor]"`` per-client norm clipping
        (repro.core.aggregation module docstring).  Static: each variant
        compiles its own fused step.
    """

    selection_cfg: selection.SelectionConfig = dataclasses.field(
        default_factory=selection.SelectionConfig)
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    robust_agg: str = "mean"

    def step(self, stacked_old, stacked_new, global_params,
             dropout_rates, weights, rng, *, full_round: bool,
             dense_masks: bool = False, stacked_upload=None,
             delivered=None) -> RoundOutputs:
        """Run one round's server side.

        Args:
          stacked_old / stacked_new: client params before/after local
            training, leaves (N, *leaf).
          global_params: current global pytree (un-stacked).
          dropout_rates: (N,) float32 D_n^t.
          weights: (N,) aggregation weights m_n (sample counts).  A zero
            weight excludes that client from the Eq. (4) aggregate — this
            is how baseline non-participants, deadline-dropped stragglers
            (sim/policies.py), and staleness-decayed async merges ride the
            same fused step.
          rng: the ROUND key (same key the per-client loop splits from).
          full_round: t mod h == 0 — dense broadcast round (static: the two
            variants compile once each).
          dense_masks: all-ones masks / full uploads (the fedavg / fedcs /
            oort baselines); skips importance scoring entirely (static).
          stacked_upload: optional stacked pytree the AGGREGATION consumes
            instead of ``stacked_new`` — the on-wire rendering when fault
            injection corrupts uploads (clients' own Eq. (5) state stays
            ``stacked_new``).
          delivered: optional per-mask-leaf (N,) int32 delivered-channel
            counts; truncates each client's aggregation mask to its
            delivered prefix (deadline partial aggregation).
        """
        return _round_step(
            stacked_old, stacked_new, global_params,
            jnp.asarray(dropout_rates, jnp.float32),
            jnp.asarray(weights, jnp.float32), rng, stacked_upload,
            delivered, sel_cfg=self.selection_cfg,
            full_round=bool(full_round),
            dense_masks=bool(dense_masks), comm=self.comm,
            robust=str(self.robust_agg))

    def run(self, state: ScanState, telemetry: ScanTelemetry, *,
            num_rounds: int, batched_train_fn, weights,
            h: int, a_server: float, d_max: float, delta: float,
            global_model_bytes: float, t_start=1, scheme: str = "feddd",
            static_participants=None, oort_penalty=None,
            oort_budget: float = 0.0, alloc_iters: int = 96,
            donate: bool = True) -> Tuple[ScanState, ScanTrace]:
        """Run ``num_rounds`` FULL rounds — training, masks, Eq. (4)
        aggregation, Eq. (5)/(6) updates, the Eq. (9)-(11) dropout-rate
        re-allocation AND the Eq. (12) clock — as ONE ``lax.scan`` device
        dispatch.

        Each scanned round reproduces :meth:`step` fed the same carry —
        learning state bit-identical, allocator output pinned to
        float32-ulp scale (the protocol's chunked executor and
        tests/test_round_engine.py hold the contract); the win is that K
        rounds cost one Python dispatch and one host transfer (the
        stacked :class:`ScanTrace`) instead of K of each.

        Args:
          state: the :class:`ScanState` carry entering round ``t_start``.
          telemetry: static :class:`ScanTelemetry` (allocator + clock
            inputs).
          num_rounds: K, the chunk length (static: one compile per K).
          batched_train_fn: ``(stacked_params, round_key) ->
            (stacked_params, (N,) losses)`` — local training must be
            device-fused for the loop to scan.  Pass it ``jax.jit``-wrapped
            (callers already do — jit-of-jit just inlines): per-round
            dispatch then runs the same XLA-compiled arithmetic the scan
            inlines, which is what makes scanned rounds bit-identical to
            sequential ones.  An eager train fn is still correct but can
            differ from its compiled self in the last float32 bit
            (e.g. fused multiply-adds).
          weights: (N,) aggregation weights m_n (sample counts).
          h / a_server / d_max / delta / global_model_bytes: protocol
            constants (static).
          t_start: 1-based round index of the chunk's first round (traced:
            successive chunks reuse the compile).
          scheme: "feddd" runs masks + re-allocation; the dense baselines
            ("fedavg" / "fedcs" / "oort") run full uploads with
            non-participants masked back to stale params/losses.
          static_participants: (N,) bool — required for "fedcs", whose
            loss-independent selection is precomputed host-side.
          oort_penalty / oort_budget: required for "oort" — the static
            system-utility penalty (:func:`repro.core.baselines
            .oort_system_penalty`) and the byte budget for the traced
            greedy re-ranking.
          alloc_iters: golden-section iterations of the in-scan allocator
            (96 matches ``solve_dropout_rates_with``'s default, so the
            scanned rates are bit-identical to the sequential
            ``allocator="jax"`` path).
          donate: donate the STACKED PARAMS and GLOBAL PARAMS carries to
            the dispatch (``donate_argnums`` on the ``client_params`` and
            ``global_params`` arguments — the losses / rng / clock stay
            un-donated, they are tiny and may alias caller arrays) so both
            model buffers update in place instead of being copied per
            chunk.  XLA implements the donation on CPU/GPU/TPU for the
            pinned jax version; a backend that declines falls back to a
            copy with a compile-time warning.  The caller must treat BOTH
            passed-in carries as consumed — the protocol executor copies
            the user-provided global pytree once before its first chunk so
            the caller's arrays are never invalidated
            (tests/test_round_engine.py
            ::test_scanned_run_donates_stacked_carry pins all sides).
        """
        if scheme == "fedcs" and static_participants is None:
            raise ValueError("scheme='fedcs' requires static_participants")
        if scheme == "oort" and oort_penalty is None:
            raise ValueError("scheme='oort' requires oort_penalty (see "
                             "baselines.oort_system_penalty) + oort_budget")
        n = telemetry.model_bytes.shape[0]
        spec = (None if self.comm.is_default else WireSpec.from_stacked(
            state.client_params, self.selection_cfg.channel_axis))
        fn = _scanned_rounds_fn(
            batched_train_fn, self.selection_cfg, int(num_rounds), int(h),
            str(scheme), float(a_server), float(d_max), float(delta),
            float(global_model_bytes), int(alloc_iters), bool(donate),
            self.comm, spec, str(self.robust_agg))
        part = (jnp.ones((n,), bool) if static_participants is None
                else jnp.asarray(static_participants, bool))
        pen = (jnp.ones((n,), jnp.float32) if oort_penalty is None
               else jnp.asarray(oort_penalty, jnp.float32))
        return fn(state.client_params, state.global_params,
                  tuple(state)[2:], telemetry,
                  jnp.asarray(t_start, jnp.int32),
                  jnp.asarray(weights, jnp.float32), part, pen,
                  jnp.asarray(oort_budget, jnp.float32))


# One compiled fn per (train fn, selection config, chunk length, protocol
# constants): the module-level cache is shared across engine instances and
# protocol runs, and t_start stays traced so successive chunks of the same
# length never retrace.
@functools.lru_cache(maxsize=64)
def _scanned_rounds_fn(train_fn, sel_cfg: selection.SelectionConfig,
                       num_rounds: int, h: int, scheme: str,
                       a_server: float, d_max: float, delta: float,
                       global_model_bytes: float, alloc_iters: int,
                       donate: bool, comm: CommConfig,
                       wire_spec, robust: str = "mean"):
    dense = scheme != "feddd"

    # client_params and global_params are separate leading arguments so
    # donate_argnums can target exactly the two model-buffer carries: the
    # losses / rng / clock entries of the state are tiny, may alias
    # caller-visible arrays, and are never donated.  The protocol executor
    # copies the user-provided global pytree once before its first chunk,
    # so donating the global carry never invalidates caller state.
    def run_rounds(client_params, global_params, rest: Tuple,
                   tel: ScanTelemetry, t_start,
                   weights, static_part, oort_penalty, oort_budget):
        state = ScanState(client_params, global_params, *rest)
        n = weights.shape[0]

        def body(st: ScanState, t):
            params, gparams, losses, dropout, rng, sim_time = st
            rng, rk = jax.random.split(rng)
            d_used = dropout
            # participation — the only scheme whose selection is both
            # dynamic and loss-dependent (oort) re-ranks in-trace
            with jax.named_scope("feddd_select"):
                if scheme == "fedcs":
                    part = static_part
                elif scheme == "oort":
                    part = baselines.select_oort_traced(
                        losses, num_samples=tel.num_samples,
                        system_penalty=oort_penalty,
                        model_bytes=tel.model_bytes, budget=oort_budget)
                else:                    # feddd / fedavg: everyone
                    part = jnp.ones((n,), bool)
            # jax.named_scope: compile-time operator-name metadata only
            # (repro.obs phase vocabulary in HLO / profiler traces); the
            # compiled program is independent of observability settings.
            with jax.named_scope("feddd_local_train"):
                stacked_new, loss_dev = train_fn(params, rk)
                loss_dev = jnp.asarray(loss_dev, jnp.float32)
            with jax.named_scope("feddd_encode_masks"):
                if dense:
                    # Non-participants must not train this round: the
                    # vmapped trainer computed every row, participation
                    # masks the results back to stale params/losses
                    # (exactly the per-round executor's rule).
                    pexp = part.reshape
                    stacked_new = jax.tree_util.tree_map(
                        lambda new, old: jnp.where(
                            pexp((-1,) + (1,) * (new.ndim - 1)), new, old),
                        stacked_new, params)
                    loss_dev = jnp.where(part, loss_dev, losses)
                    masks, density = _dense_masks(stacked_new, n)
                else:
                    masks, density = selection.build_masks_batched(
                        params, stacked_new, d_used, config=sel_cfg,
                        rng=rk)
            # wire format: same static branches as _round_step — the
            # server aggregates the decoded (possibly quantized) uploads
            # and the measured mask/scale overhead rides the trace
            with jax.named_scope("feddd_encode_wire"):
                stacked_agg = wire_quant.quantize_dequantize_stacked(
                    stacked_new, rk, comm.qbits)
                wire_oh = _wire_overhead(masks, stacked_new, comm,
                                         sel_cfg.channel_axis, dense)
            with jax.named_scope("feddd_aggregate"):
                new_global = aggregation.aggregate_sparse_stacked(
                    stacked_agg, masks, weights * part,
                    prev_global=gparams, use_kernel=sel_cfg.use_kernel,
                    robust=robust)
            with jax.named_scope("feddd_client_update"):
                if dense:
                    new_clients = _adopt_global(new_global, stacked_new)
                else:
                    # t is traced inside the scan, so the Eq. (5)/(6)
                    # choice is a ``lax.cond`` over the round index — one
                    # branch executes per round (the sequential step's two
                    # static compiles become the conditional's two arms).
                    # A masked select would be wrong-by-ulp anyway: Eq. (5)
                    # with an all-ones mask computes g*1 + l*0, and
                    # -0.0 + 0.0 is +0.0, flipping signed zeros vs the
                    # adopt-global copy.
                    full = (t % h) == 0
                    new_clients = lax.cond(
                        full,
                        lambda g, l, m: _adopt_global(g, l),
                        aggregation.client_update_sparse,
                        new_global, stacked_new, masks)
            # Step 5: dropout-rate re-allocation for round t+1 (feddd).
            # The f32 clip mirrors the host dispatcher's float64 clip —
            # both feed the next round the same f32 rates.
            with jax.named_scope("feddd_allocate"):
                if dense:
                    d_next = jnp.zeros_like(dropout)
                    d_time = jnp.zeros_like(dropout)
                else:
                    # The solver self-fences with optimization_barrier
                    # (see its docstring), so inlining it here returns
                    # the same bits as the per-round host dispatch.
                    d_next, _ = allocation.solve_dropout_rates_jax(
                        *tel, jnp.maximum(loss_dev, 1e-6),
                        a_server=a_server, d_max=d_max, delta=delta,
                        global_model_bytes=global_model_bytes,
                        num_iters=alloc_iters)
                    d_next = jnp.clip(d_next, 0.0, d_max)
                    d_time = d_used
            # Eq. (12) round clock over participating clients, using the
            # dropout the uploads actually used (device f32 axis).  A
            # non-dense codec charges its analytic byte model on the
            # uplink leg — the same model the host-side driver charges —
            # while the downlink broadcast stays on the idealized mass.
            with jax.named_scope("feddd_clock"):
                u_eff = tel.model_bytes * (1.0 - d_time)
                if comm.is_default or wire_spec is None:
                    up_bytes = u_eff
                else:
                    up_bytes = analytic_wire_bytes(wire_spec, d_time,
                                                   comm, xp=jnp)
                t_all = (tel.compute_latency
                         + up_bytes / tel.uplink_rate
                         + u_eff / tel.downlink_rate)
                round_t = jnp.max(jnp.where(part, t_all, -jnp.inf))
                sim_time = sim_time + round_t
            st2 = ScanState(new_clients, new_global, loss_dev, d_next,
                            rng, sim_time)
            return st2, ScanTrace(loss_dev, density, d_next, part,
                                  round_t, sim_time, wire_oh)

        ts = t_start + jnp.arange(num_rounds, dtype=jnp.int32)
        return jax.lax.scan(body, state, ts)

    return jax.jit(run_rounds, donate_argnums=(0, 1) if donate else ())


# ------------------------------------------- client-sharded engine (SPMD)

def _leaf_sharded_reduce(num, den, gprev, dtype, *, channel_axis: int,
                         collective: str, keep_fraction: float,
                         axis_name: str):
    """Cross-shard Eq. (4) reduction of one leaf's (num, den) partials.

    ``collective="dense"``: a plain psum — exact, and on a 1-device mesh
    the identity, which is what makes the sharded engine bit-identical to
    the fused single-device step there.

    ``collective="sparse"``: the channel axis moves to the front, the
    denominator collapses to its (C,) channel profile (channel-structured
    masks make den constant along every other axis), and the partials ride
    :func:`repro.core.sparse_collective.sparse_numden_allreduce` — each
    shard ships only its top-``K = ceil(C * keep_fraction)`` channels by
    den mass plus int32 indices.  A channel with zero den has exactly-zero
    num rows, so the compaction is lossless whenever a shard's nonzero
    channel count fits the buffer; the returned overflow counts channels
    that did not.

    Returns (aggregated leaf, overflow scalar f32).
    """
    zero = jnp.float32(0.0)
    ndim = num.ndim
    ax = channel_axis % ndim if ndim else 0
    c = num.shape[ax] if ndim else 1
    if collective == "sparse" and ndim >= 1 and c > 1:
        num_cm = jnp.moveaxis(num, ax, 0)
        den_ch = jnp.moveaxis(den, ax, 0).reshape((c, -1))[:, 0]
        k = max(1, min(c, int(math.ceil(c * keep_fraction))))
        nnz = jnp.sum((den_ch > 0).astype(jnp.int32))
        num_tot_cm, den_ch_tot, ovf = \
            sparse_collective.sparse_numden_allreduce(
                num_cm, den_ch, k, axis_name, k_local=nnz)
        num_tot = jnp.moveaxis(num_tot_cm, 0, ax)
        dshape = [1] * ndim
        dshape[ax] = c
        den_tot = jnp.broadcast_to(den_ch_tot.reshape(dshape), num.shape)
        return (aggregation.finish_masked_mean(num_tot, den_tot, gprev,
                                               dtype), ovf)
    num_tot = jax.lax.psum(num, axis_name)
    den_tot = jax.lax.psum(den, axis_name)
    return (aggregation.finish_masked_mean(num_tot, den_tot, gprev, dtype),
            zero)


# One compiled fn per (mesh, selection config, round kind, comm,
# collective) — module-level cache shared across engine instances, like
# ``_round_step``'s jit cache.  Mesh objects hash on their device grid +
# axis names, so re-constructed identical meshes share the entry.
@functools.lru_cache(maxsize=64)
def _sharded_step_fn(mesh, sel_cfg: selection.SelectionConfig,
                     full_round: bool, dense_masks: bool,
                     comm: CommConfig, collective: str,
                     keep_fraction: float, robust: str = "mean"):
    p_c = jax.sharding.PartitionSpec("clients")
    p_r = jax.sharding.PartitionSpec()
    axis = "clients"
    r_kind, r_arg = aggregation.parse_robust_agg(robust)

    def body(stacked_old, stacked_new, global_params, dropout, weights,
             ids, rng):
        n_s = ids.shape[0]
        # Shard-local phases are the SAME traced arithmetic as
        # ``_round_step``: masks + QDQ fold the GLOBAL fleet positions
        # (``ids``), so every client's RNG stream is independent of how
        # the fleet is sharded.
        with jax.named_scope("feddd_encode_masks"):
            if dense_masks:
                masks, density = _dense_masks(stacked_new, n_s)
            else:
                masks, density = selection.build_masks_batched(
                    stacked_old, stacked_new, dropout, config=sel_cfg,
                    rng=rng, client_indices=ids)
        with jax.named_scope("feddd_encode_wire"):
            stacked_agg = wire_quant.quantize_dequantize_stacked(
                stacked_new, rng, comm.qbits, client_indices=ids)
            wire_oh = _wire_overhead(masks, stacked_new, comm,
                                     sel_cfg.channel_axis, dense_masks)
            if wire_oh is None:
                wire_oh = jnp.zeros((n_s,), jnp.int32)
        with jax.named_scope("feddd_aggregate"):
            g_leaves, treedef = jax.tree_util.tree_flatten(global_params)
            w_leaves = jax.tree_util.tree_leaves(stacked_agg)
            m_leaves = jax.tree_util.tree_leaves(masks)
            overflow = jnp.float32(0.0)
            if r_kind != "mean":
                # Robust variants need cross-client order statistics /
                # whole-tree norms, which shard-local (num, den) partials
                # cannot compose — dense-gather fallback: all_gather the
                # client axis (device order = fleet order) and run the
                # single-device robust reduction replicated on every
                # shard, so the result is the same arithmetic as the
                # batched engine's.
                sw_full = [jax.lax.all_gather(sw, axis, tiled=True)
                           for sw in w_leaves]
                sm_full = [jax.lax.all_gather(
                    jnp.broadcast_to(sm, sw.shape), axis, tiled=True)
                    for sw, sm in zip(w_leaves, m_leaves)]
                w_full = jax.lax.all_gather(weights, axis, tiled=True)
                out_leaves = aggregation.robust_leaf_stacks(
                    sw_full, sm_full, w_full, g_leaves, r_kind, r_arg,
                    sel_cfg.use_kernel)
            else:
                out_leaves = []
                for sw, sm, gl in zip(w_leaves, m_leaves, g_leaves):
                    bm = jnp.broadcast_to(sm, sw.shape)
                    num, den = aggregation.leaf_masked_partials(
                        sw, bm, weights, sel_cfg.use_kernel)
                    agg, ovf = _leaf_sharded_reduce(
                        num, den, gl, sw.dtype,
                        channel_axis=sel_cfg.channel_axis,
                        collective=collective,
                        keep_fraction=keep_fraction,
                        axis_name=axis)
                    overflow = overflow + ovf
                    out_leaves.append(agg)
            new_global = jax.tree_util.tree_unflatten(treedef, out_leaves)
        with jax.named_scope("feddd_client_update"):
            if full_round:
                new_clients = _adopt_global(new_global, stacked_new)
            else:
                new_clients = aggregation.client_update_sparse(
                    new_global, stacked_new, masks)
        return new_clients, new_global, density, wire_oh, overflow

    # check_rep=False: the replicated outputs (new_global, overflow) are
    # replicated BY CONSTRUCTION — psum / identical all_gather+scatter on
    # every shard — but the static replication checker cannot prove it
    # through the scatter-adds of the sparse path.
    fn = shard_map(body, mesh,
                   in_specs=(p_c, p_c, p_r, p_c, p_c, p_c, p_r),
                   out_specs=(p_c, p_r, p_c, p_c, p_r),
                   check_rep=False)
    return jax.jit(fn)


def _pad_rows(stacked, pad: int):
    """Append ``pad`` zero rows along the leading client axis."""
    return jax.tree_util.tree_map(
        lambda l: jnp.concatenate(
            [l, jnp.zeros((pad,) + l.shape[1:], l.dtype)]), stacked)


@dataclasses.dataclass
class ShardedRoundEngine:
    """Client-sharded FedDD round over a 1-D ``clients`` device mesh.

    The fleet's client axis shards over ``mesh``; per-shard mask building,
    wire encoding, Eq. (4) partials, and Eq. (5)/(6) updates run inside
    ONE ``shard_map`` so each device only ever touches its ``N/P`` rows.
    The sole cross-device traffic is the Eq. (4) (num, den) reduction —
    dense psum by default, or the compacted top-K channel exchange of
    ``core/sparse_collective.py`` (``collective="sparse"``), whose
    per-link bytes scale with (1-D).

    Contracts (tests/test_sharded_engine.py):
      * on a 1-device mesh with ``collective="dense"`` the step is
        BIT-IDENTICAL to :class:`BatchedRoundEngine` — same RNG folds
        (global fleet ids), same partial sums, psum = identity;
      * on multi-device meshes parity is allclose: psum adds per-shard
        partial sums in a different order than the single flat (N,)
        reduction, so the last float32 bit is reduction-order dependent
        (the standard SPMD ulp caveat);
      * ``collective="sparse"`` additionally reports ``overflow`` — the
        psum of channels whose den mass did not fit a shard's static
        buffer; zero overflow certifies the compacted reduction carried
        exactly the dense psum's mass.

    Clients need not divide the mesh: the trailing shard zero-pads with
    weight-0 rows (excluded from Eq. (4) by the same rule as
    non-participants) and the padded outputs are sliced off.
    """

    selection_cfg: selection.SelectionConfig = dataclasses.field(
        default_factory=selection.SelectionConfig)
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    mesh: object = None        # jax.sharding.Mesh with a "clients" axis
    collective: str = "dense"  # dense psum | sparse compacted top-K
    keep_fraction: float = 1.0  # sparse buffer: K = ceil(C * fraction)
    robust_agg: str = "mean"   # Eq. (4) variant; non-mean falls back to
                               # a dense all-gather of the client axis

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError("ShardedRoundEngine requires a mesh (see "
                             "repro.launch.mesh.make_client_mesh)")
        if "clients" not in self.mesh.axis_names:
            raise ValueError(
                f"mesh must carry a 'clients' axis; got "
                f"{self.mesh.axis_names}")
        if self.collective not in ("dense", "sparse"):
            raise ValueError(f"collective must be 'dense' or 'sparse', "
                             f"got {self.collective!r}")
        if not 0.0 < self.keep_fraction <= 1.0:
            raise ValueError(f"keep_fraction must be in (0,1], got "
                             f"{self.keep_fraction}")

    @property
    def num_shards(self) -> int:
        return self.mesh.devices.size

    def step(self, stacked_old, stacked_new, global_params,
             dropout_rates, weights, rng, *, full_round: bool,
             dense_masks: bool = False, stacked_upload=None,
             delivered=None) -> RoundOutputs:
        """One sharded round step; same signature and outputs as
        :meth:`BatchedRoundEngine.step` (wire overhead is None with the
        default comm, and ``collective_overflow`` reports the sparse
        collective's missed-channel count)."""
        if stacked_upload is not None or delivered is not None:
            raise NotImplementedError(
                "upload overrides / delivered prefixes are single-device "
                "engine features (fault corruption and deadline partial "
                "aggregation do not shard)")
        n = jax.tree_util.tree_leaves(stacked_new)[0].shape[0]
        p = self.num_shards
        pad = (-n) % p
        d = jnp.asarray(dropout_rates, jnp.float32)
        w = jnp.asarray(weights, jnp.float32)
        so, sn = stacked_old, stacked_new
        if pad:
            so = _pad_rows(so, pad)
            sn = _pad_rows(sn, pad)
            d = jnp.concatenate([d, jnp.zeros((pad,), jnp.float32)])
            w = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)])
        ids = jnp.arange(n + pad, dtype=jnp.int32)
        fn = _sharded_step_fn(self.mesh, self.selection_cfg,
                              bool(full_round), bool(dense_masks),
                              self.comm, self.collective,
                              float(self.keep_fraction),
                              str(self.robust_agg))
        new_clients, new_global, density, wire_oh, overflow = fn(
            so, sn, global_params, d, w, ids, rng)
        if pad:
            new_clients = jax.tree_util.tree_map(lambda l: l[:n],
                                                 new_clients)
            density = density[:n]
            wire_oh = wire_oh[:n]
        return RoundOutputs(new_clients, new_global, density,
                            None if self.comm.is_default else wire_oh,
                            overflow)

    def shard_spec(self):
        """NamedSharding that places a client-stacked pytree's rows on
        their shards (device_put the persistent stacked state with this so
        jit dispatches never re-shard host arrays)."""
        return jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec("clients"))


# --------------------------------------------------- shape-grouped engine

def _slice_leaf(g: jax.Array, local_shape) -> jax.Array:
    """HeteroFL width slicing: the leading [0:s) block of every axis."""
    if tuple(g.shape) == tuple(local_shape):
        return g
    return g[tuple(slice(0, s) for s in local_shape)]


def slice_pytree(global_params, local_template):
    """Slice a full-width pytree down to a sub-model's local widths."""
    return jax.tree_util.tree_map(
        lambda g, l: _slice_leaf(g, l.shape), global_params, local_template)


@functools.partial(jax.jit,
                   static_argnames=("sel_cfg", "full_round", "dense_masks",
                                    "comm", "robust"))
def _grouped_round_step(groups: Tuple[GroupBatch, ...], global_params,
                        weights, rng, *,
                        sel_cfg: selection.SelectionConfig,
                        full_round: bool,
                        dense_masks: bool = False,
                        comm: CommConfig = CommConfig(),
                        robust: str = "mean") -> GroupedRoundOutputs:
    n = weights.shape[0]
    group_masks, group_agg, group_idx = [], [], []
    densities = jnp.zeros((n,), jnp.float32)
    wire_oh = None if comm.is_default else jnp.zeros((n,), jnp.int32)
    # jax.named_scope blocks: compile-time operator-name metadata only
    # (repro.obs phase vocabulary) — the program is independent of
    # observability settings.
    with jax.named_scope("feddd_encode_masks"):
        for g in groups:
            if dense_masks:
                ng = g.indices.shape[0]
                masks = jax.tree_util.tree_map(
                    lambda l: jnp.ones((ng,) + (1,) * (l.ndim - 1),
                                       l.dtype),
                    g.stacked_new)
                dens = jnp.ones((ng,), jnp.float32)
            else:
                masks, dens = selection.build_masks_batched(
                    g.stacked_old, g.stacked_new,
                    jnp.asarray(g.dropout, jnp.float32), config=sel_cfg,
                    rng=rng, coverage=g.coverage,
                    client_indices=g.indices)
            group_masks.append(masks)
            # wire format: the aggregate consumes the decoded (possibly
            # quantized) uploads; per-member keys fold the FLEET
            # positions, matching the per-client loop (repro.comm
            # .quantize)
            group_agg.append(wire_quant.quantize_dequantize_stacked(
                g.stacked_new, rng, comm.qbits,
                client_indices=g.indices))
            group_idx.append(g.indices)
            densities = densities.at[g.indices].set(dens)
            if wire_oh is not None:
                wire_oh = wire_oh.at[g.indices].set(_wire_overhead(
                    masks, g.stacked_new, comm, sel_cfg.channel_axis,
                    dense_masks))
    with jax.named_scope("feddd_aggregate"):
        new_global = aggregation.aggregate_sparse_grouped(
            group_agg, group_masks, group_idx, weights, global_params,
            prev_global=global_params, use_kernel=sel_cfg.use_kernel,
            robust=robust)
    with jax.named_scope("feddd_client_update"):
        new_group_params = []
        for g, masks in zip(groups, group_masks):
            g_local = slice_pytree(new_global,
                                   unstack_pytree(g.stacked_new, 1)[0])
            if full_round:
                # Eq. (6): every member adopts its slice of the fresh
                # global.
                upd = jax.tree_util.tree_map(
                    lambda gl, l: jnp.broadcast_to(gl, l.shape)
                    .astype(l.dtype),
                    g_local, g.stacked_new)
            else:
                # Eq. (5): the local-width global broadcasts over the
                # group axis.
                upd = aggregation.client_update_sparse(
                    g_local, g.stacked_new, masks)
            new_group_params.append(upd)
    return GroupedRoundOutputs(tuple(new_group_params), new_global,
                               densities, wire_oh)


# One compiled grouped-sharded fn per (mesh, selection config, round kind,
# comm, shape census) — jit keyed like ``_grouped_round_step`` plus the
# static mesh.
@functools.partial(jax.jit,
                   static_argnames=("sel_cfg", "full_round", "dense_masks",
                                    "comm", "mesh"))
def _sharded_grouped_round_step(groups: Tuple[GroupBatch, ...],
                                global_params, weights_ext, rng, *,
                                sel_cfg: selection.SelectionConfig,
                                full_round: bool,
                                dense_masks: bool = False,
                                comm: CommConfig = CommConfig(),
                                mesh=None) -> GroupedRoundOutputs:
    """Grouped round with every group's MEMBER axis sharded over a 1-D
    ``clients`` mesh.

    Per group, one ``shard_map`` runs the shard-local phases (masks at
    native widths, wire encoding, Eq. (4) partials zero-padded to global
    widths) and psums the group's (num, den); the group partials then add
    across groups — Eq. (4)'s sums are linear, so group-then-total
    summation is exact up to float reduction order — before one shared
    :func:`repro.core.aggregation.finish_masked_mean`.  Eq. (5)/(6)
    updates stay row-parallel GSPMD ops over the sharded member stacks.

    ``weights_ext`` is the (N+1,) fleet weight vector with a ZERO sentinel
    at row N: callers pad each group's member axis to a mesh multiple with
    zero rows carrying canvas id N, so padded rows weigh nothing and their
    densities land on the sliced-off sentinel row.  Returns canvases of
    width N (the sentinel row is sliced before returning).
    """
    p_c = jax.sharding.PartitionSpec("clients")
    p_r = jax.sharding.PartitionSpec()
    n1 = weights_ext.shape[0]                # N + 1 (sentinel)
    g_leaves, treedef = jax.tree_util.tree_flatten(global_params)
    global_shapes = tuple(l.shape for l in g_leaves)     # static
    num_tot = [jnp.zeros(s, jnp.float32) for s in global_shapes]
    den_tot = [jnp.zeros(s, jnp.float32) for s in global_shapes]
    densities = jnp.zeros((n1,), jnp.float32)
    wire_oh = None if comm.is_default else jnp.zeros((n1,), jnp.int32)
    staged = []                              # (group, masks, dens, oh)

    for g in groups:
        def body(old, new, dropout, w_rows, ids, cov, rng):
            m = ids.shape[0]
            with jax.named_scope("feddd_encode_masks"):
                if dense_masks:
                    masks = jax.tree_util.tree_map(
                        lambda l: jnp.ones((m,) + (1,) * (l.ndim - 1),
                                           l.dtype), new)
                    dens = jnp.ones((m,), jnp.float32)
                else:
                    masks, dens = selection.build_masks_batched(
                        old, new, dropout, config=sel_cfg, rng=rng,
                        coverage=cov, client_indices=ids)
            with jax.named_scope("feddd_encode_wire"):
                agg = wire_quant.quantize_dequantize_stacked(
                    new, rng, comm.qbits, client_indices=ids)
                oh = _wire_overhead(masks, new, comm,
                                    sel_cfg.channel_axis, dense_masks)
                if oh is None:
                    oh = jnp.zeros((m,), jnp.int32)
            with jax.named_scope("feddd_aggregate"):
                nums, dens_l = [], []
                for sw, sm, gshape in zip(
                        jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(masks), global_shapes):
                    bm = jnp.broadcast_to(sm, sw.shape)
                    num, den = aggregation.leaf_masked_partials(
                        sw, bm, w_rows, sel_cfg.use_kernel)
                    pads = [(0, gs - ls)
                            for gs, ls in zip(gshape, num.shape)]
                    num = jnp.pad(num, pads)
                    den = jnp.pad(den, pads)
                    nums.append(jax.lax.psum(num, "clients"))
                    dens_l.append(jax.lax.psum(den, "clients"))
            return masks, dens, oh, tuple(nums), tuple(dens_l)

        w_rows = weights_ext[g.indices]
        masks, dens, oh, nums, dens_l = shard_map(
            body, mesh,
            in_specs=(p_c, p_c, p_c, p_c, p_c, p_r, p_r),
            out_specs=(p_c, p_c, p_c, p_r, p_r),
            check_rep=False)(g.stacked_old, g.stacked_new,
                             g.dropout, w_rows, g.indices, g.coverage,
                             rng)
        num_tot = [a + b for a, b in zip(num_tot, nums)]
        den_tot = [a + b for a, b in zip(den_tot, dens_l)]
        staged.append((g, masks, dens, oh))

    out_leaves = [aggregation.finish_masked_mean(num, den, gl, gl.dtype)
                  for num, den, gl in zip(num_tot, den_tot, g_leaves)]
    new_global = jax.tree_util.tree_unflatten(treedef, out_leaves)

    with jax.named_scope("feddd_client_update"):
        new_group_params = []
        for g, masks, dens, oh in staged:
            densities = densities.at[g.indices].set(dens)
            if wire_oh is not None:
                wire_oh = wire_oh.at[g.indices].set(oh)
            g_local = slice_pytree(new_global,
                                   unstack_pytree(g.stacked_new, 1)[0])
            if full_round:
                upd = jax.tree_util.tree_map(
                    lambda gl, l: jnp.broadcast_to(gl, l.shape)
                    .astype(l.dtype),
                    g_local, g.stacked_new)
            else:
                upd = aggregation.client_update_sparse(
                    g_local, g.stacked_new, masks)
            new_group_params.append(upd)
    return GroupedRoundOutputs(tuple(new_group_params), new_global,
                               densities[:-1],
                               None if wire_oh is None else wire_oh[:-1])


@dataclasses.dataclass
class GroupedRoundEngine:
    """One-jit-call FedDD round over a shape-grouped ragged fleet.

    The heterogeneous counterpart of :class:`BatchedRoundEngine`: clients
    are partitioned by sub-model shape (``repro.fl.heterogeneity
    .group_by_shape``), each group's parameters stack along a leading member
    axis, and ONE jit-compiled step per shape census runs, for every group,

        coverage-aware batched mask building (Eq. (20)/(21) scores at the
        group's NATIVE widths — no padded waste),
        the scatter of each group's masked update into the full-width
        aggregation canvas (:func:`repro.core.aggregation
        .aggregate_sparse_grouped`, bit-identical to the padded loop), and
        the Eq. (5)/(6) client updates at local widths.

    Group membership (``GroupBatch.indices``) is traced, so deadline drops,
    async buffers, and re-grouped fleets with the same shape census reuse
    the compiled step; a new census (different group shapes/sizes) compiles
    once.  Exclusion and staleness enter exactly as in the homogeneous
    engine: per-client weights on the stacked Eq. (4) aggregation, indexed
    by canvas row.

    With ``mesh`` (a 1-D ``clients`` device mesh) each group's MEMBER axis
    shards over the devices: shard-local masks/partials per group inside
    ``shard_map``, per-group psum of the Eq. (4) (num, den), group partials
    summed before one shared division (see
    :func:`_sharded_grouped_round_step`).  Parity with the single-device
    grouped step is allclose (per-group-then-total summation reorders the
    float reduction); clients need not divide the mesh — padded member
    rows carry weight 0 via the sentinel canvas row.
    """

    selection_cfg: selection.SelectionConfig = dataclasses.field(
        default_factory=selection.SelectionConfig)
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    mesh: object = None        # optional jax.sharding.Mesh ("clients")
    robust_agg: str = "mean"   # Eq. (4) variant (single-device only:
                               # the sharded-grouped step composes
                               # per-group psums, which robust variants
                               # cannot ride)

    def __post_init__(self):
        if self.mesh is not None and "clients" not in self.mesh.axis_names:
            raise ValueError(
                f"mesh must carry a 'clients' axis; got "
                f"{self.mesh.axis_names}")
        if self.mesh is not None and str(self.robust_agg) != "mean":
            raise NotImplementedError(
                "robust_agg is a single-device grouped-engine feature: "
                "the sharded-grouped step sums per-group (num, den) "
                "partials across shards, which trimmed/clip aggregation "
                "cannot compose with")

    def step(self, groups: Sequence[GroupBatch], global_params,
             weights, rng, *, full_round: bool,
             dense_masks: bool = False) -> GroupedRoundOutputs:
        """Run one round's server side over the grouped fleet.

        Args:
          groups: one :class:`GroupBatch` per shape group; ``indices`` are
            rows into ``weights`` / the densities canvas AND the ids the
            per-client mask keys fold in (fleet positions for protocol/wave
            runs; buffer positions for async merges).
          global_params: current full-width global pytree.
          weights: (N,) aggregation weights m_n indexed by canvas row; zero
            excludes that row (non-participation, deadline drops,
            staleness-decayed async merges).
          rng: the ROUND key (the per-client loop's split).
          full_round / dense_masks: as in :meth:`BatchedRoundEngine.step`.
        """
        if self.mesh is None:
            return _grouped_round_step(
                tuple(groups), global_params,
                jnp.asarray(weights, jnp.float32), rng,
                sel_cfg=self.selection_cfg, full_round=bool(full_round),
                dense_masks=bool(dense_masks), comm=self.comm,
                robust=str(self.robust_agg))
        return self._step_sharded(groups, global_params, weights, rng,
                                  full_round=full_round,
                                  dense_masks=dense_masks)

    def _step_sharded(self, groups, global_params, weights, rng, *,
                      full_round: bool, dense_masks: bool
                      ) -> GroupedRoundOutputs:
        p = self.mesh.devices.size
        w = jnp.asarray(weights, jnp.float32)
        n = w.shape[0]
        w_ext = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])
        padded, sizes = [], []
        for g in groups:
            n_g = jax.tree_util.tree_leaves(g.stacked_new)[0].shape[0]
            sizes.append(n_g)
            pad = (-n_g) % p
            idx = jnp.asarray(g.indices, jnp.int32)
            drop = jnp.asarray(g.dropout, jnp.float32)
            if pad:
                g = GroupBatch(
                    indices=jnp.concatenate(
                        [idx, jnp.full((pad,), n, jnp.int32)]),
                    stacked_old=_pad_rows(g.stacked_old, pad),
                    stacked_new=_pad_rows(g.stacked_new, pad),
                    coverage=g.coverage,
                    dropout=jnp.concatenate(
                        [drop, jnp.zeros((pad,), jnp.float32)]))
            else:
                g = GroupBatch(idx, g.stacked_old, g.stacked_new,
                               g.coverage, drop)
            padded.append(g)
        out = _sharded_grouped_round_step(
            tuple(padded), global_params, w_ext, rng,
            sel_cfg=self.selection_cfg, full_round=bool(full_round),
            dense_masks=bool(dense_masks), comm=self.comm, mesh=self.mesh)
        group_params = tuple(
            (jax.tree_util.tree_map(lambda l: l[:n_g], gp)
             if jax.tree_util.tree_leaves(gp)[0].shape[0] != n_g else gp)
            for gp, n_g in zip(out.group_client_params, sizes))
        return GroupedRoundOutputs(group_params, out.global_params,
                                   out.densities, out.wire_overhead)


def train_grouped(groups, group_stacked, group_coverage, local_train_fn,
                  rk, part, losses, d_used, *, dense: bool,
                  num_clients: int):
    """Per-client local training over grouped stacked state + GroupBatch
    assembly — the host-side half of a grouped round, shared by the
    protocol executor and the sim runner so the two stay in lockstep.

    Trains member ``i`` iff ``part[i]`` (callers pass all-ones for feddd,
    where everyone trains); non-participants keep stale params and their
    stale loss.  Returns ``(loss_dev, batches)``: per-client device losses
    in fleet order and one complete :class:`GroupBatch` per group.
    """
    loss_dev: List = [None] * num_clients
    batches: List[GroupBatch] = []
    for grp, stacked, cov in zip(groups, group_stacked, group_coverage):
        per_client = unstack_pytree(stacked, grp.size)
        new_list = []
        for pos, i in enumerate(grp.indices):
            if part[i]:
                p, l = local_train_fn(per_client[pos], i,
                                      jax.random.fold_in(rk, i))
            else:
                p, l = per_client[pos], losses[i]
            new_list.append(p)
            loss_dev[i] = l
        batches.append(GroupBatch(
            indices=jnp.asarray(grp.indices, jnp.int32),
            stacked_old=stacked,
            stacked_new=stack_pytrees(new_list),
            coverage=None if dense else cov,
            dropout=jnp.asarray(d_used[list(grp.indices)], jnp.float32)))
    return loss_dev, batches


def unstack_groups(groups, group_stacked, num_clients: int) -> List:
    """Grouped stacked state -> per-client pytree list in fleet order."""
    params: List = [None] * num_clients
    for grp, stacked in zip(groups, group_stacked):
        for i, p in zip(grp.indices, unstack_pytree(stacked, grp.size)):
            params[i] = p
    return params


class GroupedFleetState:
    """Host-side state of a ragged fleet between grouped rounds.

    Owns the per-group stacked params (persisting across rounds — nothing
    re-stacks between them) and the train -> step -> export cycle, so the
    protocol executor and the sim runner drive the grouped engine through
    ONE implementation and cannot drift apart.
    """

    def __init__(self, groups, group_coverage, client_params,
                 selection_cfg: selection.SelectionConfig,
                 num_clients: int, comm: CommConfig = CommConfig(),
                 mesh=None, robust_agg: str = "mean"):
        self.engine = GroupedRoundEngine(selection_cfg, comm, mesh,
                                         robust_agg)
        self.groups = groups
        self.coverage = group_coverage
        self.num_clients = num_clients
        self.group_stacked = [
            stack_pytrees([client_params[i] for i in g.indices])
            for g in groups
        ]
        self._batches = None

    def train(self, local_train_fn, rk, part, losses, d_used,
              *, dense: bool) -> List:
        """Run local training and stage this round's GroupBatches; returns
        per-client device losses (fleet order)."""
        loss_dev, self._batches = train_grouped(
            self.groups, self.group_stacked, self.coverage, local_train_fn,
            rk, part, losses, d_used, dense=dense,
            num_clients=self.num_clients)
        return loss_dev

    def step(self, global_params, weights, rk, *, full_round: bool,
             dense: bool):
        """One grouped engine step over the staged batches; returns
        ``(new_global, densities, wire_overhead)`` and rebinds the stacked
        client state (``wire_overhead`` is None with the default comm)."""
        out = self.engine.step(self._batches, global_params, weights, rk,
                               full_round=full_round, dense_masks=dense)
        self.group_stacked = list(out.group_client_params)
        return out.global_params, out.densities, out.wire_overhead

    def discard(self) -> None:
        """Drop a staged round without stepping: client params stay at
        their pre-training state (quorum-skipped rounds, sim/faults.py)."""
        self._batches = None

    @property
    def staged_batches(self):
        """The GroupBatches ``train()`` staged for the next ``step()``
        (read-only view for the sim runner's payload-validation screen)."""
        return self._batches

    def export(self) -> List:
        """Per-client pytree list in fleet order (host-side sync point)."""
        return unstack_groups(self.groups, self.group_stacked,
                              self.num_clients)


def make_batched_train_fn(per_client_step, stacked_data):
    """vmap a per-client ``step(params, *client_data) -> (params, loss)``
    into ``(stacked_params, rng) -> (stacked_params, (N,) losses)``.

    Convenience for fully-fused rounds when every client's data shard has
    the same shape (the benchmark's homogeneous setting).  ``stacked_data``
    is a tuple of arrays with a leading client axis.
    """
    def batched(stacked_params, rng):
        del rng
        return jax.vmap(per_client_step)(stacked_params, *stacked_data)

    return batched
