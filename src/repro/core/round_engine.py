"""Batched FedDD round engine — the homogeneous hot path, fully on device.

``FedDDServer.run`` executes Algorithm 1 as a Python loop over clients:
per-client ``build_masks`` dispatches, per-leaf ``float(...)`` host syncs in
``mask_density``, list-based padding and aggregation.  At simulation scale
(hundreds of clients) dispatch overhead — not compute — dominates.

This module stacks client parameter pytrees along a leading client axis and
rewrites the round's server side as ONE ``jax.jit``-compiled step:

    importance scoring   — client axis folded into the channel axis, one
                           pass per leaf (Pallas kernel when use_kernel)
    mask building        — full-width ``lax.top_k`` ranks + a dynamic
                           ``rank < keep`` compare, vmapped over clients
    masked aggregation   — Eq. (4) over the already-stacked leaves
                           (Pallas sparse_agg kernel when use_kernel)
    sparse client update — Eq. (5)/(6) broadcast over the client axis

Per-round device->host traffic collapses to one transfer of a small
telemetry struct (per-client upload densities, plus losses when local
training is batched too) instead of O(clients x leaves) ``float()`` calls.

Results are bit-identical to the per-client loop for a fixed seed
(tests/test_round_engine.py asserts this), so ``protocol.py`` routes every
homogeneous FedDD run through this engine and keeps the loop only for
heterogeneous (ragged-width) client models.

The engine also serves the fedavg/fedcs/oort baselines (``dense_masks``:
all-ones masks, no scoring) and the event-driven simulator
(``repro.sim.runner``): non-participation, deadline-dropped stragglers, and
staleness-decayed async merges are all expressed as per-client aggregation
weights — weight 0 excludes a client from the stacked Eq. (4) reduction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import aggregation, selection


class RoundOutputs(NamedTuple):
    """Device-side results of one batched round step."""

    client_params: object      # pytree, leaves (N, *leaf): W_n^{t+1}
    global_params: object      # pytree: W^t
    densities: jax.Array       # (N,) fraction of elements uploaded


def stack_pytrees(trees: Sequence) -> object:
    """[pytree] x N (identical structure/shapes) -> pytree of (N, *leaf)."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def unstack_pytree(stacked, n: int) -> List:
    """Inverse of :func:`stack_pytrees` (lazy device slices, no host sync)."""
    return [jax.tree_util.tree_map(lambda l: l[i], stacked)
            for i in range(n)]


# The whole server side of Algorithm 1 (steps 2-4 + 6-7) in one trace.
# Module-level jit keyed on the (hashable, frozen) SelectionConfig so the
# compile cache is shared across engine instances and server runs.
@functools.partial(jax.jit,
                   static_argnames=("sel_cfg", "full_round", "dense_masks"))
def _round_step(stacked_old, stacked_new, global_params, dropout_rates,
                weights, rng, *, sel_cfg: selection.SelectionConfig,
                full_round: bool, dense_masks: bool = False) -> RoundOutputs:
    if dense_masks:
        # Baseline rounds (fedavg/fedcs/oort): participants upload FULL
        # models, so masks are all-ones and no importance scoring runs.
        # Non-participation is a 0 in ``weights`` — a zero-weight client
        # contributes nothing to either Eq. (4) sum, exactly like being
        # left out of the aggregation list.
        n = jax.tree_util.tree_leaves(stacked_new)[0].shape[0]
        masks = jax.tree_util.tree_map(
            lambda l: jnp.ones((n,) + (1,) * (l.ndim - 1), l.dtype),
            stacked_new)
        density = jnp.ones((n,), jnp.float32)
    else:
        masks, density = selection.build_masks_batched(
            stacked_old, stacked_new, dropout_rates, config=sel_cfg, rng=rng)
    new_global = aggregation.aggregate_sparse_stacked(
        stacked_new, masks, weights, prev_global=global_params,
        use_kernel=sel_cfg.use_kernel)
    if full_round:
        # Eq. (6): every client adopts the fresh global model.
        new_clients = jax.tree_util.tree_map(
            lambda g, l: jnp.broadcast_to(g, l.shape).astype(l.dtype),
            new_global, stacked_new)
    else:
        # Eq. (5): the un-stacked global broadcasts against the (N, ...)
        # stacked leaves, so the per-client rule applies verbatim.
        new_clients = aggregation.client_update_sparse(
            new_global, stacked_new, masks)
    return RoundOutputs(new_clients, new_global, density)


@dataclasses.dataclass
class BatchedRoundEngine:
    """One-jit-call FedDD round over client-stacked parameters.

    Args:
      selection_cfg: mask-building config; ``selection_cfg.use_kernel``
        routes BOTH the importance scoring and the Eq. (4) aggregation
        through the Pallas kernels.
    """

    selection_cfg: selection.SelectionConfig = dataclasses.field(
        default_factory=selection.SelectionConfig)

    def step(self, stacked_old, stacked_new, global_params,
             dropout_rates, weights, rng, *, full_round: bool,
             dense_masks: bool = False) -> RoundOutputs:
        """Run one round's server side.

        Args:
          stacked_old / stacked_new: client params before/after local
            training, leaves (N, *leaf).
          global_params: current global pytree (un-stacked).
          dropout_rates: (N,) float32 D_n^t.
          weights: (N,) aggregation weights m_n (sample counts).  A zero
            weight excludes that client from the Eq. (4) aggregate — this
            is how baseline non-participants, deadline-dropped stragglers
            (sim/policies.py), and staleness-decayed async merges ride the
            same fused step.
          rng: the ROUND key (same key the per-client loop splits from).
          full_round: t mod h == 0 — dense broadcast round (static: the two
            variants compile once each).
          dense_masks: all-ones masks / full uploads (the fedavg / fedcs /
            oort baselines); skips importance scoring entirely (static).
        """
        return _round_step(
            stacked_old, stacked_new, global_params,
            jnp.asarray(dropout_rates, jnp.float32),
            jnp.asarray(weights, jnp.float32), rng,
            sel_cfg=self.selection_cfg, full_round=bool(full_round),
            dense_masks=bool(dense_masks))


def make_batched_train_fn(per_client_step, stacked_data):
    """vmap a per-client ``step(params, *client_data) -> (params, loss)``
    into ``(stacked_params, rng) -> (stacked_params, (N,) losses)``.

    Convenience for fully-fused rounds when every client's data shard has
    the same shape (the benchmark's homogeneous setting).  ``stacked_data``
    is a tuple of arrays with a leading client axis.
    """
    def batched(stacked_params, rng):
        del rng
        return jax.vmap(per_client_step)(stacked_params, *stacked_data)

    return batched
