"""Pure-jnp oracle for the masked merge kernel (FedDD Eq. (5)):

out = G * M + W_local * (1 - M),   M a per-channel 0/1 vector broadcast
over the fan-in dimension.
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_merge_ref(global_w: jnp.ndarray, local_w: jnp.ndarray,
                     mask_row: jnp.ndarray) -> jnp.ndarray:
    """global_w/local_w: (C, F); mask_row: (C,) in {0,1}.  Same dtype out."""
    m = mask_row.astype(jnp.float32)[:, None]
    out = (global_w.astype(jnp.float32) * m
           + local_w.astype(jnp.float32) * (1.0 - m))
    return out.astype(local_w.dtype)
