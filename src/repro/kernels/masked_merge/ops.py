"""jit'd wrapper for the masked merge kernel (rank/axis handling)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_merge.masked_merge import masked_merge_2d


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def masked_merge(global_w: jax.Array, local_w: jax.Array,
                 mask_row: jax.Array, *, channel_axis: int = -1) -> jax.Array:
    """Eq. (5) merge.  mask_row: (C,) where C = shape[channel_axis]."""
    ax = channel_axis % local_w.ndim
    g = jnp.moveaxis(global_w, ax, 0)
    l = jnp.moveaxis(local_w, ax, 0)
    c = l.shape[0]
    shape = l.shape
    out = masked_merge_2d(g.reshape(c, -1), l.reshape(c, -1),
                          mask_row.reshape(c), interpret=not _on_tpu())
    return jnp.moveaxis(out.reshape(shape), 0, ax)
