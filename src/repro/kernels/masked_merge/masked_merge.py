"""Pallas TPU kernel: fused Eq. (5) client update
``W_next = G ⊙ M + W_hat ⊙ (1 - M)`` with a per-channel mask.

Every client runs this over every parameter tensor every round (Step 7), so
fusing the broadcast + select + blend into a single HBM pass (2 reads, 1
write, mask from a (BC, 1) sliver) halves the traffic vs. materialising the
broadcast mask.  Tiling mirrors the importance kernel: (BC, BF) VMEM tiles,
mask delivered as a (BC, 1) block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 256
DEFAULT_BF = 512


def _merge_kernel(g_ref, l_ref, m_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)            # (BC, 1) broadcast
    out_ref[...] = (g * m + l * (1.0 - m)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def masked_merge_2d(global_w: jax.Array, local_w: jax.Array,
                    mask_row: jax.Array, *,
                    bc: int = DEFAULT_BC, bf: int = DEFAULT_BF,
                    interpret: bool = False) -> jax.Array:
    c, f = global_w.shape
    bc = min(bc, c)
    bf = min(bf, f)
    grid = (pl.cdiv(c, bc), pl.cdiv(f, bf))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, f), local_w.dtype),
        interpret=interpret,
    )(global_w, local_w, mask_row.reshape(c, 1))
