"""Pallas TPU kernels for FedDD's compute hot-spots (DESIGN.md §6).

  importance   fused |dW (W+dW)/W| + per-channel row reduction   (Step 2)
  sparse_agg   masked weighted aggregation over stacked clients  (Step 4)
  masked_merge fused Eq.(5) sparse global/local merge            (Step 7)

Each kernel ships ``ref.py`` (pure-jnp oracle), the Pallas kernel with
explicit BlockSpec VMEM tiling, and ``ops.py`` (jit'd wrapper; on CPU it
runs interpret=True so tests validate the kernel body bit-for-bit).
"""
