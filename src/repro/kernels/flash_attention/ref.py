"""Pure-jnp oracle for the flash-attention kernel: dense causal /
sliding-window GQA attention with fp32 softmax."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def gqa_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd); window 0 = unlimited."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg,
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= kj > qi - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshk->bqhgk", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
