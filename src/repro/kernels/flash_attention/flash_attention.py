"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA).

Grid (batch*kv_heads*g, Sq/BQ, Skv/BK): each step loads a (BQ, hd) query
tile and a (BK, hd) K/V tile into VMEM, accumulates the online-softmax
running (m, l, o) in VMEM scratch across the KV grid axis (minor-most, so
the scratch stays resident), and writes the normalised output tile on the
last KV step.  Scores therefore NEVER touch HBM — this removes the
S^2-score traffic that dominates the XLA-only lowering of 32k prefill
(EXPERIMENTS.md §Perf Q4).

Default tiles BQ=512, BK=1024, hd<=256: VMEM ~= (512+2*1024)*256*4B +
512*1024*4B (p-matrix) + scratch ~= 5 MiB — comfortably inside the 16 MiB
v5e budget, MXU-aligned (128 multiples).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38
DEFAULT_BQ = 512
DEFAULT_BK = 1024


def _flash_kernel(causal: bool, window: int, sq: int, skv: int, scale: float,
                  q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
    v = v_ref[0].astype(jnp.float32)
    bq, hd = q.shape
    bk = k.shape[0]
    # zero the padded tails (undefined memory; 0 * NaN would poison p @ v)
    q_valid = (qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, hd), 0)
               ) < sq
    kv_valid = (kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, hd), 0)
                ) < skv
    q = jnp.where(q_valid, q, 0.0)
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (rows < sq) & (cols < skv)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (BQ, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
    p = jnp.exp(s - m_new)                            # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                    # (BQ, 1)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1)[:, None]
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kj == nk - 1)
    def _finalise():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, H, hd).

    GQA: query head h reads kv head h // (H/Hkv).  Heads/batch are folded
    into the leading grid axis.
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    # fold (b, hkv, g): q -> (b*hkv*g, sq, hd); kv indexed by (b*hkv)
    qf = (q.reshape(b, sq, hkv, g, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * g, sq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)

    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    grid = (b * hkv * g, pl.cdiv(sq, bq_), pl.cdiv(skv, bk_))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal, window, sq, skv, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda n, i, j, g=g: (n // g, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda n, i, j, g=g: (n // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv * g, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return (out.reshape(b, hkv, g, sq, hd).transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, h, hd))
