"""jit'd wrapper: flash attention with CPU interpret fallback."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gqa_flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=not _on_tpu())
