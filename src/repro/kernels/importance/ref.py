"""Pure-jnp oracle for the fused importance kernel (FedDD Eq. (20)).

score[c] = sqrt( sum_f ( |dW * (W + dW) / W| )^2 )   over fan-in f,
with dW = W_new - W_old and an epsilon-guarded division.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def channel_importance_ref(w_old: jnp.ndarray, w_new: jnp.ndarray
                           ) -> jnp.ndarray:
    """w_old/w_new: (C, F) float; returns (C,) float32."""
    wo = w_old.astype(jnp.float32)
    wn = w_new.astype(jnp.float32)
    dw = wn - wo
    denom = jnp.where(jnp.abs(wo) < EPS, jnp.where(wo < 0, -EPS, EPS), wo)
    imp = jnp.abs(dw * wn / denom)
    return jnp.sqrt(jnp.sum(imp * imp, axis=1))
