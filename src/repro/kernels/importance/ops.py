"""jit'd public wrapper for the importance kernel.

Handles arbitrary tensor ranks / channel axes by folding every non-channel
axis into the fan-in dimension, then calls the Pallas kernel (interpret=True
automatically on CPU so the kernel body itself is what tests validate).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.importance.importance import channel_importance_sumsq


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def channel_importance(w_old: jax.Array, w_new: jax.Array, *,
                       channel_axis: int = -1,
                       coverage: Optional[jax.Array] = None) -> jax.Array:
    """Per-channel importance score (Eq. (20)/(21)); returns (C,) fp32."""
    ax = channel_axis % w_old.ndim
    wo = jnp.moveaxis(w_old, ax, 0)
    wn = jnp.moveaxis(w_new, ax, 0)
    c = wo.shape[0]
    wo = wo.reshape(c, -1)
    wn = wn.reshape(c, -1)
    ss = channel_importance_sumsq(wo, wn, interpret=not _on_tpu())
    score = jnp.sqrt(ss)
    if coverage is not None:
        score = score / jnp.maximum(coverage, 1e-8)
    return score


def channel_importance_batched(w_old: jax.Array, w_new: jax.Array, *,
                               channel_axis: int = -1,
                               coverage: Optional[jax.Array] = None
                               ) -> jax.Array:
    """Client-stacked importance: (N, *leaf) x2 -> (N, C) fp32.

    The client axis folds into the kernel's channel axis — every (client,
    channel) row is an independent fan-in reduction, so a single (N*C, F)
    pallas_call scores all clients in one HBM pass with the same per-row
    accumulation order as N separate (C, F) calls (bit-identical results).
    """
    ax = channel_axis % (w_old.ndim - 1) + 1
    n = w_old.shape[0]
    wo = jnp.moveaxis(w_old, ax, 1)
    wn = jnp.moveaxis(w_new, ax, 1)
    c = wo.shape[1]
    wo = wo.reshape(n * c, -1)
    wn = wn.reshape(n * c, -1)
    ss = channel_importance_sumsq(wo, wn, interpret=not _on_tpu())
    score = jnp.sqrt(ss).reshape(n, c)
    if coverage is not None:
        score = score / jnp.maximum(coverage, 1e-8)
    return score
