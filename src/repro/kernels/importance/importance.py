"""Pallas TPU kernel: fused per-channel importance (FedDD Eq. (20)).

Tiling: grid (C/BC, F/BF); W_old/W_new blocks (BC, BF) stream HBM->VMEM; the
(BC,) partial sum-of-squares accumulates in the output block, which is
revisited across the fan-in grid axis (output index_map ignores j, so the
block stays VMEM-resident over the minor grid dimension — the standard TPU
reduction pattern).  MXU is not involved (elementwise + row reduce): the
kernel is memory-bound by design, its value is fusing three elementwise ops
+ reduction into one HBM pass over two weight tensors.

Block sizes default to (256, 512): 2 * 256*512*4B = 1 MiB of VMEM for the
inputs — comfortably within the ~16 MiB v5e VMEM budget while keeping the
last dim a multiple of the 128-lane register tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8
DEFAULT_BC = 256
DEFAULT_BF = 512


def _importance_kernel(c: int, f: int, w_old_ref, w_new_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    wo = w_old_ref[...].astype(jnp.float32)
    wn = w_new_ref[...].astype(jnp.float32)
    bc, bf = wo.shape
    # mask the padded tail of non-divisible shapes (padding is undefined)
    row = i * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
    col = j * bf + jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 1)
    valid = (row < c) & (col < f)
    wo = jnp.where(valid, wo, 1.0)
    wn = jnp.where(valid, wn, 1.0)
    dw = wn - wo
    denom = jnp.where(jnp.abs(wo) < EPS, jnp.where(wo < 0, -EPS, EPS), wo)
    imp = jnp.abs(dw * wn / denom)
    partial = jnp.sum(imp * imp, axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def channel_importance_sumsq(w_old: jax.Array, w_new: jax.Array, *,
                             bc: int = DEFAULT_BC, bf: int = DEFAULT_BF,
                             interpret: bool = False) -> jax.Array:
    """(C, F) x2 -> (C,) float32 sum of squared importances (pre-sqrt)."""
    c, f = w_old.shape
    bc = min(bc, c)
    bf = min(bf, f)
    grid = (pl.cdiv(c, bc), pl.cdiv(f, bf))
    return pl.pallas_call(
        functools.partial(_importance_kernel, c, f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=interpret,
    )(w_old, w_new)
