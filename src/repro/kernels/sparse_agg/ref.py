"""Pure-jnp oracle for the sparse aggregation kernel (FedDD Eq. (4)).

num[c,f] = sum_n  w_n * W[n,c,f] * M[n,c,f]
den[c,f] = sum_n  w_n * M[n,c,f]
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def masked_weighted_sum_ref(stack_w: jnp.ndarray, stack_m: jnp.ndarray,
                            weights: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """stack_w/stack_m: (N, C, F); weights: (N,).  fp32 outputs (C, F)."""
    wts = weights.astype(jnp.float32).reshape(-1, 1, 1)
    sw = stack_w.astype(jnp.float32)
    sm = stack_m.astype(jnp.float32)
    num = jnp.sum(sw * sm * wts, axis=0)
    den = jnp.sum(sm * wts, axis=0)
    return num, den
