"""Pallas TPU kernel: FedDD server aggregation (Eq. (4)) over client-stacked
tensors.

Inputs are stacked (N, C, F) client weights + masks and an (N,) weight
vector; outputs are the fp32 (C, F) numerator and denominator.  Tiling:
grid (C/BC, F/BF); each step streams the FULL client axis for one (BC, BF)
tile — the client axis is the reduction axis, and N is small (pods/clients,
<= 32), so the (N, BC, BF) block fits VMEM: with the default (128, 256) tile
and N=32, 2 * 32*128*256*4B = 8 MiB.  Weights live in SMEM-friendly (N, 1)
blocks.

This is the fusion the server hot loop wants: one HBM pass over the two
stacked tensors produces both Eq. (4) reduction terms (XLA would otherwise
materialise the (N, C, F) masked product).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 128
DEFAULT_BF = 256


def _agg_kernel(w_stack_ref, m_stack_ref, wts_ref, num_ref, den_ref):
    sw = w_stack_ref[...].astype(jnp.float32)     # (N, BC, BF)
    sm = m_stack_ref[...].astype(jnp.float32)
    wts = wts_ref[...].astype(jnp.float32)        # (N, 1)
    wb = wts[:, :, None]                          # (N, 1, 1)
    num_ref[...] = jnp.sum(sw * sm * wb, axis=0)
    den_ref[...] = jnp.sum(sm * wb, axis=0)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def masked_weighted_sum_2d(stack_w: jax.Array, stack_m: jax.Array,
                           weights: jax.Array, *,
                           bc: int = DEFAULT_BC, bf: int = DEFAULT_BF,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """(N, C, F) x2 + (N,) -> ((C, F) num fp32, (C, F) den fp32)."""
    n, c, f = stack_w.shape
    bc = min(bc, c)
    bf = min(bf, f)
    grid = (pl.cdiv(c, bc), pl.cdiv(f, bf))
    num, den = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bc, bf), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, bc, bf), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bc, bf), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, f), jnp.float32),
            jax.ShapeDtypeStruct((c, f), jnp.float32),
        ],
        interpret=interpret,
    )(stack_w, stack_m, weights.reshape(n, 1))
    return num, den
