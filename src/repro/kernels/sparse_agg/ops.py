"""jit'd wrapper: reshapes arbitrary-rank stacked client tensors to
(N, C, F) and dispatches to the Pallas kernel (interpret=True off-TPU)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sparse_agg.sparse_agg import masked_weighted_sum_2d


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def masked_weighted_sum(stack_w: jax.Array, stack_m: jax.Array,
                        weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """stack_w/stack_m: (N, ...) identical shapes; weights: (N,).

    Returns (num, den) with the original trailing shape, fp32.
    """
    n = stack_w.shape[0]
    orig = stack_w.shape[1:]
    if stack_w.ndim == 2:
        sw = stack_w.reshape(n, 1, -1)
        sm = stack_m.reshape(n, 1, -1)
    else:
        c = stack_w.shape[1]
        sw = stack_w.reshape(n, c, -1)
        sm = jnp.broadcast_to(stack_m, stack_w.shape).reshape(n, c, -1)
    num, den = masked_weighted_sum_2d(sw, sm, weights,
                                      interpret=not _on_tpu())
    return num.reshape(orig), den.reshape(orig)
