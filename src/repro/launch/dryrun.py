import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

MUST be run as a module:  PYTHONPATH=src python -m repro.launch.dryrun \
    [--arch ID ...] [--shape NAME ...] [--mesh single|multi|both] [--force]

The XLA_FLAGS line above precedes every other import (jax locks the device
count on first initialisation).  Results are cached incrementally under
results/dryrun/ so interrupted sweeps resume.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as specs_mod
from repro.launch.hlo_analysis import (Hardware, Roofline,
                                       collective_bytes_per_device,
                                       model_flops)
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.sharding import reset_rules, set_rules
from repro.optim import adafactor, adamw

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _opt(name: str):
    return adafactor(1e-2) if name == "adafactor" else adamw(3e-4)


def build_lowerable(cfg, shape_name: str):
    """Returns (fn, example_args (abstract), in_shardings) for jit."""
    seq, batch, kind = specs_mod.SHAPES[shape_name]
    pol = specs_mod.policy_for(cfg)
    if kind == "train":
        opt = _opt(pol.optimizer)
        step = lm.make_train_step(cfg, opt,
                                  num_microbatches=pol.num_microbatches)
        ts_shape = jax.eval_shape(
            lambda: lm.init_train_state(jax.random.PRNGKey(0), cfg, opt))
        batch_abs = specs_mod.input_specs(cfg, shape_name)
        ts_specs = lm.train_state_pspecs(cfg, ts_shape)
        b_specs = jax.tree_util.tree_map(
            lambda s: _batch_spec(s), batch_abs)
        return step, (ts_shape, batch_abs), (ts_specs, b_specs)
    if kind == "prefill":
        def prefill(params, batch_in):
            logits, _ = lm.forward(params, cfg, batch_in)
            return logits[:, -1]
        p_shape = lm.abstract_params(cfg)
        batch_abs = specs_mod.input_specs(cfg, shape_name)
        p_specs = lm.param_pspecs(cfg, p_shape)
        b_specs = jax.tree_util.tree_map(lambda s: _batch_spec(s), batch_abs)
        return prefill, (p_shape, batch_abs), (p_specs, b_specs)
    # decode
    cfg_eff = specs_mod.effective_decode_config(cfg, shape_name)
    serve = lm.make_serve_step(cfg_eff)
    p_shape = lm.abstract_params(cfg_eff)
    state_abs, tok_abs = specs_mod.decode_specs(cfg, shape_name)
    p_specs = lm.param_pspecs(cfg_eff, p_shape)
    s_specs = lm.decode_state_pspecs(cfg_eff, state_abs)
    t_spec = _batch_spec(tok_abs)
    return serve, (p_shape, state_abs, tok_abs), (p_specs, s_specs, t_spec)


def _batch_spec(sds):
    from repro.models.sharding import spec
    if sds.ndim >= 2:
        axes = ("batch", "seq") + (None,) * (sds.ndim - 2)
    elif sds.ndim == 1:
        axes = ("batch",)
    else:
        axes = ()
    return spec(*axes, shape=sds.shape)


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             rules: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    ok, reason = specs_mod.should_run(cfg, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "status": "skip", "reason": reason}
    if not ok:
        return rec
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    reset_rules()
    pol_rules = specs_mod.policy_for(cfg).rules
    if pol_rules:
        set_rules(**pol_rules)
    if rules:
        set_rules(**rules)
    try:
        seqk = specs_mod.SHAPES[shape_name][2]
        donate = (0,) if seqk == "train" else ((1,) if seqk == "decode" else ())
        with jax.sharding.set_mesh(mesh):
            fn, args, in_specs = build_lowerable(cfg, shape_name)
            lowered = jax.jit(fn, in_shardings=in_specs,
                              donate_argnums=donate).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes_per_device(hlo)
        seq, batch, kind = specs_mod.SHAPES[shape_name]
        rl = Roofline(
            flops_per_device=float(cost.get("flops", 0.0)),
            bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            collective_per_device=coll, num_devices=n_dev)
        mf = model_flops(cfg, seq, batch, kind)
        hlo_total_flops = rl.flops_per_device * n_dev
        rec.update({
            "status": "ok",
            "num_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (mem.argument_size_in_bytes
                                        + mem.output_size_in_bytes
                                        + mem.temp_size_in_bytes
                                        - mem.alias_size_in_bytes),
            },
            "roofline": rl.as_dict(),
            "model_flops_total": mf,
            "hlo_flops_total": hlo_total_flops,
            "useful_flops_ratio": (mf / hlo_total_flops
                                   if hlo_total_flops else None),
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    finally:
        reset_rules()
    return rec


def result_path(arch: str, shape: str, mesh: str, tag: str = "") -> Path:
    sfx = f"_{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}_{shape}_{mesh}{sfx}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(specs_mod.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default="",
                    help="JSON dict of sharding-rule overrides (perf exps)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact HLO accounting "
                         "(analysis-grade; slower compiles)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="override the arch policy's grad-accum count")
    args = ap.parse_args()
    if args.unroll:
        os.environ["REPRO_UNROLL_SCAN"] = "1"
    if args.microbatches:
        from repro.launch.specs import RUN_POLICY, ArchRunPolicy, policy_for
        for a in args.arch:
            from repro.configs import get_config as _gc
            cfg0 = _gc(a)
            pol = policy_for(cfg0)
            RUN_POLICY[cfg0.name] = ArchRunPolicy(
                optimizer=pol.optimizer,
                num_microbatches=args.microbatches)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rules = json.loads(args.rules) if args.rules else None
    for arch in args.arch:
        for shape in args.shape:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                path = result_path(get_config(arch).name, shape, mesh_name,
                                   args.tag)
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    print(f"[cached] {rec['arch']} {shape} {mesh_name}: "
                          f"{rec['status']}")
                    continue
                print(f"[run] {arch} {shape} {mesh_name} ...", flush=True)
                rec = run_pair(arch, shape, mp, rules=rules, tag=args.tag)
                path.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"mem={rec['memory']['peak_estimate_bytes']/1e9:.2f}GB/dev "
                          f"terms(s): c={r['compute_term_s']:.3e} "
                          f"m={r['memory_term_s']:.3e} "
                          f"coll={r['collective_term_s']:.3e} "
                          f"dom={r['dominant']}", flush=True)
                else:
                    print(f"  {rec['status']}: {rec.get('reason') or rec.get('error')}",
                          flush=True)


if __name__ == "__main__":
    main()
