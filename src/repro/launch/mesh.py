"""Production and host mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialisation.
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips/pod; 2 pods multi-pod.

    Axes: ``data`` (FSDP + batch), ``model`` (TP/EP), and ``pod`` (the
    cross-pod axis FedDD's sparse collectives compress) when multi_pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def _largest_divisor_leq(n: int, k: int) -> int:
    """Largest divisor of ``n`` that is <= ``k`` (k >= 1)."""
    k = max(1, min(int(k), n))
    while n % k:
        k -= 1
    return k


def make_host_mesh(data: int = 1, model: int = 1):
    """Small 2-D mesh over whatever local devices exist (tests/examples).

    The requested axis sizes are clamped to DIVISORS of the available
    device count so the ``data * model`` product always tiles a prefix of
    ``jax.devices()`` exactly — asking for (data=3, model=1) on 8 devices
    yields a (2, 1) mesh rather than a shape-mismatch failure.
    """
    devs = jax.devices()
    n = len(devs)
    data = _largest_divisor_leq(n, data)
    model = _largest_divisor_leq(n // data, model)
    grid = np.asarray(devs[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def make_client_mesh(num_devices: int | None = None):
    """1-D ``clients`` mesh for the client-sharded round engines.

    Uses up to ``num_devices`` local devices (all of them by default).
    This is the mesh :class:`repro.core.round_engine.ShardedRoundEngine`
    shards the fleet axis over; client counts need not divide the mesh —
    the engine zero-pads the trailing shard.
    """
    devs = jax.devices()
    k = len(devs) if num_devices is None else max(1, min(int(num_devices),
                                                         len(devs)))
    return jax.sharding.Mesh(np.asarray(devs[:k]), ("clients",))


def resolve_client_mesh(mesh):
    """Normalise a ``ProtocolConfig.mesh`` value to a 1-D clients Mesh.

    Accepts an int (device count → :func:`make_client_mesh`), ``True``
    (all local devices), or an existing Mesh that carries a ``clients``
    axis.
    """
    if mesh is True:
        return make_client_mesh()
    if isinstance(mesh, int):
        return make_client_mesh(mesh)
    if isinstance(mesh, jax.sharding.Mesh):
        if "clients" not in mesh.axis_names:
            raise ValueError(
                f"client-sharded engines need a 'clients' mesh axis; got "
                f"axes {mesh.axis_names}")
        return mesh
    raise TypeError(f"mesh must be an int, True, or jax.sharding.Mesh; "
                    f"got {type(mesh).__name__}")
