"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips/pod; 2 pods multi-pod.

    Axes: ``data`` (FSDP + batch), ``model`` (TP/EP), and ``pod`` (the
    cross-pod axis FedDD's sparse collectives compress) when multi_pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
