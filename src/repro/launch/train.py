"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b \
        --steps 100 [--reduced] [--multi-pod]

On the CPU container only ``--reduced`` configs are runnable; the full
configs are exercised via the dry-run.  On a real TPU slice this driver is
the entry point: it builds the production mesh, shards the TrainState with
the same specs the dry-run validated, and runs the training loop with
periodic checkpointing.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import make_lm_dataset
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.optim import adafactor, adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    pol = specs_mod.policy_for(cfg)
    opt = (adafactor(args.lr * 10) if pol.optimizer == "adafactor"
           else adamw(args.lr))
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh(len(jax.devices())))
    print(f"arch={cfg.name} reduced={args.reduced} "
          f"mesh={dict(zip(mesh.axis_names, mesh.axis_sizes))}")

    toks = make_lm_dataset(vocab_size=cfg.vocab_size,
                           num_tokens=1 << 18, seed=0)

    with jax.sharding.set_mesh(mesh):
        state = lm.init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step_fn = jax.jit(lm.make_train_step(cfg, opt),
                          donate_argnums=(0,))
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for step in range(1, args.steps + 1):
            starts = rng.integers(0, len(toks) - args.seq - 1, args.batch)
            batch_tok = np.stack([toks[s:s + args.seq] for s in starts])
            batch = {"tokens": jnp.asarray(batch_tok)}
            if cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_patch_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.family == "audio":
                batch["enc_frames"] = jnp.zeros(
                    (args.batch, 24, cfg.d_model), jnp.bfloat16)
            state, metrics = step_fn(state, batch)
            if step % max(1, args.steps // 10) == 0 or step == 1:
                print(f"step {step:5d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"({time.perf_counter() - t0:.1f}s)", flush=True)
            if (args.checkpoint_every
                    and step % args.checkpoint_every == 0):
                save_checkpoint(
                    Path(args.checkpoint_dir) / f"{cfg.name}_{step}.npz",
                    state.params, metadata={"step": step})
    print("done.")


if __name__ == "__main__":
    main()
