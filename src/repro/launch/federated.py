"""Production federated-pods driver: FedDD across pod slices.

    PYTHONPATH=src python -m repro.launch.federated --pods 4 --rounds 5

This is the deployable form of examples/federated_pods.py: pods are
federated-learning clients (DESIGN.md §3); the server-side allocation LP
(core/allocation.py) converts per-pod telemetry (link rates / step times /
data stats) into per-round dropout rates; parameter exchange uses the
compacted sparse all-gather.

SPMD staticness note: compaction buffers need a static size, so the jitted
round uses ``k = ceil(C * (1 - min_n D_n))`` channels per tensor and each
pod zero-weights channels beyond its own allocation — differential rates
shape the *contribution* weights while the buffer stays static.  Recompiles
happen only when the bucketised k changes.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.allocation import ClientTelemetry, solve_dropout_rates
from repro.core.importance import channel_importance
from repro.core.sparse_collective import (dense_allreduce_mean,
                                          sparse_allgather_mean)
from repro.data import make_lm_dataset
from repro.models import lm


def pod_telemetry(n_pods: int, model_bytes: float, seed: int = 0
                  ) -> ClientTelemetry:
    """Cross-pod DCN links are the heterogeneous resource (Table-4 analog:
    pods on different network fabrics / distances)."""
    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n_pods, model_bytes),
        uplink_rate=rng.uniform(25e9, 100e9, n_pods),      # bytes/s DCN
        downlink_rate=rng.uniform(25e9, 100e9, n_pods),
        compute_latency=rng.uniform(0.5, 2.0, n_pods),     # local step time
        num_samples=np.full(n_pods, 1.0),
        label_coverage=np.full(n_pods, 1.0),
        train_loss=np.ones(n_pods),
    )


def make_round_fn(cfg, mesh, lr: float, local_steps: int, k_frac: float):
    """Jitted FedDD round over the 'pod' axis.

    ``k_frac`` (static) sizes the compaction buffer from the SMALLEST
    dropout rate; the traced per-pod rate ``d_local`` zero-weights rows
    beyond each pod's own allocation, so the differential rates from the
    allocation LP act exactly as in Algorithm 1."""

    def round_fn(p_stacked, batch_stacked, d_stacked):
        p_local = jax.tree_util.tree_map(lambda t: t[0], p_stacked)
        batch = batch_stacked[0]
        d_local = d_stacked[0]
        p_old = p_local

        def loss_of(p, tokens):
            l, _ = lm.loss_fn(p, cfg, {"tokens": tokens}, remat=False)
            return l

        loss = jnp.zeros(())
        for _ in range(local_steps):
            l, g = jax.value_and_grad(loss_of)(p_local, batch)
            p_local = jax.tree_util.tree_map(
                lambda p_, g_: (p_.astype(jnp.float32)
                                - lr * g_.astype(jnp.float32)
                                ).astype(p_.dtype), p_local, g)
            loss = l

        def exchange(old, new):
            if new.ndim <= 1:
                return dense_allreduce_mean(new, "pod")
            cax = new.ndim - 1
            nm = jnp.moveaxis(new, cax, 0)
            om = jnp.moveaxis(old, cax, 0)
            c = nm.shape[0]
            k = max(1, int(np.ceil(c * k_frac)))
            k_n = jnp.ceil(c * (1.0 - d_local)).astype(jnp.int32)
            scores = channel_importance(om.reshape(c, -1),
                                        nm.reshape(c, -1), channel_axis=0)
            agg = sparse_allgather_mean(nm, scores, k, "pod",
                                        k_local=jnp.minimum(k_n, k))
            return jnp.moveaxis(agg, 0, cax)

        p_new = jax.tree_util.tree_map(exchange, p_old, p_local)
        return (jax.tree_util.tree_map(lambda t: t[None], p_new),
                jnp.asarray(loss)[None])

    return jax.jit(jax.shard_map(round_fn, mesh=mesh,
                                 in_specs=(P("pod"), P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_vma=False))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--a-server", type=float, default=0.6)
    ap.add_argument("--d-max", type=float, default=0.8)
    ap.add_argument("--delta", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=3e-2)
    args = ap.parse_args()

    n_pods = len(jax.devices())
    mesh = jax.make_mesh((n_pods,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = get_config(args.arch, reduced=True)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    pbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(params))
    tel = pod_telemetry(n_pods, pbytes)
    stacked = jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (n_pods,) + t.shape), params)
    toks = make_lm_dataset(vocab_size=cfg.vocab_size,
                           num_tokens=n_pods * 20_000, seed=0)
    shards = jnp.asarray(toks.reshape(n_pods, -1))

    losses = np.ones(n_pods)
    rng = np.random.default_rng(0)
    round_fn = None
    k_cached = None
    t0 = time.perf_counter()
    for r in range(1, args.rounds + 1):
        tel_r = dataclasses.replace(tel, train_loss=losses)
        alloc = solve_dropout_rates(tel_r, a_server=args.a_server,
                                    d_max=args.d_max, delta=args.delta,
                                    global_model_bytes=pbytes)
        # static-k bucket (1/16 granularity) from the min dropout rate
        k_frac = float(np.ceil((1.0 - alloc.dropout_rates.min()) * 16) / 16)
        if k_frac != k_cached:
            round_fn = make_round_fn(cfg, mesh, args.lr, args.local_steps,
                                     k_frac)
            k_cached = k_frac
        starts = rng.integers(0, shards.shape[1] - args.seq - 1,
                              (n_pods, args.batch))
        batch = jnp.stack([
            jnp.stack([jax.lax.dynamic_slice(shards[p], (int(s),),
                                             (args.seq,))
                       for s in starts[p]]) for p in range(n_pods)])
        d_vec = jnp.asarray(alloc.dropout_rates, jnp.float32)
        stacked, lvec = round_fn(stacked, batch, d_vec)
        losses = np.asarray(lvec)
        print(f"round {r}: D=[{alloc.dropout_rates.min():.2f},"
              f"{alloc.dropout_rates.max():.2f}] k_frac={k_frac:.3f} "
              f"mean_loss={losses.mean():.4f} "
              f"t_server={alloc.t_server:.2f}s "
              f"({time.perf_counter() - t0:.1f}s)", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
