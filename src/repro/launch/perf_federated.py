import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf F-series: FedDD's cross-pod parameter sync on the production mesh.

Measures the collective bytes of one federated synchronisation round of the
full granite-3-8b parameter set on the (pod=2, data=16, model=16) mesh:

  baseline  — paper-faithful FedAvg sync: dense weighted all-reduce of every
              parameter over the ``pod`` axis (this is also what a
              multi-pod data-parallel trainer does every step);
  feddd(D)  — the paper's technique, TPU-adapted: per-tensor channel
              importance -> top-(1-D) compaction -> all-gather of compacted
              (values, indices) over ``pod`` + scatter/mean (DESIGN.md §3).

Within-pod sharding of every parameter matches the training layout, so the
sync composes with the real trainer: shard_map runs over ALL mesh axes and
each (data, model) cell exchanges only its local shard with its cross-pod
peer.

    PYTHONPATH=src python -m repro.launch.perf_federated [--arch ID]
"""

import argparse
import json
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.importance import channel_importance
from repro.core.sparse_collective import (compact_topk,
                                          dense_allreduce_mean,
                                          scatter_accumulate)
from repro.launch.hlo_analysis import collective_bytes_per_device
from repro.launch.mesh import make_production_mesh
from repro.models import lm

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _leaf_sync_dense(new):
    if new.ndim == 0:
        return new
    return dense_allreduce_mean(new, "pod")


def _leaf_sync_sparse(old, new, d_rate: float, quant: str = "none"):
    """FedDD compacted exchange of the LOCAL shard over the pod axis.

    quant='int8': beyond-paper — compacted channel values are exchanged as
    int8 with a per-channel fp32 absmax scale (F3), halving the value bytes
    at any dropout rate."""
    if new.ndim <= 1:
        return dense_allreduce_mean(new, "pod")
    cax = new.ndim - 1
    nm = jnp.moveaxis(new, cax, 0)
    om = jnp.moveaxis(old, cax, 0)
    c = nm.shape[0]
    k = max(1, int(np.ceil(c * (1.0 - d_rate))))
    scores = channel_importance(om.reshape(c, -1), nm.reshape(c, -1),
                                channel_axis=0)
    compact, idx = compact_topk(nm, scores, k)
    if quant == "int8":
        flat = compact.reshape(k, -1).astype(jnp.float32)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        q = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-12)),
                     -127, 127).astype(jnp.int8)
        all_q = jax.lax.all_gather(q, "pod")
        all_s = jax.lax.all_gather(scale, "pod")
        all_i = jax.lax.all_gather(idx, "pod")
        p = all_i.shape[0]
        deq = (all_q.astype(jnp.float32) * all_s).reshape(
            (p * k,) + compact.shape[1:])
        num, cnt = scatter_accumulate(nm.shape, deq, all_i.reshape(p * k))
    else:
        all_c = jax.lax.all_gather(compact, "pod")
        all_i = jax.lax.all_gather(idx, "pod")
        p = all_i.shape[0]
        num, cnt = scatter_accumulate(
            nm.shape, all_c.reshape((p * k,) + compact.shape[1:]),
            all_i.reshape(p * k))
    wshape = (c,) + (1,) * (nm.ndim - 1)
    agg = num / jnp.maximum(cnt, 1e-12).reshape(wshape)
    keep_local = (cnt <= 1e-12).reshape(wshape)
    out = jnp.where(keep_local, nm, agg.astype(nm.dtype)).astype(nm.dtype)
    return jnp.moveaxis(out, 0, cax)


def build_sync(cfg, mesh, mode: str, d_rate: float, quant: str = "none"):
    p_shape = lm.abstract_params(cfg)
    p_specs = lm.param_pspecs(cfg, p_shape)

    def sync(p_old, p_new):
        def body(*leaves):
            n = len(leaves) // 2
            olds, news = leaves[:n], leaves[n:]
            outs = []
            for o, nw in zip(olds, news):
                if mode == "dense":
                    outs.append(_leaf_sync_dense(nw))
                else:
                    outs.append(_leaf_sync_sparse(o, nw, d_rate, quant))
            return tuple(outs)

        flat_old, treedef = jax.tree_util.tree_flatten(p_old)
        flat_new = jax.tree_util.tree_leaves(p_new)
        flat_specs = jax.tree_util.tree_leaves(
            lm.param_pspecs(cfg, p_old), is_leaf=lambda x: x is None or
            isinstance(x, jax.sharding.PartitionSpec))
        in_specs = tuple(flat_specs) + tuple(flat_specs)
        out = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=tuple(flat_specs),
                            check_vma=False)(*flat_old, *flat_new)
        return jax.tree_util.tree_unflatten(treedef, out)

    return sync, (p_shape, p_shape), (p_specs, p_specs)


def run_one(arch: str, mode: str, d_rate: float, quant: str = "none") -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    tag = f"fed_{mode}" + (f"_d{int(d_rate * 100)}" if mode == "feddd" else "")
    if quant != "none":
        tag += f"_{quant}"
    with jax.sharding.set_mesh(mesh):
        fn, args, in_specs = build_sync(cfg, mesh, mode, d_rate, quant)
        lowered = jax.jit(fn, in_shardings=in_specs).lower(*args)
        compiled = lowered.compile()
        coll = collective_bytes_per_device(compiled.as_text())
        mem = compiled.memory_analysis()
    total = sum(coll.values())
    rec = {
        "arch": cfg.name, "shape": "train_4k", "mesh": "multi", "tag": tag,
        "status": "ok", "mode": mode, "d_rate": d_rate,
        "collective_per_device": coll,
        "collective_bytes_per_device": total,
        "collective_term_s": total / 50e9,
        "temp_bytes": mem.temp_size_in_bytes,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--rates", nargs="*", type=float,
                    default=[0.0, 0.4, 0.6, 0.8])
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = []
    rec = run_one(args.arch, "dense", 0.0)
    out.append(rec)
    print(f"dense    : {rec['collective_bytes_per_device'] / 1e6:9.1f} "
          f"MB/dev  term={rec['collective_term_s'] * 1e3:.2f} ms")
    for d in args.rates:
        rec = run_one(args.arch, "feddd", d)
        out.append(rec)
        print(f"feddd D={d:.1f}: "
              f"{rec['collective_bytes_per_device'] / 1e6:9.1f} MB/dev  "
              f"term={rec['collective_term_s'] * 1e3:.2f} ms")
    for d in (0.6, 0.8):
        rec = run_one(args.arch, "feddd", d, quant="int8")
        out.append(rec)
        print(f"feddd D={d:.1f} int8: "
              f"{rec['collective_bytes_per_device'] / 1e6:9.1f} MB/dev  "
              f"term={rec['collective_term_s'] * 1e3:.2f} ms")
    path = RESULTS_DIR / f"federated_sync_{args.arch}.json"
    path.write_text(json.dumps(out, indent=1))
    print("written", path)


if __name__ == "__main__":
    main()
