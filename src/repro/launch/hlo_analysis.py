"""Post-SPMD HLO analysis: collective byte counting + roofline terms.

``collective_bytes`` parses the *compiled* (partitioned, per-device) HLO
text and sums the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  cost_analysis() does not
expose collective traffic, so this is the source for the roofline
collective term (system prompt §ROOFLINE).

Conventions: the compiled module is per-device, so parsed byte counts are
per-device; we report ``collective_bytes = per_device_bytes * num_devices``
so the roofline formula  ``collective_term = collective_bytes /
(chips * link_bw)``  reduces to per-device bytes / link bandwidth.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %foo.12 = bf16[16,128]{1,0} all-reduce(%bar.3), replica_groups=...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"([\w\-]+)\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_per_device(hlo_text: str) -> Dict[str, int]:
    """Sum per-device operand bytes per collective kind.

    Operand shapes are recovered from the instruction-definition table; for
    ``all-gather`` the operand (pre-gather shard) is what each device sends
    per ring hop aggregated over the ring, so we follow the assignment and
    count operand sizes uniformly.
    """
    # instruction name -> result shape string
    shapes: Dict[str, str] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        shapes[m.group(1)] = m.group(2)

    out = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):   # *-start/-done variants
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue                                  # counted at -start
        # operand list: text between the first '(' after op name and its ')'
        start = m.end()
        depth = 1
        i = start
        while i < len(hlo_text) and depth:
            if hlo_text[i] == "(":
                depth += 1
            elif hlo_text[i] == ")":
                depth -= 1
            i += 1
        operands = hlo_text[start:i - 1]
        n = 0
        for om in re.finditer(r"%?([\w.\-]+)", operands):
            name = om.group(1)
            if name in shapes:
                n += _shape_bytes(shapes[name])
        if n == 0:
            # fallback: use the result shape (all-reduce: same size)
            n = _shape_bytes(m.group(2))
        out[kind] += n
    return out


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip numbers (system prompt §ROOFLINE)."""
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    link_bw: float = 50e9            # bytes/s per ICI link
    hbm_bytes: float = 16e9          # capacity


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: Dict[str, int]
    num_devices: int
    hw: Hardware = dataclasses.field(default_factory=Hardware)

    @property
    def compute_term(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def memory_term(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def collective_term(self) -> float:
        total = sum(self.collective_per_device.values())
        return total / self.hw.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_per_device": dict(self.collective_per_device),
            "num_devices": self.num_devices,
            "compute_term_s": self.compute_term,
            "memory_term_s": self.memory_term,
            "collective_term_s": self.collective_term,
            "dominant": self.dominant,
        }


def model_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens.

    N counts *active* parameters: for MoE layers top_k/num_experts of the
    expert params; embeddings excluded from the 6ND rule's N (standard
    convention) but the lm_head matmul is included via 2*D*d*V.
    """
    import numpy as np
    n_active = 0
    layout = cfg.layout()
    d = cfg.d_model
    hd = cfg.head_dim_
    for spec in layout:
        if spec.mixer in ("attn", "attn_local"):
            n_active += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        elif spec.mixer == "mamba":
            di = cfg.mamba.expand * d
            dr = cfg.mamba.dt_rank or max(1, int(np.ceil(d / 16)))
            n_active += (d * 2 * di + di * (dr + 2 * cfg.mamba.d_state)
                         + dr * di + di * d)
        elif spec.mixer in ("mlstm", "slstm"):
            di = int(cfg.xlstm.proj_factor * d)
            n_active += d * 2 * di + di * d
            hd_x = di // cfg.num_heads
            # mlstm q/k/v are per-head block-diagonal
            n_active += (3 * di * hd_x if spec.mixer == "mlstm"
                         else 4 * di * di + 4 * di * hd_x)
        if spec.cross_attention:
            n_active += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        if spec.ff == "dense":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n_active += mats * d * cfg.d_ff
        elif spec.ff == "moe":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n_active += mats * d * cfg.moe.d_ff_expert * cfg.moe.top_k
    # encoder layers (audio)
    for spec in (cfg.encoder_layout() if cfg.is_encdec else []):
        n_active += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        n_active += mats * d * cfg.d_ff

    tokens = batch * (1 if kind == "decode" else seq)
    factor = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    head = 2.0 * tokens * d * cfg.vocab_size * (3.0 if kind == "train" else 1.0)
    return factor * n_active * tokens + head
