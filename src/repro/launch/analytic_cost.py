"""Loop-aware analytic cost model for the roofline report.

WHY THIS EXISTS: ``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts a
``while``-loop body ONCE, not times its trip count.  Our models deliberately
``lax.scan`` over layer super-blocks and gradient-accumulation microbatches
(HLO-size control, see blocks.py), so raw HLO FLOPs/bytes undercount by the
product of scan trip counts — the EXPERIMENTS.md roofline table therefore
reports BOTH the raw cost_analysis numbers and the analytic estimates below,
and bottleneck calls use the analytic terms.

The model (per GLOBAL step; divide by chips for per-device):

FLOPs
  dense matmul work      6*N*D_tokens (train, +2ND remat refwd = 8ND),
                         2*N*D (prefill/decode);   N = active params
  attention              4*B*S*W*H*hd per layer fwd (W = S full, window
                         local, cache decode), x2 bwd, +fwd for remat
  logits                 2*T*d*V (x3 train)
  mamba scan             ~12*B*S*di*ds per layer fwd (discretise+scan+out)
  mlstm chunk            ~4*B*S*Q*H*hd intra + 4*B*S*hd*hd inter per layer

Bytes (HBM traffic)
  params                 train: read bf16 + grad fp32 w + opt fp32 r/w
                         (16 B/param + 8 adam / 4 adafactor);
                         prefill/decode: 2 B/param per step
  activations            ~14 R/W of (B,S,D) bf16 per layer fwd, x2 train
  KV cache / states      decode: full cache read + one-slot write
  logits                 T*V*4 r/w
"""

from __future__ import annotations

import math
from typing import Dict

from repro.models.config import ModelConfig


def _active_params(cfg: ModelConfig) -> float:
    """Active (per-token) parameter count, MoE counting top_k experts."""
    n = 0.0
    d = cfg.d_model
    hd = cfg.head_dim_
    for spec in cfg.layout():
        if spec.mixer in ("attn", "attn_local"):
            n += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        elif spec.mixer == "mamba":
            di = cfg.mamba.expand * d
            dr = cfg.mamba.dt_rank or max(1, math.ceil(d / 16))
            n += d * 2 * di + di * (dr + 2 * cfg.mamba.d_state) \
                + dr * di + di * d
        elif spec.mixer in ("mlstm", "slstm"):
            di = int(cfg.xlstm.proj_factor * d)
            n += d * 2 * di + di * d
            hd_x = di // cfg.num_heads
            # mlstm q/k/v are per-head block-diagonal (3 * H * hd^2)
            n += (3 * di * hd_x if spec.mixer == "mlstm"
                  else 4 * di * di + 4 * di * hd_x)
        if spec.cross_attention:
            n += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        if spec.ff == "dense":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n += mats * d * cfg.d_ff
        elif spec.ff == "moe":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n += mats * d * cfg.moe.d_ff_expert * cfg.moe.top_k
    for spec in (cfg.encoder_layout() if cfg.is_encdec else []):
        n += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        n += mats * d * cfg.d_ff
    return n


def total_params(cfg: ModelConfig) -> float:
    n = _active_params(cfg)
    if cfg.moe is not None:
        # add the inactive experts
        mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        per_layer_extra = (mats * cfg.d_model * cfg.moe.d_ff_expert
                           * (cfg.moe.num_experts - cfg.moe.top_k))
        n += per_layer_extra * sum(
            1 for s in cfg.layout() if s.ff == "moe")
    n += cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return n


def _attn_flops(cfg: ModelConfig, b: int, s: int, kind: str) -> float:
    total = 0.0
    hd = cfg.head_dim_
    h = cfg.num_heads
    for spec in cfg.layout():
        if spec.mixer == "attn":
            w = s if kind != "decode" else s      # cache length
            per = 4.0 * b * (s if kind != "decode" else 1) * w * h * hd
            if kind != "decode":
                per *= 0.5                         # causal mask halves
        elif spec.mixer == "attn_local":
            win = min(spec.window or cfg.window_size, s)
            per = 4.0 * b * (s if kind != "decode" else 1) * win * h * hd
        elif spec.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            per = 12.0 * b * (s if kind != "decode" else 1) * di \
                * cfg.mamba.d_state
        elif spec.mixer == "mlstm":
            di = int(cfg.xlstm.proj_factor * cfg.d_model)
            hh, dh = cfg.num_heads, di // cfg.num_heads
            q = cfg.xlstm.chunk_size
            toks = s if kind != "decode" else 1
            per = 4.0 * b * toks * min(q, s) * hh * dh \
                + 4.0 * b * toks * dh * dh * hh
        else:                                      # slstm
            di = int(cfg.xlstm.proj_factor * cfg.d_model)
            per = 8.0 * b * (s if kind != "decode" else 1) * di
        if spec.cross_attention and cfg.is_encdec:
            enc = min(s, cfg.encoder_seq_cap)
            per += 4.0 * b * (s if kind != "decode" else 1) * enc * h * hd
        total += per
    mult = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[kind]
    return total * mult   # train: fwd + 2x bwd + remat refwd


def analytic_flops(cfg: ModelConfig, seq: int, batch: int, kind: str,
                   remat: bool = True) -> float:
    n = _active_params(cfg)
    tokens = batch * (seq if kind != "decode" else 1)
    if kind == "train":
        base = (8.0 if remat else 6.0) * n * tokens
        logits = 6.0 * tokens * cfg.d_model * cfg.vocab_size
    else:
        base = 2.0 * n * tokens
        logits = 2.0 * (batch if kind != "train" else tokens) \
            * cfg.d_model * cfg.vocab_size
    return base + logits + _attn_flops(cfg, batch, seq, kind)


def analytic_bytes(cfg: ModelConfig, seq: int, batch: int, kind: str,
                   optimizer: str = "adamw") -> float:
    p = total_params(cfg)
    d = cfg.d_model
    layers = cfg.num_layers + cfg.encoder_layers
    tokens = batch * (seq if kind != "decode" else 1)
    if kind == "train":
        opt = 16.0 if optimizer == "adamw" else 6.0
        param_traffic = p * (2.0 + 4.0 + opt)     # bf16 read, grad, opt r/w
        act = 14.0 * 2.0 * tokens * d * 2.0 * layers
        logits = tokens * cfg.vocab_size * 8.0
        return param_traffic + act + logits
    if kind == "prefill":
        return p * 2.0 + 14.0 * tokens * d * 2.0 * layers \
            + batch * cfg.vocab_size * 4.0
    # decode: every param read once; KV/states read once
    cache = 0.0
    for spec in cfg.layout():
        if spec.mixer == "attn":
            cache += 2.0 * batch * seq * cfg.num_kv_heads * cfg.head_dim_ * 2
        elif spec.mixer == "attn_local":
            win = min(spec.window or cfg.window_size, seq)
            cache += 2.0 * batch * win * cfg.num_kv_heads * cfg.head_dim_ * 2
        elif spec.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            cache += batch * di * cfg.mamba.d_state * 4
        elif spec.mixer in ("mlstm",):
            di = int(cfg.xlstm.proj_factor * cfg.d_model)
            hh = cfg.num_heads
            cache += batch * hh * (di // hh) ** 2 * 4
        else:
            di = int(cfg.xlstm.proj_factor * cfg.d_model)
            cache += 4 * batch * di * 4
    return p * 2.0 + cache + batch * cfg.vocab_size * 4.0


def analytic_terms(cfg: ModelConfig, seq: int, batch: int, kind: str,
                   num_devices: int, *, optimizer: str = "adamw",
                   peak_flops: float = 197e12, hbm_bw: float = 819e9
                   ) -> Dict[str, float]:
    fl = analytic_flops(cfg, seq, batch, kind)
    by = analytic_bytes(cfg, seq, batch, kind, optimizer)
    return {
        "analytic_flops_total": fl,
        "analytic_bytes_total": by,
        "analytic_compute_term_s": fl / (num_devices * peak_flops),
        "analytic_memory_term_s": by / (num_devices * hbm_bw),
    }
