"""Production serving driver: batched autoregressive decode with a static
(ring-buffered where sliding-window) KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_27b \
        --batch 4 --steps 32 [--reduced]

On a real TPU slice, drop ``--reduced`` and add ``--production-mesh`` to
shard the cache (batch over data, kv-heads over model) with the same specs
the decode dry-run validated.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm


def sample_greedy(logits: jax.Array, rng=None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_topk(logits: jax.Array, rng: jax.Array, k: int = 40,
                temperature: float = 0.8) -> jax.Array:
    v, idx = jax.lax.top_k(logits / temperature, k)
    choice = jax.random.categorical(rng, v)
    return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0] \
        .astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_27b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--sample", choices=("greedy", "topk"), default="topk")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(len(jax.devices())))
    cache_len = args.cache_len or args.steps + 8
    key = jax.random.PRNGKey(0)
    sampler = sample_topk if args.sample == "topk" else sample_greedy

    with jax.sharding.set_mesh(mesh):
        params = lm.init_model(key, cfg)
        serve = jax.jit(lm.make_serve_step(cfg), donate_argnums=(1,))
        enc = (jnp.zeros((args.batch, 24, cfg.d_model), jnp.bfloat16)
               if cfg.is_encdec else None)
        state = lm.init_decode_state(params, cfg, args.batch, cache_len,
                                     enc_frames=enc)
        tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size)
        outs = [np.asarray(tok)]
        t0 = time.perf_counter()
        for t in range(args.steps):
            logits, state = serve(params, state, tok)
            key, rk = jax.random.split(key)
            tok = sampler(logits, rk)[:, None]
            outs.append(np.asarray(tok))
        dt = time.perf_counter() - t0
    seq = np.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"{dt / args.steps * 1e3:.1f} ms/token")
    print("request 0 token ids:", seq[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
