"""Input shapes & abstract input specs for every (arch x shape) pair.

The four assigned input shapes:

  train_4k      seq=4096    global_batch=256   train_step
  prefill_32k   seq=32768   global_batch=32    prefill (forward, last logits)
  decode_32k    seq=32768   global_batch=128   serve_step (1 token, KV cache)
  long_500k     seq=524288  global_batch=1     serve_step (sub-quadratic only)

``should_run`` encodes the DESIGN.md §4 skip table; ``input_specs`` returns
weak-type-correct ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig

SHAPES: Dict[str, Tuple[int, int, str]] = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# archs allowed to run the 500k decode (sub-quadratic context handling)
LONG_OK = {"xlstm-1.3b", "jamba-1.5-large-398b", "gemma3-27b"}

# at 500k, global/full-attention layers fall back to a windowed ring cache
# (Gemma-3's own long-context serving recipe); see DESIGN.md §4.
LONG_GLOBAL_WINDOW = 32768

# whisper's decoder is text: cap decoder token length (enc frames carry seq)
AUDIO_DECODER_LEN = 512


def should_run(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, ("full-attention KV at 500k is quadratic-regime; "
                       "skipped per assignment rules (DESIGN.md §4)")
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _bf16(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """Abstract batch for train/prefill kinds (decode handled separately)."""
    seq, batch, kind = SHAPES[shape_name]
    if cfg.family == "vlm":
        p = cfg.num_patch_tokens
        return {"tokens": _i32(batch, seq - p),
                "patch_embeds": _bf16(batch, p, cfg.d_model)}
    if cfg.family == "audio":
        return {"tokens": _i32(batch, min(seq, AUDIO_DECODER_LEN)),
                "enc_frames": _bf16(batch, seq, cfg.d_model)}
    return {"tokens": _i32(batch, seq)}


def decode_specs(cfg: ModelConfig, shape_name: str) -> Tuple[object, object]:
    """(abstract DecodeState, abstract one-token batch) for serve_step."""
    seq, batch, kind = SHAPES[shape_name]
    assert kind == "decode"
    cfg_eff = effective_decode_config(cfg, shape_name)
    enc_len = min(seq, cfg.encoder_seq_cap) if cfg.is_encdec else 0
    state = lm.abstract_decode_state(cfg_eff, batch, seq, enc_len=enc_len)
    tokens = _i32(batch, 1)
    return state, tokens


def effective_decode_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """At 500k, global/full attention layers switch to a windowed ring KV
    (Gemma-3 long-context recipe; applies to gemma3 + jamba's attn layers)."""
    if shape_name == "long_500k" and cfg.name in LONG_OK:
        return dataclasses.replace(
            cfg, long_context_global_window=LONG_GLOBAL_WINDOW)
    return cfg


@dataclasses.dataclass(frozen=True)
class ArchRunPolicy:
    """Per-arch dry-run knobs (optimizer, microbatching, sharding-rule
    overrides), sized so the activation working set fits HBM
    (EXPERIMENTS.md §Dry-run / §Perf)."""
    optimizer: str = "adamw"
    num_microbatches: int = 1
    # winning §Perf rules (e.g. N2/N6: residual->model, seq_act->data)
    rules: Optional[Dict[str, str]] = None


RUN_POLICY: Dict[str, ArchRunPolicy] = {
    "nemotron-4-340b": ArchRunPolicy(optimizer="adafactor",
                                     num_microbatches=16,
                                     rules={"residual": "model",
                                            "seq_act": "data"}),
    "jamba-1.5-large-398b": ArchRunPolicy(optimizer="adafactor",
                                          num_microbatches=8,
                                          rules={"residual": "model"}),
    "gemma3-27b": ArchRunPolicy(num_microbatches=8),
    "pixtral-12b": ArchRunPolicy(num_microbatches=8),
    "qwen3-moe-30b-a3b": ArchRunPolicy(num_microbatches=8),
    "whisper-medium": ArchRunPolicy(num_microbatches=4),
    "chatglm3-6b": ArchRunPolicy(num_microbatches=4),
    "granite-3-8b": ArchRunPolicy(num_microbatches=8),
    "granite-moe-1b-a400m": ArchRunPolicy(num_microbatches=8),
    "xlstm-1.3b": ArchRunPolicy(num_microbatches=4),
}


def policy_for(cfg: ModelConfig) -> ArchRunPolicy:
    return RUN_POLICY.get(cfg.name, ArchRunPolicy())
