"""The paper's FL models (Tables 2, 3, 6) in plain JAX.

  MLP   FC(784,100)-ReLU-FC(100,64)-ReLU-FC(64,10)           (MNIST)
  CNN1  Conv(1,10,5)-pool-Conv(10,20,5)-pool-FC(320,50)-FC(50,10)   (FMNIST)
  CNN2  3xConv(16/32/64,k3)+pool-FC(1024,500)-FC(500,100)-FC(100,10) (CIFAR10)

plus the five heterogeneous VGG-style sub-models of Tables 3 (hetero-a) and
6 (hetero-b).  All parameters are dicts of (in..., out_channels) tensors so
FedDD's channel masks (channel_axis=-1) apply directly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# A spec is a list of layer tuples:
#   ("conv", in_ch, out_ch, kernel)    3x3/5x5 conv + ReLU
#   ("pool",)                          2x2 max pool
#   ("fc", d_in, d_out)                dense (+ReLU except last)
MLP_SPEC = [("fc", 784, 100), ("fc", 100, 64), ("fc", 64, 10)]
CNN1_SPEC = [("conv", 1, 10, 5), ("pool",), ("conv", 10, 20, 5), ("pool",),
             ("fc", 320, 50), ("fc", 50, 10)]
CNN2_SPEC = [("conv", 3, 16, 3), ("pool",), ("conv", 16, 32, 3), ("pool",),
             ("conv", 32, 64, 3), ("pool",),
             ("fc", 1024, 500), ("fc", 500, 100), ("fc", 100, 10)]


def _vgg(widths: Sequence[int], fcs: Sequence[int]) -> List[Tuple]:
    spec: List[Tuple] = []
    cin = 3
    for w in widths:
        spec += [("conv", cin, w, 3), ("pool",)]
        cin = w
    d = widths[-1]          # 32x32 through 5 pools -> 1x1 spatial
    dims = [d] + list(fcs) + [10]
    for i in range(len(dims) - 1):
        spec.append(("fc", dims[i], dims[i + 1]))
    return spec


# Table 3 (model-heterogeneous-a): five VGG-ish sub-models
HETERO_A_SPECS = [
    _vgg([64, 128, 256, 512, 512], [100, 100]),   # full model
    _vgg([64, 128, 256, 256, 512], [100, 100]),
    _vgg([64, 128, 256, 256, 512], [80, 100]),
    _vgg([32, 128, 256, 256, 512], [80, 100]),
    _vgg([32, 128, 128, 256, 512], [80, 100]),
]

# Table 6 (model-heterogeneous-b): larger spread
HETERO_B_SPECS = [
    _vgg([64, 128, 256, 512, 512], [100, 100]),   # full model
    _vgg([64, 128, 256, 256, 256], [100, 100]),
    _vgg([64, 128, 256, 256, 256], [80, 80]),
    _vgg([32, 96, 256, 256, 256], [80, 80]),
    _vgg([32, 96, 128, 128, 256], [80, 80]),
]


def init_cnn_spec(key, spec: Sequence[Tuple]) -> Dict:
    params: Dict[str, Dict] = {}
    li = 0
    for layer in spec:
        if layer[0] == "conv":
            _, cin, cout, k = layer
            key, sub = jax.random.split(key)
            scale = 1.0 / math.sqrt(cin * k * k)
            params[f"conv{li}"] = {
                "w": jax.random.normal(sub, (k, k, cin, cout)) * scale,
                "b": jnp.zeros((cout,)),
            }
            li += 1
        elif layer[0] == "fc":
            _, din, dout = layer
            key, sub = jax.random.split(key)
            params[f"fc{li}"] = {
                "w": jax.random.normal(sub, (din, dout)) / math.sqrt(din),
                "b": jnp.zeros((dout,)),
            }
            li += 1
    return params


def init_mlp(key) -> Dict:
    return init_cnn_spec(key, MLP_SPEC)


def init_cnn(key, which: str) -> Dict:
    return init_cnn_spec(key, CNN1_SPEC if which == "cnn1" else CNN2_SPEC)


def apply_spec(params: Dict, spec: Sequence[Tuple], x: jax.Array
               ) -> jax.Array:
    """x: (B, H, W, C) images or (B, D) flats for pure-MLP specs."""
    li = 0
    n_fc_seen = 0
    n_fc = sum(1 for l in spec if l[0] == "fc")
    for layer in spec:
        if layer[0] == "conv":
            p = params[f"conv{li}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + p["b"])
            li += 1
        elif layer[0] == "pool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
        elif layer[0] == "fc":
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            p = params[f"fc{li}"]
            x = x @ p["w"] + p["b"]
            n_fc_seen += 1
            if n_fc_seen < n_fc:
                x = jax.nn.relu(x)
            li += 1
    return x


def model_bytes(params) -> int:
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(params)))


# ------------------------------------------------------- train / eval ------

def _ce(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def make_local_train_fn(spec: Sequence[Tuple], ds, parts,
                        *, lr: float = 0.05, batch_size: int = 64,
                        local_epochs: int = 1, flatten: bool = False):
    """Returns local_train_fn(params, client_idx, rng) -> (params, loss)
    running ``local_epochs`` epochs of minibatch SGD on the client's shard.

    Per-client data is bound eagerly (numpy indexing) and each step is a
    jitted SGD update.
    """
    xs = [jnp.asarray(ds.x[p]) for p in parts]
    ys = [jnp.asarray(ds.y[p]) for p in parts]
    if flatten:
        xs = [x.reshape(x.shape[0], -1) for x in xs]

    @jax.jit
    def _step(params, xb, yb):
        def _loss(p):
            return _ce(apply_spec(p, spec, xb), yb)
        loss, g = jax.value_and_grad(_loss)(params)
        params = jax.tree_util.tree_map(lambda p_, g_: p_ - lr * g_,
                                        params, g)
        return params, loss

    def local_train(params, client_idx: int, rng) -> Tuple[Dict, float]:
        x, y = xs[client_idx], ys[client_idx]
        n = x.shape[0]
        if n == 0:
            return params, 0.0
        loss = 0.0
        steps = 0
        for ep in range(local_epochs):
            perm = jax.random.permutation(
                jax.random.fold_in(rng, ep), n)
            for s in range(0, max(n - batch_size + 1, 1), batch_size):
                idx = perm[s:s + batch_size]
                params, l = _step(params, x[idx], y[idx])
                loss += float(l)
                steps += 1
        return params, loss / max(steps, 1)

    return local_train


def make_eval_fn(spec: Sequence[Tuple], test_ds, *, flatten: bool = False,
                 batch_size: int = 512, per_class: bool = False):
    x = jnp.asarray(test_ds.x)
    y = np.asarray(test_ds.y)
    if flatten:
        x = x.reshape(x.shape[0], -1)

    @jax.jit
    def _logits(params, xb):
        return apply_spec(params, spec, xb)

    def eval_fn(params) -> Dict:
        preds = []
        for s in range(0, x.shape[0], batch_size):
            preds.append(np.asarray(
                jnp.argmax(_logits(params, x[s:s + batch_size]), -1)))
        pred = np.concatenate(preds)
        acc = float(np.mean(pred == y))
        out = {"accuracy": acc}
        if per_class:
            for c in range(test_ds.num_classes):
                m = y == c
                out[f"acc_class_{c}"] = (float(np.mean(pred[m] == y[m]))
                                         if m.any() else 0.0)
        return out

    return eval_fn
