from repro.fl.heterogeneity import (ShapeGroup, group_by_shape,
                                    sample_system_telemetry, shape_signature)
from repro.fl.models import (init_cnn, init_mlp, make_eval_fn,
                             make_local_train_fn, model_bytes,
                             CNN1_SPEC, CNN2_SPEC, MLP_SPEC,
                             HETERO_A_SPECS, HETERO_B_SPECS, init_cnn_spec)
