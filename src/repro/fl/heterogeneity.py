"""System-heterogeneity sampler — the paper's Table 4 simulation settings.

  r_u  ~ U[1, 5]  x 10^4 bit/s        uplink
  r_d  ~ U[4, 20] x 10^4 bit/s        downlink
  f_n  ~ U[1, 10] GHz                 CPU frequency
  c_n  ~ U[1, 10] Megacycles/sample   per-sample cycles

t_cmp = c_n * b_n / f_n  (Eq. (7)) with b_n = client batch size per epoch
(we use the client's shard size x local epochs, matching the paper's
"batch size of one epoch" reading).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.allocation import ClientTelemetry


def sample_system_telemetry(
    num_clients: int,
    model_bytes: Sequence[float],
    num_samples: Sequence[int],
    label_coverage: Sequence[float],
    *,
    local_epochs: int = 1,
    seed: int = 0,
    initial_loss: float = 1.0,
) -> ClientTelemetry:
    rng = np.random.default_rng(seed)
    n = num_clients
    bits_u = rng.uniform(1e4, 5e4, n)            # bit/s (Table 4)
    bits_d = rng.uniform(4e4, 2e5, n)
    f_ghz = rng.uniform(1, 10, n)                # GHz
    c_mc = rng.uniform(1, 10, n)                 # Megacycles/sample
    samples = np.asarray(num_samples, float)
    t_cmp = c_mc * 1e6 * samples * local_epochs / (f_ghz * 1e9)
    return ClientTelemetry(
        model_bytes=np.asarray(model_bytes, float),
        uplink_rate=bits_u / 8.0,                # bytes/s
        downlink_rate=bits_d / 8.0,
        compute_latency=t_cmp,
        num_samples=samples,
        label_coverage=np.asarray(label_coverage, float),
        train_loss=np.full(n, initial_loss),
    )


# --------------------------------------------------------------- shape groups

@dataclasses.dataclass(frozen=True)
class ShapeGroup:
    """One equivalence class of a ragged fleet: every member client holds a
    sub-model with the identical pytree structure and leaf shapes, so their
    parameters stack along a leading client axis and one jit-compiled engine
    step serves the whole group (core/round_engine.py GroupedRoundEngine).

    ``indices`` are the members' positions in the fleet (ascending) — they
    are both the rows each member occupies in the full-fleet aggregation
    canvas and the ids the per-client mask RNG keys fold in, so grouped
    results stay bit-identical to the per-client reference loop.
    """

    signature: Tuple                 # hashable (treedef, ((shape, dtype)...))
    indices: Tuple[int, ...]         # fleet positions of the members

    @property
    def size(self) -> int:
        return len(self.indices)


def shape_signature(params) -> Tuple:
    """Hashable identity of a pytree's (structure, leaf shapes, dtypes)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return (treedef,
            tuple((tuple(l.shape), str(np.asarray(l).dtype
                                       if not hasattr(l, "dtype") else l.dtype))
                  for l in leaves))


def group_by_shape(client_params: Sequence) -> "list[ShapeGroup]":
    """Partition a fleet by sub-model shape.

    Returns the groups ordered by their smallest member index (a pure
    function of the fleet, so the grouped engine's jit cache and canvas
    layout are deterministic).  A homogeneous fleet yields one group.
    """
    members: dict = {}
    for i, p in enumerate(client_params):
        members.setdefault(shape_signature(p), []).append(i)
    groups = [ShapeGroup(signature=sig, indices=tuple(idx))
              for sig, idx in members.items()]
    return sorted(groups, key=lambda g: g.indices[0])
