from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.checkpoint.run_state import (RunState, load_run_state,
                                        save_run_state)
