"""Full-run crash-resume snapshots over the atomic checkpoint writer.

A :class:`RunState` is everything a driver needs to continue a run from a
round boundary with BIT-IDENTICAL results (tests/test_resume.py): the
array state (global + stacked client params, losses, dropout rates, the
protocol PRNG key, observed-telemetry EWMAs) rides the flattened-npz
tensor file of :mod:`repro.checkpoint.io`, while the round index, the
completed :class:`~repro.core.protocol.RoundRecord` history, and the sim
extras (clock, event trace) ride the msgpack/json ``.meta`` sidecar —
reusing the obs run-log serialization (:mod:`repro.obs.runlog`), whose
round events round-trip records exactly (float64 repr / native doubles).

Nothing else needs persisting: fault draws are keyed
``(seed, tag, epoch, client)`` and network/outage chains are keyed per
epoch, so they replay for free on resume; jit caches re-warm on first
dispatch with the same traced arithmetic.

Both writes are atomic (temp + fsync + ``os.replace``), so a SIGKILL at
any instant leaves either the previous snapshot or the new one — never a
torn file.  The tensor file is written before the sidecar; loaders
require the sidecar's round marker, so a kill between the two writes
reads as the OLDER complete snapshot pair at worst one round behind.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, List

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.obs import runlog

_FORMAT = 1


@dataclasses.dataclass
class RunState:
    """One resumable snapshot at a round boundary.

    round: the last COMPLETED round index (resume continues at round+1).
    arrays: pytree (typically a dict) of array state — global params,
      stacked client params, losses, dropout, PRNG key, telemetry EWMAs.
    history: the RoundRecords of rounds 1..round.
    extra: JSON-able driver extras (sim clock, event trace, seeds...).
    """

    round: int
    arrays: Any
    history: List
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


def save_run_state(path: str | Path, state: RunState) -> None:
    """Atomically persist ``state`` (tensors + sidecar)."""
    meta = {
        "_run_state": _FORMAT,
        "round": int(state.round),
        "history": [runlog.round_event(r) for r in state.history],
        "extra": runlog.jsonable(state.extra),
    }
    save_checkpoint(path, state.arrays, metadata=meta)


def load_run_state(path: str | Path, like_arrays: Any) -> RunState:
    """Restore a snapshot written by :func:`save_run_state`.

    ``like_arrays`` is the shape/dtype template for the array state —
    the caller's freshly-initialised state, which resume then overwrites.
    """
    arrays, meta = load_checkpoint(path, like_arrays)
    if meta.get("_run_state") != _FORMAT:
        raise ValueError(
            f"{path} is not a RunState snapshot (missing/unknown "
            f"_run_state marker {meta.get('_run_state')!r}) — plain "
            "parameter checkpoints cannot seed a resume")
    history = [runlog.record_from_event(ev) for ev in meta["history"]]
    return RunState(round=int(meta["round"]), arrays=arrays,
                    history=history, extra=dict(meta.get("extra") or {}))
