"""Checkpointing: flattened-pytree .npz tensors + msgpack metadata.

Sharded arrays are gathered to host before writing (dry-run-scale models are
never materialised, so this path only runs for real trainings).  Structure
round-trips exactly: tree paths are serialised into the npz keys.

Writes are ATOMIC (write-temp + fsync + rename): a process killed mid-write
— the crash-mid-round scenario the fault layer (repro.sim.faults) injects
on the simulated side — leaves either the previous checkpoint intact or the
new one complete, never a torn file (tests/test_optim_checkpoint.py).
"""

from __future__ import annotations

import io
import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax

try:
    import msgpack
    _HAVE_MSGPACK = True
except ImportError:                               # pragma: no cover
    _HAVE_MSGPACK = False


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # non-native dtypes (bfloat16, fp8): store as float32; the load
            # path casts back to the template dtype (lossless for bf16).
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _atomic_write_bytes(path: Path, data: bytes) -> None:
    """Durably replace ``path``: temp file + fsync + atomic rename.

    ``os.replace`` is atomic on POSIX, so a reader (or a crash) can only
    ever observe the old complete file or the new complete file.
    """
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_checkpoint(path: str | Path, tree: Any,
                    metadata: Optional[Dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, **{k: v for k, v in flat.items()})
    _atomic_write_bytes(path, buf.getvalue())
    meta = dict(metadata or {})
    meta["_keys"] = sorted(flat.keys())
    meta_bytes = (msgpack.packb(meta) if _HAVE_MSGPACK
                  else json.dumps(meta).encode())
    _atomic_write_bytes(Path(str(path) + ".meta"), meta_bytes)


def load_checkpoint(path: str | Path, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    meta_path = Path(str(path) + ".meta")
    meta: Dict = {}
    if meta_path.exists():
        raw = meta_path.read_bytes()
        meta = (msgpack.unpackb(raw) if _HAVE_MSGPACK
                else json.loads(raw.decode()))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        if isinstance(leaf, np.ndarray):
            # host-side template (e.g. the float64 loss / dropout state of
            # a RunState): restore as numpy at full precision — routing
            # through jnp would silently truncate f64 to f32
            leaves.append(np.asarray(arr, dtype=leaf.dtype))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), meta
