"""Checkpointing: flattened-pytree .npz tensors + msgpack metadata.

Sharded arrays are gathered to host before writing (dry-run-scale models are
never materialised, so this path only runs for real trainings).  Structure
round-trips exactly: tree paths are serialised into the npz keys.
"""

from __future__ import annotations

import io
import json
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax

try:
    import msgpack
    _HAVE_MSGPACK = True
except ImportError:                               # pragma: no cover
    _HAVE_MSGPACK = False


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # non-native dtypes (bfloat16, fp8): store as float32; the load
            # path casts back to the template dtype (lossless for bf16).
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str | Path, tree: Any,
                    metadata: Optional[Dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    with open(path, "wb") as f:
        np.savez(f, **{k: v for k, v in flat.items()})
    meta = dict(metadata or {})
    meta["_keys"] = sorted(flat.keys())
    meta_bytes = (msgpack.packb(meta) if _HAVE_MSGPACK
                  else json.dumps(meta).encode())
    Path(str(path) + ".meta").write_bytes(meta_bytes)


def load_checkpoint(path: str | Path, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    meta_path = Path(str(path) + ".meta")
    meta: Dict = {}
    if meta_path.exists():
        raw = meta_path.read_bytes()
        meta = (msgpack.unpackb(raw) if _HAVE_MSGPACK
                else json.loads(raw.decode()))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), meta
