"""Pure-JAX optimizers (no optax dependency in this container).

Functional API in the optax style:

    opt = adamw(lr=1e-3, weight_decay=0.01)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are pytrees matching ``params`` so they shard identically (FSDP: the
optimizer state inherits the parameter sharding — crucial for the 340B
config's memory budget, see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Grads, Any, Optional[Params]], Tuple[Any, Any]]


def apply_updates(params: Params, updates: Any) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# --------------------------------------------------------------- sgd -------

def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            ups = _tmap(lambda g: -lr * g.astype(jnp.float32), grads)
            return ups, {"step": state["step"] + 1}
        mu = _tmap(lambda m, g: momentum * m + g.astype(jnp.float32),
                   state["mu"], grads)
        ups = _tmap(lambda m: -lr * m, mu)
        return ups, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update)


# --------------------------------------------------------------- adam ------

def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "m": z,
                "v": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def _u(m_, v_, p=None):
            upd = -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - lr * weight_decay * p.astype(jnp.float32)
            return upd

        if weight_decay and params is not None:
            ups = _tmap(_u, m, v, params)
        else:
            ups = _tmap(_u, m, v)
        return ups, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


# ------------------------------------------------------------ adafactor ----

def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30
              ) -> Optimizer:
    """Factored second-moment optimizer (memory-lean — used by the 340B
    config where full Adam moments exceed HBM; see EXPERIMENTS.md §Perf)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def _s(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree_util.tree_map(_s, params)}

    def update(grads, state, params=None):
        del params
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def _u(g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1,
                                                keepdims=True)[..., None],
                                       eps))
                upd = -lr * gf / jnp.sqrt(jnp.maximum(denom, eps))
                return upd, {"vr": vr, "vc": vc}
            v = beta * s["v"] + (1 - beta) * g2
            return -lr * gf / jnp.sqrt(jnp.maximum(v, eps)), {"v": v}

        leaves_g, tdef = jax.tree_util.tree_flatten(grads)
        leaves_s = tdef.flatten_up_to(state["v"])
        outs = [_u(g, s) for g, s in zip(leaves_g, leaves_s)]
        ups = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        vs = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        return ups, {"step": step, "v": vs}

    return Optimizer(init, update)
