from repro.optim.optimizers import (Optimizer, adafactor, adam, adamw, sgd)
