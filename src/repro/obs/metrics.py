"""Label-aware metrics registry — counters, gauges, histograms.

The registry is the shared numeric export path of the repro: live runs
feed it through :class:`repro.obs.recorder.Recorder` (round totals, byte
economies, fault incidents, span timings), offline tools rebuild one from
a JSONL run log (``repro.obs.report --prom``), and the benchmark harness
(``benchmarks/common.py``) lands every ``csv_row`` emission in a shared
module registry — so runs and benchmarks render through the SAME
Prometheus/CSV serializers instead of growing per-module writers.

Deliberately tiny and dependency-free (stdlib + numpy-compatible floats):
no background threads, no clocks, no global state — a registry is a plain
dict the caller owns.  All mutation is O(1) per sample; rendering sorts
for deterministic output.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

# Default histogram buckets: host-seconds scale (spans, round walls).
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                   60.0, float("inf"))

_KINDS = ("counter", "gauge", "histogram")


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = key + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(f) if not f.is_integer() else str(int(f))


class _Histogram:
    __slots__ = ("buckets", "counts", "total", "count")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.total += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:  # per-bucket counts; render accumulates for le=
                self.counts[i] += 1
                break


class MetricsRegistry:
    """Counters / gauges / histograms with labels.

    Metrics auto-register on first touch with the touching method's kind;
    re-using a name with a different kind raises (one name, one kind —
    the Prometheus contract).
    """

    def __init__(self):
        self._kinds: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._buckets: Dict[str, Tuple[float, ...]] = {}
        self._data: Dict[str, Dict[Tuple, object]] = {}

    # -- registration / mutation -----------------------------------------

    def _declare(self, name: str, kind: str, help_: str = "") -> None:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        prev = self._kinds.get(name)
        if prev is not None and prev != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{prev}, cannot re-register as {kind}")
        self._kinds[name] = kind
        if help_:
            self._help[name] = help_
        self._data.setdefault(name, {})

    def describe(self, name: str, kind: str, help_: str = "",
                 buckets: Optional[Iterable[float]] = None) -> None:
        """Optional up-front declaration (kind + help text + buckets)."""
        self._declare(name, kind, help_)
        if buckets is not None:
            self._buckets[name] = tuple(sorted(set(
                list(buckets) + [float("inf")])))

    def inc(self, name: str, value: float = 1.0, /, **labels) -> None:
        """Counter increment (monotone; negative increments raise)."""
        if value < 0:
            raise ValueError(f"counter {name!r} increment must be >= 0")
        self._declare(name, "counter")
        key = _label_key(labels)
        cur = self._data[name].get(key, 0.0)
        self._data[name][key] = float(cur) + float(value)

    def set(self, name: str, value: float, /, **labels) -> None:
        """Gauge set (last write wins)."""
        self._declare(name, "gauge")
        self._data[name][_label_key(labels)] = float(value)

    def observe(self, name: str, value: float, /, **labels) -> None:
        """Histogram observation."""
        self._declare(name, "histogram")
        key = _label_key(labels)
        h = self._data[name].get(key)
        if h is None:
            h = _Histogram(self._buckets.get(name, DEFAULT_BUCKETS))
            self._data[name][key] = h
        h.observe(value)

    # -- reads -----------------------------------------------------------

    def value(self, name: str, /, **labels) -> Optional[float]:
        """Current counter/gauge value (None when never touched)."""
        series = self._data.get(name, {})
        v = series.get(_label_key(labels))
        return None if v is None or isinstance(v, _Histogram) else float(v)

    def samples(self) -> List[Tuple[str, Dict[str, str], float]]:
        """Flat (name, labels, value) view; histograms flatten to their
        ``_sum`` / ``_count`` series.  Sorted, deterministic."""
        out = []
        for name in sorted(self._data):
            for key in sorted(self._data[name]):
                v = self._data[name][key]
                labels = dict(key)
                if isinstance(v, _Histogram):
                    out.append((f"{name}_sum", labels, v.total))
                    out.append((f"{name}_count", labels, float(v.count)))
                else:
                    out.append((name, labels, float(v)))
        return out

    # -- rendering (the one export path) ---------------------------------

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._data):
            kind = self._kinds[name]
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} {kind}")
            for key in sorted(self._data[name]):
                v = self._data[name][key]
                if isinstance(v, _Histogram):
                    acc = 0
                    for b, c in zip(v.buckets, v.counts):
                        acc += c
                        le = _fmt_labels(key, (("le", _fmt_value(b)),))
                        lines.append(f"{name}_bucket{le} {acc}")
                    lbl = _fmt_labels(key)
                    lines.append(f"{name}_sum{lbl} {v.total!r}")
                    lines.append(f"{name}_count{lbl} {v.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)} {float(v)!r}")
        return "\n".join(lines) + "\n"

    def csv_rows(self, header: bool = True) -> List[str]:
        """``metric,labels,value`` rows (histograms as _sum/_count)."""
        rows = ["metric,labels,value"] if header else []
        for name, labels, v in self.samples():
            lbl = ";".join(f"{k}={val}" for k, val in sorted(labels.items()))
            rows.append(f"{name},{lbl},{v!r}")
        return rows
