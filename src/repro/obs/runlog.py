"""Schema-versioned JSONL run log — one event per line.

Event kinds (the ``event`` field):

* ``run_start`` — first line of every log; carries ``schema`` (this
  module's :data:`SCHEMA_VERSION`), the driver (``protocol`` | ``sim``),
  and run metadata (scheme, fleet size, executor/policy, rounds).
* ``round`` — one per :class:`~repro.core.protocol.RoundRecord`, a
  faithful serialization of every record field (plus ``path`` and the
  optional per-client upload-completion offsets ``client_up`` the
  straggler timeline renders).  The round stream ROUND-TRIPS: feeding a
  log back through :func:`history_from_events` reconstructs the exact
  ``RunResult`` history, bit for bit — Python's ``json`` emits float64
  ``repr`` which parses back to the identical double, and every array
  field is written as a list of native floats.
* ``span`` — one per host-side span (``name``, chunk-relative ``t_start``
  and ``dur_s``, optional ``round``).
* ``fault`` — one per fault incident (crash / retry / abort / corrupt /
  quarantine / quorum_skip), from ``repro.sim.faults.incident_events``.
* ``run_end`` — totals (rounds, host seconds, rounds/sec).

Everything here is host-side plumbing over data the drivers already
pulled (the ``ScanTrace`` / ``RoundRecord`` transfer): writing a log adds
NO device->host syncs — pinned by tests/test_obs.py.
"""

from __future__ import annotations

import json
from typing import Dict, IO, List, Optional

import numpy as np

SCHEMA_VERSION = 1

# RoundRecord fields serialized into / parsed out of a ``round`` event.
_RECORD_SCALARS = ("round", "sim_time", "host_wall_time", "mean_loss",
                   "uploaded_fraction", "participants", "sim_round_time",
                   "uploaded_bytes", "wire_bytes", "epsilon", "survivors",
                   "retries", "abandoned_bytes", "quarantined_bytes",
                   "skipped")


def jsonable(x):
    """Numpy-aware conversion to plain JSON types (exact for float64:
    ``json`` round-trips doubles via repr)."""
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.bool_,)):
        return bool(x)
    if isinstance(x, np.ndarray):
        return [jsonable(v) for v in x.tolist()]
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    return x


class JsonlWriter:
    """Append-only JSONL sink; one ``write`` = one line = one event."""

    def __init__(self, path: str):
        self.path = str(path)
        self._fh: Optional[IO] = open(self.path, "w", encoding="utf-8")

    def write(self, event: Dict) -> None:
        if self._fh is None:
            return
        self._fh.write(json.dumps(jsonable(event), separators=(",", ":"))
                       + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def round_event(record, **extra) -> Dict:
    """Serialize one RoundRecord (+ extra context fields) to an event."""
    ev = {"event": "round"}
    for f in _RECORD_SCALARS:
        ev[f] = jsonable(getattr(record, f))
    ev["dropout_rates"] = jsonable(np.asarray(record.dropout_rates))
    ev["metrics"] = jsonable(record.metrics)
    ev.update({k: jsonable(v) for k, v in extra.items()})
    return ev


def record_from_event(ev: Dict):
    """Inverse of :func:`round_event` — an identical RoundRecord."""
    from repro.core.protocol import RoundRecord  # lazy: core imports obs
    kw = {f: ev[f] for f in _RECORD_SCALARS if f in ev}
    metrics = ev.get("metrics")
    return RoundRecord(dropout_rates=np.asarray(ev["dropout_rates"],
                                                np.float64),
                       metrics=metrics, **kw)


def read_events(path: str) -> List[Dict]:
    """Parse a JSONL run log; validates the run_start schema header."""
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    if not events:
        raise ValueError(f"empty run log: {path}")
    head = events[0]
    if head.get("event") != "run_start":
        raise ValueError(f"run log {path} does not start with a "
                         f"run_start event (got {head.get('event')!r})")
    schema = head.get("schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(f"run log {path} has schema {schema!r}; this "
                         f"reader understands {SCHEMA_VERSION}")
    return events


def history_from_events(events: List[Dict]) -> List:
    """The round stream of a parsed log as RoundRecords (exact)."""
    return [record_from_event(ev) for ev in events
            if ev.get("event") == "round"]


def load_history(path: str) -> List:
    """read_events + history_from_events in one call."""
    return history_from_events(read_events(path))
