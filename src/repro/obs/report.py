"""Run-inspection CLI over a JSONL run log.

::

    python -m repro.obs.report results/quickstart_run.jsonl \
        [--csv report.csv] [--prom metrics.prom] [--top 5]

Renders, from the structured events alone (repro.obs.runlog):

* run header — driver, scheme, fleet, wall/sim seconds, rounds/sec;
* per-phase time breakdown — host span totals (calls, total s, mean ms,
  share of spanned time) for the allocate → train → encode → transport →
  aggregate → eval pipeline;
* byte economy — uploaded vs on-wire totals, wire overhead/savings,
  abandoned + quarantined bytes;
* failure economy — skipped rounds, survivor stats, retries, incident
  counts by kind;
* cohort participation — population-mode runs (repro.population): how
  many distinct clients the service reached, first contacts per round,
  and a rounds-participated histogram reconstructed from the per-round
  ``cohort`` events;
* straggler timelines — per-client upload-completion offsets (sim clock)
  with mean/max and slowest-in-round counts; ``--top N`` worst clients —
  prefaced by the correlated-outage windows (repro.sim.outages): each
  cell's down intervals reconstructed from outage_begin/outage_end
  incidents, so a burst of slow rounds reads against the cells that
  were dark while it happened.

``--csv`` writes the per-round stream as CSV; ``--prom`` replays the
round + fault events through the SAME
:func:`repro.obs.recorder.update_round_metrics` mapping a live run uses,
into a fresh registry, and writes its Prometheus text — offline and live
exports always agree.
"""

from __future__ import annotations

import argparse
from collections import defaultdict
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.runlog import _RECORD_SCALARS, read_events


def _fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:,.1f} {unit}" if unit != "B" else f"{n:,.0f} B"
        n /= 1024.0
    return f"{n:,.1f} GiB"


def _section(title: str) -> List[str]:
    return ["", title, "-" * len(title)]


def _header_lines(events: List[Dict]) -> List[str]:
    head = events[0]
    tail = next((e for e in reversed(events)
                 if e.get("event") == "run_end"), None)
    meta = {k: v for k, v in head.items()
            if k not in ("event", "schema")}
    lines = _section("Run")
    lines.append("  " + "  ".join(f"{k}={v}" for k, v in meta.items()))
    if tail is not None:
        lines.append(f"  rounds={tail.get('rounds')}"
                     f"  wall={tail.get('wall_s', 0.0):.3f}s"
                     f"  sim={tail.get('sim_s', 0.0):.3f}s"
                     f"  rounds/sec={tail.get('rounds_per_sec', 0.0):.2f}")
    else:
        lines.append("  (no run_end event — run truncated?)")
    return lines


def _phase_lines(events: List[Dict]) -> List[str]:
    spans = [e for e in events if e.get("event") == "span"]
    lines = _section("Phase breakdown (host spans)")
    if not spans:
        lines.append("  no span events (log written without spans?)")
        return lines
    agg: Dict[str, List[float]] = defaultdict(lambda: [0, 0.0])
    for e in spans:
        a = agg[e["name"]]
        a[0] += 1
        a[1] += float(e["dur_s"])
    total = sum(a[1] for a in agg.values()) or 1.0
    lines.append(f"  {'phase':<16}{'calls':>7}{'total_s':>10}"
                 f"{'mean_ms':>10}{'share':>8}")
    for name, (calls, tot) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        lines.append(f"  {name:<16}{calls:>7}{tot:>10.4f}"
                     f"{1e3 * tot / calls:>10.3f}"
                     f"{100.0 * tot / total:>7.1f}%")
    return lines


def _byte_lines(rounds: List[Dict],
                events: Optional[List[Dict]] = None) -> List[str]:
    lines = _section("Byte economy")
    if not rounds:
        lines.append("  no round events")
        return lines
    up = sum(float(r.get("uploaded_bytes", 0.0)) for r in rounds)
    wire = sum(float(r.get("wire_bytes", 0.0)) for r in rounds)
    aband = sum(float(r.get("abandoned_bytes", 0.0)) for r in rounds)
    quar = sum(float(r.get("quarantined_bytes", 0.0)) for r in rounds)
    lines.append(f"  uploaded (raw payload): {_fmt_bytes(up)}")
    lines.append(f"  on-wire:                {_fmt_bytes(wire)}")
    if up > 0:
        delta = 100.0 * (wire - up) / up
        word = "overhead" if delta >= 0 else "savings"
        lines.append(f"  wire {word}:          {abs(delta):.1f}%")
    lines.append(f"  abandoned (late/aborted): {_fmt_bytes(aband)}")
    lines.append(f"  quarantined (screened):   {_fmt_bytes(quar)}")
    # client-sharded runs: cross-device Eq. (4) collective bytes
    # (repro.comm.payload.account_collective) — the per-link (1-D)
    # saving of the compacted top-K exchange vs a dense psum
    coll = [e for e in (events or [])
            if e.get("event") == "collective"]
    if coll:
        dense = sum(float(e.get("dense", 0.0)) for e in coll)
        moved = sum(float(e.get("wire", 0.0)) for e in coll)
        lines.append(f"  cross-device (collective): {_fmt_bytes(moved)}"
                     f" of {_fmt_bytes(dense)} dense-psum equivalent")
        if dense > 0:
            lines.append(f"  per-link savings:         "
                         f"{100.0 * (1.0 - moved / dense):.1f}%")
    return lines


def _failure_lines(events: List[Dict], rounds: List[Dict]) -> List[str]:
    lines = _section("Failure economy")
    if not rounds:
        lines.append("  no round events")
        return lines
    skipped = sum(1 for r in rounds if r.get("skipped"))
    retries = sum(int(r.get("retries", 0)) for r in rounds)
    surv = [int(r.get("survivors", 0)) for r in rounds]
    part = [int(r.get("participants", 0)) for r in rounds]
    lines.append(f"  rounds: {len(rounds)}  skipped (quorum): {skipped}"
                 f"  retries: {retries}")
    if surv:
        lines.append(f"  survivors: min {min(surv)} / mean "
                     f"{sum(surv) / len(surv):.1f} / of "
                     f"{max(part) if part else 0} participants")
    incidents = [e for e in events if e.get("event") == "fault"]
    if incidents:
        by_kind: Dict[str, int] = defaultdict(int)
        for e in incidents:
            by_kind[e.get("kind", "unknown")] += 1
        kinds = "  ".join(f"{k}={c}" for k, c in sorted(by_kind.items()))
        lines.append(f"  incidents: {kinds}")
    else:
        lines.append("  incidents: none recorded")
    return lines


def _outage_lines(events: List[Dict]) -> List[str]:
    """Correlated-outage windows (repro.sim.outages), reconstructed from
    the outage_begin / outage_end fault incidents: one line per window,
    so straggler offsets can be read against which cells were dark."""
    begins = [e for e in events if e.get("event") == "fault"
              and e.get("kind") == "outage_begin"]
    ends = [e for e in events if e.get("event") == "fault"
            and e.get("kind") == "outage_end"]
    if not begins and not ends:
        return []
    lines = _section("Outage windows (correlated cell failures)")
    open_by_cell: Dict[int, Dict] = {}
    windows = []     # (cell, begin_round, end_round|None, duration|None,
    #                   members)
    for e in sorted(begins + ends, key=lambda e: int(e.get("round", 0))):
        cell = int(e.get("cell", -1))
        if e.get("kind") == "outage_begin":
            open_by_cell[cell] = e
        else:
            b = open_by_cell.pop(cell, None)
            windows.append((cell,
                            int(b["round"]) if b else None,
                            int(e.get("round", 0)),
                            e.get("duration"),
                            e.get("members", [])))
    for cell, b in sorted(open_by_cell.items()):
        windows.append((cell, int(b["round"]), None, None,
                        b.get("members", [])))
    windows.sort(key=lambda w: (w[1] if w[1] is not None else -1, w[0]))
    for cell, b, end, dur, members in windows:
        span = (f"rounds {b}-{end - 1}" if b is not None and end is not None
                else f"round {b}- (still down at end)" if end is None
                else f"-round {end - 1} (down from start of log)")
        dur_s = f"  ({dur} epoch{'s' if dur != 1 else ''} down)" \
            if dur is not None else ""
        mem = ",".join(str(m) for m in members)
        lines.append(f"  cell {cell}: {span}{dur_s}  members {mem}")
    return lines


def _cohort_lines(events: List[Dict]) -> List[str]:
    """Cohort participation (population-mode runs, repro.population):
    coverage of the population, first contacts per round, and the
    rounds-participated histogram.  Empty when the log holds no
    ``cohort`` events (fleet-mode runs render no section)."""
    cohorts = [e for e in events if e.get("event") == "cohort"]
    if not cohorts:
        return []
    lines = _section("Cohort participation (population mode)")
    pop = int(cohorts[0].get("population", 0))
    sizes = {int(e.get("cohort_size", 0)) for e in cohorts}
    served: set = set()
    participated: Dict[int, int] = defaultdict(int)
    for e in cohorts:
        served.update(int(c) for c in e.get("cohort", []))
        for c in e.get("participated", []):
            participated[int(c)] += 1
    size_s = (str(next(iter(sizes))) if len(sizes) == 1
              else f"{min(sizes)}-{max(sizes)}")
    lines.append(f"  population: {pop}  cohort size: {size_s}"
                 f"  rounds: {len(cohorts)}")
    lines.append(f"  distinct clients served: {len(served)}"
                 f" ({100.0 * len(served) / pop:.1f}% of population)"
                 if pop else f"  distinct clients served: {len(served)}")
    fc = [(int(e.get("round", i)), int(e.get("first_contact", 0)))
          for i, e in enumerate(cohorts)]
    shown = " ".join(f"r{r}={c}" for r, c in fc[:12])
    more = "  ..." if len(fc) > 12 else ""
    lines.append(f"  first contacts/round: total {sum(c for _, c in fc)}"
                 f"  {shown}{more}")
    hist: Dict[int, int] = defaultdict(int)
    for c in participated.values():
        hist[c] += 1
    lines.append("  rounds-participated histogram:")
    for times in sorted(hist):
        lines.append(f"    {times:>3} round{'s' if times != 1 else ''}: "
                     f"{hist[times]} client{'s' if hist[times] != 1 else ''}")
    return lines


def _straggler_lines(rounds: List[Dict], top: int) -> List[str]:
    lines = _section("Straggler timeline (per-client upload offsets)")
    tracked = [r for r in rounds if r.get("client_up")]
    if not tracked:
        lines.append("  no per-client timing in this log")
        return lines
    n = max(len(r["client_up"]) for r in tracked)
    tot = [0.0] * n
    cnt = [0] * n
    mx = [0.0] * n
    slowest = [0] * n
    for r in tracked:
        ups = r["client_up"]
        seen = [(i, float(t)) for i, t in enumerate(ups) if t is not None]
        for i, t in seen:
            tot[i] += t
            cnt[i] += 1
            mx[i] = max(mx[i], t)
        if seen:
            slowest[max(seen, key=lambda it: it[1])[0]] += 1
    stats = [(i, tot[i] / cnt[i], mx[i], slowest[i], cnt[i])
             for i in range(n) if cnt[i]]
    stats.sort(key=lambda s: -s[1])
    lines.append(f"  {len(tracked)} rounds tracked, {len(stats)} clients;"
                 f" slowest {min(top, len(stats))} by mean offset:")
    lines.append(f"  {'client':>8}{'mean_s':>10}{'max_s':>10}"
                 f"{'slowest_in':>12}{'uploads':>9}")
    for i, mean, m, slow, c in stats[:top]:
        lines.append(f"  {i:>8}{mean:>10.4f}{m:>10.4f}{slow:>12}{c:>9}")
    return lines


def render(events: List[Dict], top: int = 5) -> str:
    rounds = [e for e in events if e.get("event") == "round"]
    lines: List[str] = []
    lines += _header_lines(events)
    lines += _phase_lines(events)
    lines += _byte_lines(rounds, events)
    lines += _failure_lines(events, rounds)
    lines += _outage_lines(events)
    lines += _cohort_lines(events)
    lines += _straggler_lines(rounds, top)
    return "\n".join(lines).lstrip("\n") + "\n"


def rounds_csv(events: List[Dict]) -> str:
    """Per-round stream as CSV (the scalar RoundRecord fields)."""
    cols = list(_RECORD_SCALARS)
    rows = [",".join(cols)]
    for e in events:
        if e.get("event") != "round":
            continue
        rows.append(",".join(repr(e.get(c, "")) if isinstance(e.get(c), float)
                             else str(e.get(c, "")) for c in cols))
    return "\n".join(rows) + "\n"


def registry_from_events(events: List[Dict]) -> MetricsRegistry:
    """Replay round + fault events into a fresh registry via the SAME
    mapping a live Recorder uses (update_round_metrics)."""
    from repro.obs.recorder import update_round_metrics
    from repro.obs.runlog import record_from_event
    reg = MetricsRegistry()
    for e in events:
        if e.get("event") == "round":
            update_round_metrics(reg, record_from_event(e),
                                 scheme=e.get("scheme", ""),
                                 path=e.get("path", ""))
        elif e.get("event") == "fault":
            reg.inc("feddd_fault_incidents_total", 1,
                    kind=e.get("kind", "unknown"))
    return reg


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Inspect a FedDD JSONL run log: phase timings, "
                    "byte/failure economies, straggler timelines.")
    ap.add_argument("jsonl", help="run log written via --log-jsonl / "
                                  "ObsConfig.jsonl_path")
    ap.add_argument("--csv", metavar="PATH",
                    help="also write the per-round stream as CSV")
    ap.add_argument("--prom", metavar="PATH",
                    help="also write Prometheus text metrics replayed "
                         "from the log")
    ap.add_argument("--top", type=int, default=5,
                    help="straggler clients to list (default 5)")
    args = ap.parse_args(argv)

    events = read_events(args.jsonl)
    print(render(events, top=args.top), end="")
    if args.csv:
        with open(args.csv, "w", encoding="utf-8") as fh:
            fh.write(rounds_csv(events))
        print(f"\nwrote per-round CSV -> {args.csv}")
    if args.prom:
        with open(args.prom, "w", encoding="utf-8") as fh:
            fh.write(registry_from_events(events).prometheus_text())
        print(f"wrote Prometheus text -> {args.prom}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
