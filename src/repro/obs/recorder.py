"""Run recorder — the one observability hook the drivers talk to.

:class:`ObsConfig` rides :class:`repro.core.protocol.ProtocolConfig`
(field ``obs``); the protocol driver and the sim runner build a recorder
per run via :func:`make_recorder`.  The default config is INERT: it
resolves to the shared :data:`NULL_RECORDER`, whose every method is a
no-op returning immediately — the hard contract is that disabled
observability leaves learning state bit-identical on all four execution
paths and compiles the identical engine programs (tests/test_obs.py pins
both, mirroring the zero-rate-faults contract of repro.sim.faults).

A live :class:`Recorder` composes three sinks:

* a :class:`~repro.obs.metrics.MetricsRegistry` (own or shared via
  ``ObsConfig.registry``) — round/byte/failure counters, per-scheme
  loss/accuracy gauges, span histograms;
* an optional JSONL run log (``ObsConfig.jsonl_path`` —
  repro.obs.runlog), one event per round / span / fault incident;
* optional ``jax.profiler`` trace annotations (``ObsConfig.trace``):
  every host span also enters a ``TraceAnnotation``, so spans line up
  with device activity in a profiler trace.  The fused/scanned device
  pipelines themselves are annotated UNCONDITIONALLY with
  ``jax.named_scope`` phase names (compile-time metadata only — see
  core/round_engine.py), which is why enabling tracing never triggers a
  recompile.

Everything the recorder consumes is already host-side (the round's one
``device_get`` / the chunk's ``ScanTrace`` pull): recording adds no
device->host transfers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Iterator, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.runlog import SCHEMA_VERSION, JsonlWriter, round_event

# Round-pipeline phase names (host spans + the named_scope annotations in
# core/round_engine.py use the same vocabulary).
PHASES = ("allocate", "local_train", "encode", "transport", "decode",
          "aggregate", "eval", "engine_step", "host_transfer",
          "chunk_dispatch", "client_update")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs (``ProtocolConfig.obs``).

    enabled: master switch.  Any of the other fields being set also
      activates recording (setting a log path IS opting in).
    jsonl_path: write the structured JSONL run log here (repro.obs.runlog;
      overwritten per run).
    trace: wrap host spans in ``jax.profiler.TraceAnnotation`` so they
      show up in profiler traces next to device activity.
    registry: share a :class:`MetricsRegistry` across runs (benchmark
      sweeps aggregating into one export); None gives the run its own.
    """

    enabled: bool = False
    jsonl_path: Optional[str] = None
    trace: bool = False
    registry: Optional[MetricsRegistry] = None

    @property
    def active(self) -> bool:
        return bool(self.enabled or self.jsonl_path or self.trace
                    or self.registry is not None)


class NullRecorder:
    """Inert recorder — every hook no-ops.  Shared singleton
    :data:`NULL_RECORDER`; the disabled-observability bit-identity
    contract rests on these methods doing nothing at all."""

    active = False
    registry = None

    def span(self, name: str, round: Optional[int] = None):  # noqa: A002
        return contextlib.nullcontext()

    def span_done(self, name: str, t_start: float,
                  round: Optional[int] = None) -> None:  # noqa: A002
        pass

    def event(self, kind: str, /, **fields) -> None:
        pass

    def fault(self, round: int, incident: Dict) -> None:  # noqa: A002
        pass

    def uplink(self, uploaded_bytes: float, wire_bytes: float) -> None:
        pass

    def collective(self, dense_bytes: float, wire_bytes: float) -> None:
        pass

    def round(self, record, *, path: str = "", scheme: str = "",
              client_times=None) -> None:
        pass

    def close(self) -> None:
        pass


NULL_RECORDER = NullRecorder()


def update_round_metrics(reg: MetricsRegistry, record, *, scheme: str,
                         path: str) -> None:
    """Fold one RoundRecord into a registry — THE round->metrics mapping,
    shared by the live recorder and the offline report's ``--prom``
    replay so both render identical numbers."""
    lbl = dict(scheme=scheme, path=path)
    reg.inc("feddd_rounds_total", 1, **lbl)
    if record.skipped:
        reg.inc("feddd_rounds_skipped_total", 1, **lbl)
    if record.retries:
        reg.inc("feddd_retries_total", record.retries, **lbl)
    if record.abandoned_bytes:
        reg.inc("feddd_abandoned_bytes_total", record.abandoned_bytes,
                **lbl)
    if record.quarantined_bytes:
        reg.inc("feddd_quarantined_bytes_total",
                record.quarantined_bytes, **lbl)
    reg.set("feddd_mean_loss", record.mean_loss, scheme=scheme)
    reg.set("feddd_sim_time_seconds", record.sim_time, scheme=scheme)
    if record.metrics and "accuracy" in record.metrics:
        reg.set("feddd_accuracy", float(record.metrics["accuracy"]),
                scheme=scheme)
    reg.observe("feddd_round_host_seconds", record.host_wall_time, **lbl)
    reg.observe("feddd_sim_round_seconds", record.sim_round_time, **lbl)


class Recorder:
    """Live recorder: metrics + spans + JSONL events for one run."""

    active = True

    def __init__(self, cfg: ObsConfig, *, driver: str, **meta):
        self.cfg = cfg
        self.registry = cfg.registry if cfg.registry is not None \
            else MetricsRegistry()
        self._writer = (JsonlWriter(cfg.jsonl_path)
                        if cfg.jsonl_path else None)
        self._t0 = time.perf_counter()
        self._rounds = 0
        self._host_s = 0.0
        self._sim_s = 0.0
        self._closed = False
        self.event("run_start", schema=SCHEMA_VERSION, driver=driver,
                   **meta)

    # -- spans -----------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str,
             round: Optional[int] = None) -> Iterator[None]:  # noqa: A002
        """Host-side span around one pipeline phase.  With
        ``ObsConfig.trace`` the span also enters a ``jax.profiler``
        TraceAnnotation, so profiler timelines carry the same names."""
        ctx = contextlib.nullcontext()
        if self.cfg.trace:
            import jax
            ctx = jax.profiler.TraceAnnotation(name)
        start = time.perf_counter()
        with ctx:
            yield
        self.span_done(name, start, round=round)

    def span_done(self, name: str, t_start: float,
                  round: Optional[int] = None) -> None:  # noqa: A002
        """Record a span that already ran, from its ``perf_counter`` start.

        For phases awkward to wrap in a ``with`` block (the sim runner's
        event-timeline section).  No profiler annotation — retroactive
        spans cannot wrap device dispatches.
        """
        dur = time.perf_counter() - t_start
        self.registry.observe("feddd_span_seconds", dur, name=name)
        ev = {"name": name, "t_start": t_start - self._t0, "dur_s": dur}
        if round is not None:
            ev["round"] = int(round)
        self.event("span", **ev)

    # -- events ----------------------------------------------------------

    def event(self, kind: str, /, **fields) -> None:
        # ``kind`` is positional-only: fault incidents legitimately carry
        # a "kind" field of their own (crash/retry/...), which must land
        # in ``fields`` rather than collide with the event kind.
        if self._writer is not None:
            self._writer.write({"event": kind, **fields})

    def fault(self, round: int, incident: Dict) -> None:  # noqa: A002
        """One fault incident (repro.sim.faults.incident_events dict)."""
        self.registry.inc("feddd_fault_incidents_total", 1,
                          kind=incident.get("kind", "unknown"))
        self.event("fault", round=round, **incident)

    def uplink(self, uploaded_bytes: float, wire_bytes: float) -> None:
        """Byte counters fed from THE shared reduction
        (repro.comm.payload.account_uplink)."""
        self.registry.inc("feddd_uploaded_bytes_total",
                          float(uploaded_bytes))
        self.registry.inc("feddd_wire_bytes_total", float(wire_bytes))

    def collective(self, dense_bytes: float, wire_bytes: float) -> None:
        """Cross-device Eq. (4) reduction bytes, fed from THE shared
        reduction (repro.comm.payload.account_collective).  ``dense_bytes``
        is the dense-psum equivalent, ``wire_bytes`` what the configured
        collective actually moved; the ``feddd_cross_device_bytes`` gauge
        tracks the latest round so dashboards see the live (1-D) per-link
        saving next to the cumulative counters."""
        self.registry.inc("feddd_collective_dense_bytes_total",
                          float(dense_bytes))
        self.registry.inc("feddd_collective_bytes_total",
                          float(wire_bytes))
        self.registry.set("feddd_cross_device_bytes", float(wire_bytes))
        self.event("collective", dense=float(dense_bytes),
                   wire=float(wire_bytes))

    def round(self, record, *, path: str = "", scheme: str = "",
              client_times=None) -> None:
        """Fold one finished RoundRecord into metrics + the run log.

        ``client_times`` (optional, (N,) float, NaN = did not upload) are
        the per-client upload-completion offsets on the SIMULATED clock —
        the straggler-timeline axis of ``repro.obs.report``.
        """
        self._rounds += 1
        self._host_s += float(record.host_wall_time)
        self._sim_s = float(record.sim_time)
        update_round_metrics(self.registry, record, scheme=scheme,
                             path=path)
        if self._writer is not None:
            extra = {"path": path, "scheme": scheme}
            if client_times is not None:
                ct = np.asarray(client_times, float)
                extra["client_up"] = [None if not np.isfinite(v)
                                      else float(v) for v in ct]
            self._writer.write(round_event(record, **extra))

    def close(self) -> None:
        """Final run_end event + run-level gauges.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        wall = time.perf_counter() - self._t0
        rps = self._rounds / wall if wall > 0 else 0.0
        self.registry.set("feddd_rounds_per_sec", rps)
        self.event("run_end", rounds=self._rounds, wall_s=wall,
                   host_round_s=self._host_s, sim_s=self._sim_s,
                   rounds_per_sec=rps)
        if self._writer is not None:
            self._writer.close()


def make_recorder(cfg: Optional[ObsConfig], *, driver: str, **meta):
    """Recorder for an active config, :data:`NULL_RECORDER` otherwise."""
    if cfg is None or not cfg.active:
        return NULL_RECORDER
    return Recorder(cfg, driver=driver, **meta)
