"""Unified observability layer: metrics registry, round/span tracing,
structured JSONL run logs, and a run-inspection CLI.

Entry points:

* :class:`ObsConfig` — rides ``ProtocolConfig.obs``; default is inert.
* :func:`make_recorder` — a :class:`Recorder` for active configs, the
  shared :data:`NULL_RECORDER` (all no-ops) otherwise.
* :class:`MetricsRegistry` — counters/gauges/histograms with labels,
  Prometheus-text + CSV rendering (also the benchmark export path).
* ``repro.obs.runlog`` — schema-versioned JSONL events; round events
  round-trip to bit-identical RoundRecords.
* ``python -m repro.obs.report <run.jsonl>`` — phase/byte/failure
  summaries, straggler timelines, ``--csv`` / ``--prom`` export.

Import discipline: core/sim modules import ``repro.obs``; nothing in
this package imports core/sim at module level (runlog pulls RoundRecord
lazily), so the dependency edge stays one-way.
"""

from repro.obs.metrics import DEFAULT_BUCKETS, MetricsRegistry
from repro.obs.recorder import (NULL_RECORDER, ObsConfig, NullRecorder,
                                PHASES, Recorder, make_recorder,
                                update_round_metrics)
from repro.obs.runlog import (SCHEMA_VERSION, JsonlWriter,
                              history_from_events, jsonable, load_history,
                              read_events, record_from_event, round_event)

__all__ = [
    "DEFAULT_BUCKETS", "MetricsRegistry",
    "NULL_RECORDER", "ObsConfig", "NullRecorder", "PHASES", "Recorder",
    "make_recorder", "update_round_metrics",
    "SCHEMA_VERSION", "JsonlWriter", "history_from_events", "jsonable",
    "load_history", "read_events", "record_from_event", "round_event",
]
