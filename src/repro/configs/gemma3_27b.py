"""gemma3-27b [dense] — 5:1 local:global attention, 128k context, qk-norm.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-27b-pt (family card: google/gemma-3-1b-pt)]

long_500k runs for this arch: 51 of 62 layers use a 1024-token sliding
window; the ~10 global layers use windowed KV for the 500k decode shape per
Gemma-3's own long-context serving recipe (DESIGN.md §4).
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=21504, vocab_size=262144,
        activation="geglu", norm="rmsnorm", qk_norm=True,
        rope="1d", rope_theta=1_000_000.0,
        local_global_ratio=(5, 1), window_size=1024,
        tie_embeddings=True, embed_scale=True,
        source="hf:google/gemma-3-1b-pt (gemma-3 family)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, window_size=16,
        local_global_ratio=(1, 1))
