"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  [arXiv:2403.19887]

Block layout per Jamba: period-8 super-blocks with ONE attention layer and
seven Mamba layers; MoE replaces the dense FFN on every second layer
(MoEConfig.every=2).
"""

import dataclasses

from repro.models.config import MambaConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=24576, vocab_size=65536,
        activation="swiglu", norm="rmsnorm",
        rope="none",                   # Jamba attention layers are NoPE
        block_pattern=("mamba", "attn") + ("mamba",) * 6,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                      capacity_factor=1.25, every=2),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        tie_embeddings=False,
        source="arXiv:2403.19887 (Jamba-1.5)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
        block_pattern=("mamba", "attn", "mamba", "mamba"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256, every=2),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2))
