"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, GQA kv=4, qk-norm.

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936.
[hf:Qwen/Qwen3-30B-A3B]
"""

import dataclasses

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        activation="swiglu", norm="rmsnorm", qk_norm=True,
        rope="1d", rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                      capacity_factor=1.25),
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128))
