"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).

48L d_model=2048 4H d_ff=0 (blocks have internal projections) vocab=50304.
[arXiv:2405.04517]
"""

import dataclasses

from repro.models.config import ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        activation="swiglu", norm="rmsnorm",
        rope="none",
        block_pattern=("mlstm",) * 7 + ("slstm",),
        xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk_size=256),
        tie_embeddings=True,
        source="arXiv:2405.04517 (xLSTM)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        vocab_size=512, block_pattern=("mlstm", "slstm"),
        xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, chunk_size=32))
