"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.  [arXiv:2402.16819]
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
        head_dim=192, d_ff=73728, vocab_size=256000,
        activation="squared_relu", norm="layernorm",
        rope="1d", rotary_pct=0.5,      # nemotron uses partial rotary
        tie_embeddings=False,
        source="arXiv:2402.16819 (Nemotron-4 340B)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=384, num_heads=4, num_kv_heads=2,
        head_dim=96, d_ff=768, vocab_size=512)
