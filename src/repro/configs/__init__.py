"""Architecture registry: the 10 assigned architectures + the paper's own
FL models.  ``get_config(name)`` / ``list_configs()`` are the public API;
each assigned arch also provides ``reduced`` (smoke-test variant: <=2 layers,
d_model<=512, <=4 experts) via ``get_config(name, reduced=True)``.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

ARCH_IDS = [
    "pixtral_12b",
    "chatglm3_6b",
    "qwen3_moe_30b_a3b",
    "jamba_1p5_large_398b",
    "granite_3_8b",
    "xlstm_1p3b",
    "gemma3_27b",
    "whisper_medium",
    "nemotron_4_340b",
    "granite_moe_1b_a400m",
]

# hyphenated aliases matching the assignment text
ALIASES = {
    "pixtral-12b": "pixtral_12b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "granite-3-8b": "granite_3_8b",
    "xlstm-1.3b": "xlstm_1p3b",
    "gemma3-27b": "gemma3_27b",
    "whisper-medium": "whisper_medium",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}


def list_configs() -> List[str]:
    return list(ARCH_IDS)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.config()
