"""pixtral-12b [vlm] — Pixtral-ViT frontend (STUB) + Mistral-Nemo decoder.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
[hf:mistralai/Pixtral-12B-2409]
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        activation="swiglu", norm="rmsnorm",
        rope="1d", rope_theta=1_000_000_000.0,
        num_patch_tokens=256,           # stub ViT patch embeddings prefix
        tie_embeddings=False,
        source="hf:mistralai/Pixtral-12B-2409",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, num_patch_tokens=8)
