"""whisper-medium [audio] — encoder-decoder; mel+conv frontend is a STUB
(input_specs provides frame embeddings).

24L decoder + 24L encoder, d_model=1024 16H d_ff=4096 vocab=51865.
[arXiv:2212.04356]
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        activation="gelu", norm="layernorm",
        rope="none",                    # absolute sinusoidal positions
        encoder_layers=24, encoder_seq_cap=1500,
        tie_embeddings=True,
        source="arXiv:2212.04356 (Whisper)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, encoder_layers=2)
