"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff(expert)=512 vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""

import dataclasses

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155,
        activation="swiglu", norm="rmsnorm",
        rope="1d", rope_theta=10_000.0,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                      capacity_factor=1.25),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128))
