"""chatglm3-6b [dense] — RoPE-2d (partial rotary), GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.  [arXiv:2406.12793]
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        head_dim=128, d_ff=13696, vocab_size=65024,
        activation="swiglu", norm="rmsnorm",
        rope="2d", rotary_pct=0.5,       # GLM applies rotary to half the dim
        tie_embeddings=False,
        source="arXiv:2406.12793 (ChatGLM family), hf:THUDM/chatglm3-6b",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512)
