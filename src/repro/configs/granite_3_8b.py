"""granite-3-8b [dense] — GQA kv=8.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base (family card)]
"""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=12800, vocab_size=49155,
        activation="swiglu", norm="rmsnorm",
        rope="1d", rope_theta=10_000_000.0,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-8b-base",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=515)   # keep odd vocab on purpose
