"""Sparse-set codecs for upload masks — who survived the dropout, in bytes.

FedDD masks are *channel*-granular: per leaf, the kept set is a subset of
the C channels (selection.build_masks returns leaves shaped
(1, ..., C, ..., 1)).  A sparse upload therefore ships, per leaf, an
encoding of that channel subset plus the kept values.  This module owns
the subset encodings and their exact byte-size formulas:

* ``bitmask`` — a 4-byte kept-count header + ceil(C/8) packed bits.
  Density-independent: the right choice for moderate-to-high densities.
* ``index``  — a 4-byte header + the kept channel indices, sorted
  ascending, delta-encoded (gaps ``idx_k - idx_{k-1} - 1``) and
  varint-compressed (7 data bits per byte, MSB continuation).  ~1 byte
  per kept channel at low density; the winner below density ~1/8.
* ``dense``  — the values-only idealization: NO mask bytes at all (the
  receiver is assumed to know the mask).  This is exactly the analytic
  accounting the core protocol used before this subsystem existed, kept
  as the bit-identical baseline (``CommConfig()`` default).
* ``auto``   — per leaf, a 1-byte codec tag + the cheaper of bitmask and
  index — rides the crossover automatically.  (At full density the
  ``dense`` codec itself is the fallback that beats index coding; the
  degenerate-settings tests pin that ordering.)

Byte-size formulas come in two renderings that MUST agree:

* the *measured* formulas here (``mask_overhead_bytes*``) — computed from
  an actual mask, in pure int32 arithmetic (comparison sums, no float
  log2), so they are jax-traceable AND bit-stable across XLA programs:
  the multi-round ``lax.scan`` engine carries them in its trace and the
  per-round dispatch must reproduce them exactly;
* the serialized encodings (``encode_mask`` / ``decode_mask``) — real
  byte buffers whose length equals the measured formula and whose
  roundtrip is exact (tests/test_comm.py pins both).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

CODECS = ("dense", "bitmask", "index", "auto")

# Per-leaf framing for the sparse codecs: a u32 kept-count header (bitmask
# and index), plus a 1-byte codec tag when "auto" picks per leaf.  The
# dense idealization ships no mask and no header by construction.
HEADER_BYTES = 4
AUTO_TAG_BYTES = 1

# varint thresholds: value v needs 1 + sum(v >= 2^(7k)) bytes (7 data bits
# per byte).  Channel gaps are < 2^28 for any model this repo can hold, so
# four thresholds suffice and everything stays in int32.
_VARINT_THRESHOLDS = (1 << 7, 1 << 14, 1 << 21, 1 << 28)


def varint_bytes(values, xp=jnp):
    """Bytes to varint-encode each non-negative integer in ``values``.

    Integer comparison sums only — exact (no float log2), traceable when
    ``xp is jnp``, and identical under numpy for host-side accounting.
    """
    v = xp.asarray(values)
    out = xp.ones_like(v, dtype=xp.int32)
    for t in _VARINT_THRESHOLDS:
        out = out + (v >= t).astype(xp.int32)
    return out


def bitmask_bytes(num_channels: int) -> int:
    """Packed-bitmask payload bytes for a C-channel leaf (header excluded)."""
    return (int(num_channels) + 7) // 8


def _cummax(x, xp):
    if xp is jnp:
        return jax.lax.cummax(x, axis=x.ndim - 1)
    return np.maximum.accumulate(x, axis=-1)


def _index_gaps(mask1d, xp=jnp):
    """Delta gaps ``idx_k - idx_{k-1} - 1`` at kept positions, else 0.

    ``mask1d`` is a 0/1 vector (..., C); the previous kept index is an
    exclusive running max of ``i if kept else -1`` (first gap counts from
    index -1, so a kept channel 0 encodes gap 0).
    """
    m = xp.asarray(mask1d) > 0
    c = m.shape[-1]
    idx = xp.arange(c, dtype=xp.int32)
    marked = xp.where(m, idx, xp.asarray(-1, xp.int32))
    incl = _cummax(marked, xp)
    prev = xp.concatenate(
        [xp.full(m.shape[:-1] + (1,), -1, xp.int32), incl[..., :-1]],
        axis=-1)
    return xp.where(m, idx - prev - 1, 0), m


def index_bytes(mask1d, xp=jnp):
    """Exact delta+varint payload bytes for a 0/1 channel mask (...,C)
    (header excluded).  Empty mask -> 0 payload bytes."""
    gaps, m = _index_gaps(mask1d, xp)
    return xp.sum(xp.where(m, varint_bytes(gaps, xp), 0),
                  axis=-1).astype(xp.int32)


def _leaf_channel_mask(mask_leaf, lead: int, xp):
    """Collapse a broadcastable mask leaf to (..., C).

    Engine masks are (N, 1, ..., C, ..., 1); per-client masks are
    (1, ..., C, ..., 1); scalar-leaf masks are (N,) or ().  All non-channel
    dims are 1, so a reshape to (lead dims, -1) is the channel vector.
    """
    m = xp.asarray(mask_leaf)
    if lead:
        return m.reshape(m.shape[:lead] + (-1,))
    return m.reshape(-1)


def _leaf_overhead(m1d, num_channels: int, codec: str, xp):
    """Measured per-leaf mask overhead (..., ) int32 for one codec
    (``m1d`` is the (..., C) channel mask).  The dense idealization ships
    no mask — zero overhead (int8 scale framing is added by the callers)."""
    lead_shape = m1d.shape[:-1]
    if codec == "dense":
        return xp.zeros(lead_shape, xp.int32)
    bm = HEADER_BYTES + bitmask_bytes(num_channels)
    ix = HEADER_BYTES + index_bytes(m1d, xp)
    if codec == "bitmask":
        return xp.broadcast_to(xp.asarray(bm, xp.int32), lead_shape)
    if codec == "index":
        return ix
    if codec == "auto":
        return AUTO_TAG_BYTES + xp.minimum(ix, xp.asarray(bm, xp.int32))
    raise ValueError(f"unknown sparse codec {codec!r}; one of {CODECS}")


def mask_overhead_bytes_stacked(masks, params_stacked, comm) -> jax.Array:
    """Measured mask overhead per client, (N,) int32, jax-traceable.

    Args:
      masks: stacked mask pytree, leaves (N, 1, ..., C, ..., 1) — exactly
        what ``selection.build_masks_batched`` (or the engines' dense
        all-ones masks) produce.
      params_stacked: the matching stacked params (client-count anchor —
        scalar-leaf masks may carry no client axis of their own).
      comm: a :class:`repro.comm.payload.CommConfig`.

    Includes the int8 per-leaf scale framing (4 bytes per leaf with a
    non-empty kept set) when ``comm.qbits == 8`` — the scale ships with
    the mask header, not the values.  Everything is int32 comparison/sum
    arithmetic, so per-round dispatch and the scan-inlined rendering
    return identical bytes (no optimization_barrier needed).
    """
    mleaves = jax.tree_util.tree_leaves(masks)
    n = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]
    total = jnp.zeros((n,), jnp.int32)
    for m in mleaves:
        m1d = _leaf_channel_mask(m, 1, jnp)
        if m1d.shape[0] != n:    # mask leaf without a client axis
            m1d = jnp.broadcast_to(m1d.reshape(1, -1), (n, m1d.shape[-1]))
        nch = int(m1d.shape[-1])
        oh = _leaf_overhead(m1d, nch, comm.codec, jnp)
        if comm.qbits == 8:
            kept = jnp.sum((m1d > 0).astype(jnp.int32), axis=-1)
            oh = oh + 4 * (kept > 0).astype(jnp.int32)
        total = total + oh
    return total


def full_upload_overhead_bytes(spec, comm) -> int:
    """Measured overhead of a FULL (all-channels) upload, closed form.

    Dense-mask rounds (the fedavg/fedcs/oort baselines, and the reference
    loop's all-ones masks) keep every channel, but the engines represent
    those masks with a collapsed channel dim of 1 — encoding THAT shape
    would undercount the real mask bytes.  The all-ones mask's cost is a
    constant of the model shape: bitmask = header + ceil(C/8); index =
    header + C (every gap is 0 -> 1 varint byte per kept channel); auto =
    tag + min of the two — exactly what ``mask_overhead_bytes`` returns
    for a materialized all-ones C-channel mask, and exactly what
    ``payload.analytic_wire_bytes`` charges at dropout 0, so the record
    and the clock agree.  ``spec`` is a ``payload.WireSpec``.
    """
    total = 0
    for c, _ in spec.leaves:
        if comm.codec != "dense":
            bm = HEADER_BYTES + bitmask_bytes(c)
            ix = HEADER_BYTES + c
            if comm.codec == "bitmask":
                total += bm
            elif comm.codec == "index":
                total += ix
            else:                    # auto
                total += AUTO_TAG_BYTES + min(bm, ix)
        if comm.qbits == 8:
            total += 4               # per-leaf scale, kept set non-empty
    return total


def mask_overhead_bytes(masks, params, comm) -> int:
    """Per-client (un-stacked) measured overhead — the reference-loop and
    encode_upload rendering of :func:`mask_overhead_bytes_stacked`."""
    del params  # kept for signature symmetry with the stacked rendering
    total = 0
    for m in jax.tree_util.tree_leaves(masks):
        m1d = np.asarray(jax.device_get(m)).reshape(-1)
        nch = int(m1d.shape[0])
        oh = int(_leaf_overhead(m1d[None], nch, comm.codec, np)[0])
        if comm.qbits == 8 and int(np.sum(m1d > 0)) > 0:
            oh += 4
        total += oh
    return total


# ------------------------------------------------------------ wire bytes

def encode_mask(mask1d: np.ndarray, codec: str) -> bytes:
    """Serialize a 0/1 channel mask.  ``len(result)`` equals the measured
    formula (header + payload) for the chosen codec; ``dense`` encodes to
    b"" (receiver-known mask, the analytic idealization)."""
    m = np.asarray(mask1d).reshape(-1) > 0
    kept = int(np.sum(m))
    header = np.uint32(kept).tobytes()
    if codec == "dense":
        return b""
    if codec == "bitmask":
        return header + np.packbits(m).tobytes()
    if codec == "index":
        gaps, mm = _index_gaps(m.astype(np.int32)[None], np)
        out = bytearray(header)
        for g in np.asarray(gaps[0])[np.asarray(mm[0])]:
            v = int(g)
            while True:
                b = v & 0x7F
                v >>= 7
                out.append(b | (0x80 if v else 0))
                if not v:
                    break
        return bytes(out)
    if codec == "auto":
        bm = encode_mask(m, "bitmask")
        ix = encode_mask(m, "index")
        tag, body = (1, bm) if len(bm) <= len(ix) else (2, ix)
        return bytes([tag]) + body
    raise ValueError(f"unknown codec {codec!r}; one of {CODECS}")


def decode_mask(buf: bytes, num_channels: int, codec: str,
                kept_hint: Optional[int] = None) -> np.ndarray:
    """Inverse of :func:`encode_mask` -> 0/1 float32 vector of length C.

    ``dense`` needs the receiver-known mask; with no hint it decodes to
    all-ones (full upload), which is the only case the idealization is
    byte-accounted for."""
    if codec == "dense":
        return np.ones(num_channels, np.float32)
    if codec == "auto":
        tag = buf[0]
        inner = {1: "bitmask", 2: "index"}[tag]
        return decode_mask(buf[1:], num_channels, inner)
    kept = int(np.frombuffer(buf[:4], np.uint32)[0])
    body = buf[4:]
    if codec == "bitmask":
        bits = np.unpackbits(np.frombuffer(body, np.uint8))[:num_channels]
        m = bits.astype(np.float32)
        assert int(m.sum()) == kept
        return m
    if codec == "index":
        m = np.zeros(num_channels, np.float32)
        pos, prev = 0, -1
        for _ in range(kept):
            v, shift = 0, 0
            while True:
                b = body[pos]
                pos += 1
                v |= (b & 0x7F) << shift
                shift += 7
                if not (b & 0x80):
                    break
            prev = prev + 1 + v
            m[prev] = 1.0
        return m
    raise ValueError(f"unknown codec {codec!r}; one of {CODECS}")
