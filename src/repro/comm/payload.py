"""Upload payloads and on-wire byte accounting.

Glue layer of the wire-format subsystem: combines the mask codecs
(repro.comm.codecs) and value codecs (repro.comm.quantize) into

* :class:`CommConfig` — the protocol-level wire-format choice
  (``ProtocolConfig.comm``); the default (dense codec, 32-bit values) is
  the pre-comm analytic accounting, bit for bit.
* :class:`WireSpec` — the static per-model shape summary (channel / element
  counts per leaf) the analytic byte model and the overhead-aware
  allocation need.  Hashable, so it rides jit static args and lru caches.
* :func:`encode_upload` / :func:`decode_upload` — an actual serialized
  per-client upload (host-side): per-leaf mask bytes + quantized kept
  values.  The roundtrip contract (tests/test_comm.py): decoded masks are
  exact for every codec, decoded values are bit-identical for qbits=32,
  cast-exact for 16, and within one scale step (deterministically, keyed)
  for 8; ``payload.nbytes`` equals the measured accounting formulas.
* the accounting helpers every driver charges through:
  :func:`uplink_bytes_raw` (the ONE place raw ``density x model_bytes``
  uploads are computed — protocol executors, the scanned splice, and the
  sim runner all call it, so wire accounting cannot drift from it),
  :func:`account_uplink` (raw + wire bytes from measured overheads), and
  :func:`analytic_wire_bytes` (the byte model as a function of the
  dropout rate — what the Eq. (12) clock, the sim's event scheduling, and
  the overhead-aware LP consume; exact for dense/bitmask, an expected
  uniform-gap estimate for index/auto).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import codecs, quantize


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Wire-format choice for a protocol run.

    codec: mask encoding — ``dense`` (values-only idealization; the
      pre-comm accounting), ``bitmask``, ``index``, or ``auto`` (per-leaf
      cheaper of the two sparse encodings; crossover density ~1/8).
    qbits: value precision — 32 (lossless), 16 (fp16 cast), 8 (int8
      stochastic rounding; also quantizes the values the server
      AGGREGATES — clients keep local full precision for Eq. (5)).
    overhead_aware_allocation: solve the dropout LP on effective
      bytes-per-kept-parameter (nonlinear in the dropout rate) instead of
      the linear ``U_n`` proxy.  Host-side fixed point — requires
      ``allocator="numpy"`` (so it cannot ride the multi-round scan).
    """

    codec: str = "dense"
    qbits: int = 32
    overhead_aware_allocation: bool = False

    def __post_init__(self):
        if self.codec not in codecs.CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; "
                             f"expected one of {codecs.CODECS}")
        if self.qbits not in quantize.QBITS:
            raise ValueError(f"qbits must be one of {quantize.QBITS}, "
                             f"got {self.qbits}")

    @property
    def is_default(self) -> bool:
        """True when the wire format is the pre-comm analytic accounting
        (dense codec, lossless values): every driver must then be
        bit-identical to a run without a comm config at all."""
        return self.codec == "dense" and self.qbits == 32


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static shape summary of one model: per-leaf (channels, elements).

    Built once per model (host-side shape inspection only) and hashable,
    so the scanned engine can bake it into its compiled round body and the
    overhead-aware LP can cache on it.
    """

    leaves: Tuple[Tuple[int, int], ...]   # per leaf: (C, total elements)

    @classmethod
    def from_params(cls, params, channel_axis: int = -1) -> "WireSpec":
        out = []
        for l in jax.tree_util.tree_leaves(params):
            if l.ndim == 0:
                out.append((1, 1))
                continue
            ax = channel_axis % l.ndim
            out.append((int(l.shape[ax]),
                        int(np.prod(l.shape, dtype=np.int64))))
        return cls(tuple(out))

    @classmethod
    def from_stacked(cls, stacked, channel_axis: int = -1) -> "WireSpec":
        """Spec from client-STACKED params (leading client axis dropped)."""
        one = jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct(
            l.shape[1:], l.dtype), stacked)
        return cls.from_params(one, channel_axis)

    @property
    def total_elements(self) -> int:
        return sum(e for _, e in self.leaves)


# ------------------------------------------------------- real payloads

@dataclasses.dataclass
class LeafUpload:
    mask_bytes: bytes
    value_bytes: bytes
    scale: Optional[float]        # int8 per-leaf scale (ships with framing)
    num_channels: int
    shape: Tuple[int, ...]
    channel_axis: int             # which leaf axis the mask spans (part of
                                  # the model schema both ends share — NOT
                                  # inferable from shape for square leaves)
    known_mask: Optional[np.ndarray] = None   # dense codec: the mask the
                                              # receiver is assumed to know
                                              # (out-of-band, zero bytes)


@dataclasses.dataclass
class UploadPayload:
    """One client's serialized sparse upload (host-side rendering)."""

    leaves: List[LeafUpload]
    treedef: object
    comm: CommConfig

    @property
    def nbytes(self) -> int:
        """Total on-wire bytes: mask framing + quantized values + int8
        scales.  Equals the measured accounting
        (codecs.mask_overhead_bytes + kept * value_bytes)."""
        total = 0
        for lf in self.leaves:
            total += len(lf.mask_bytes) + len(lf.value_bytes)
            if lf.scale is not None:
                total += 4
        return total


def encode_upload(params, masks, comm: CommConfig,
                  key: Optional[jax.Array] = None) -> UploadPayload:
    """Serialize one client's masked update What ⊙ M.

    ``key`` is the client's quantization key
    (:func:`repro.comm.quantize.client_quant_key`), folded per leaf in
    flatten order — the same noise the in-engine QDQ draws, so
    ``decode_upload(encode_upload(x))`` equals the values the server's
    aggregation actually consumed.
    """
    pleaves, treedef = jax.tree_util.tree_flatten(params)
    mleaves = jax.tree_util.tree_leaves(masks)
    out: List[LeafUpload] = []
    for i, (p, m) in enumerate(zip(pleaves, mleaves)):
        p_host = np.asarray(jax.device_get(p), np.float32)
        m_host = np.asarray(jax.device_get(m), np.float32)
        m1d = m_host.reshape(-1)
        # the channel axis is the mask's single non-unit axis (mask
        # leaves are (1, ..., C, ..., 1)); an all-unit mask degenerates
        # to the last axis
        nonunit = [ax for ax, s in enumerate(m_host.shape) if s > 1]
        ch_ax = nonunit[0] if nonunit else max(m_host.ndim - 1, 0)
        mask_buf = codecs.encode_mask(m1d, comm.codec)
        mfull = np.broadcast_to(m_host, p_host.shape) > 0
        kept_vals = p_host[mfull]
        scale = None
        if comm.qbits == 32:
            buf = kept_vals.astype(np.float32).tobytes()
        elif comm.qbits == 16:
            buf = kept_vals.astype(np.float16).tobytes()
        else:
            leaf_key = (jax.random.fold_in(key, i) if key is not None
                        else None)
            codes, s = quantize.quantize_leaf(jnp.asarray(p_host),
                                              comm.qbits, leaf_key)
            # the scale only ships when there are values to decode with it
            scale = float(s) if int(np.sum(mfull)) else None
            buf = np.asarray(jax.device_get(codes))[mfull].tobytes()
        out.append(LeafUpload(mask_buf, buf, scale, int(m1d.shape[0]),
                              tuple(p_host.shape), ch_ax,
                              known_mask=(m1d if comm.codec == "dense"
                                          else None)))
    return UploadPayload(out, treedef, comm)


def decode_upload(payload: UploadPayload):
    """Inverse of :func:`encode_upload` -> (values, masks) pytrees.

    ``values`` holds the decoded kept values at their positions (zeros at
    dropped positions — exactly the numerator contribution of Eq. (4));
    ``masks`` is the decoded full-shape 0/1 mask.
    """
    comm = payload.comm
    vals, msks = [], []
    for lf in payload.leaves:
        m1d = (np.asarray(lf.known_mask, np.float32)
               if lf.known_mask is not None
               else codecs.decode_mask(lf.mask_bytes, lf.num_channels,
                                       comm.codec))
        # re-inflate the channel vector to the leaf's broadcast shape on
        # the axis the sender recorded (shape alone is ambiguous for
        # square leaves)
        if len(lf.shape) == 0:
            mfull = np.ones((), np.float32) * m1d[0]
        else:
            shape = [1] * len(lf.shape)
            shape[lf.channel_axis] = lf.num_channels
            mfull = np.broadcast_to(m1d.reshape(shape), lf.shape)
        sel = mfull > 0
        kept = int(np.sum(sel))
        if comm.qbits == 32:
            dec = np.frombuffer(lf.value_bytes, np.float32, count=kept)
        elif comm.qbits == 16:
            dec = np.frombuffer(lf.value_bytes, np.float16,
                                count=kept).astype(np.float32)
        else:
            q = np.frombuffer(lf.value_bytes, np.int8, count=kept)
            dec = (q.astype(np.float32) * lf.scale
                   if lf.scale and lf.scale > 0 else np.zeros(kept,
                                                              np.float32))
        full = np.zeros(lf.shape, np.float32)
        full[sel] = dec
        vals.append(full)
        msks.append(np.asarray(mfull, np.float32))
    return (jax.tree_util.tree_unflatten(payload.treedef, vals),
            jax.tree_util.tree_unflatten(payload.treedef, msks))


# ------------------------------------------------------- byte accounting

def uplink_bytes_raw(densities, participants, model_bytes) -> float:
    """THE raw uploaded-bytes reduction: sum_n density_n * U_n over the
    round's uploaders.  Single source for ``RoundRecord.uploaded_bytes``
    (and ``uploaded_fraction``) — every executor, the scanned splice, and
    the sim runner charge through here.
    """
    d = np.asarray(densities, np.float64)
    p = np.asarray(participants, np.float64)
    return float(np.dot(d * p, np.asarray(model_bytes, np.float64)))


def account_uplink(densities, participants, model_bytes, wire_overhead,
                   comm: CommConfig, obs=None) -> Tuple[float, float]:
    """(uploaded_bytes, wire_bytes) for one round.

    ``uploaded_bytes`` is the raw kept-parameter mass (density x U_n, the
    pre-comm accounting).  ``wire_bytes`` rescales the values to the
    codec's precision and adds the MEASURED per-client mask overhead
    (``wire_overhead``, from codecs.mask_overhead_bytes_stacked; None for
    the dense codec).  With the default CommConfig the two are the same
    float, bitwise.

    ``obs`` (a ``repro.obs`` recorder) hooks the byte counters here — the
    one shared reduction — so ``feddd_uploaded_bytes_total`` /
    ``feddd_wire_bytes_total`` always agree with the RoundRecord stream
    regardless of which executor charged the round.
    """
    raw = uplink_bytes_raw(densities, participants, model_bytes)
    if comm.is_default:
        wire = raw
    else:
        wire = raw * (comm.qbits / 32.0)
        if wire_overhead is not None:
            wire += float(np.dot(np.asarray(wire_overhead, np.float64),
                                 np.asarray(participants, np.float64)))
    if obs is not None and obs.active:
        obs.uplink(raw, wire)
    return raw, wire


def collective_payload_bytes(spec: WireSpec, *, mode: str = "dense",
                             k_fraction: float = 1.0) -> float:
    """Per-shard, per-hop bytes of ONE Eq. (4) cross-device reduction.

    The client-sharded engines (core/round_engine.py ShardedRoundEngine)
    reduce per-shard (num, den) partials over the mesh's ``clients`` axis.
    This is the analytic byte model of that exchange, the cross-device
    sibling of :func:`analytic_wire_bytes`:

    * ``dense``: each shard contributes the full float32 numerator (every
      leaf element) plus the (C,) denominator channel profile per leaf —
      what a dense psum moves per hop.
    * ``sparse``: the compacted top-K exchange of
      ``core/sparse_collective.py`` — per leaf
      ``K = max(1, ceil(C * k_fraction))`` rows of ``elements/C`` float32
      values, plus K int32 channel indices and K float32 den rows.

    The ratio sparse/dense therefore tracks ``k_fraction`` (= 1 - D for a
    uniform fleet): the (1-D) per-link saving the paper's WAN uplink
    argument maps onto the cross-device interconnect.
    """
    if mode not in ("dense", "sparse"):
        raise ValueError(f"mode must be 'dense' or 'sparse', got {mode!r}")
    total = 0.0
    for c, e in spec.leaves:
        if mode == "dense":
            total += e * 4.0 + c * 4.0
        else:
            k = max(1, min(c, int(np.ceil(c * k_fraction))))
            total += k * (e / c) * 4.0 + k * 4.0 + k * 4.0
    return total


def account_collective(spec: WireSpec, num_shards: int, *,
                       mode: str = "dense", k_fraction: float = 1.0,
                       obs=None) -> Tuple[float, float]:
    """(dense_bytes, actual_bytes) of one round's Eq. (4) reduction,
    summed over the mesh's shards.

    ``dense_bytes`` is what the round WOULD have moved with a dense psum;
    ``actual_bytes`` is what the configured collective moved (equal for
    ``mode="dense"``).  ``obs`` (a ``repro.obs`` recorder) hooks the
    cross-device byte counters here, mirroring :func:`account_uplink` for
    the uplink leg — ``repro.obs.report`` renders the ratio as the
    (1-D) per-link saving.
    """
    dense = collective_payload_bytes(spec, mode="dense") * num_shards
    actual = collective_payload_bytes(
        spec, mode=mode, k_fraction=k_fraction) * num_shards
    if obs is not None and obs.active:
        obs.collective(dense, actual)
    return dense, actual


def analytic_wire_bytes(spec: WireSpec, dropout, comm: CommConfig, xp=np):
    """Modelled on-wire upload bytes as a function of the dropout rate.

    Mirrors the mask builder exactly on kept counts (per leaf,
    ``kept = clip(ceil(C*(1-D)), 0, C)`` — the same D for every leaf) and
    the measured formulas on framing.  Exact for ``dense`` and
    ``bitmask``; for ``index``/``auto`` the varint gap length uses the
    expected uniform spacing ``C/kept - 1`` (the measured overhead
    depends on WHICH channels survive, which only the mask knows).

    ``dropout`` may be scalar or a vector (broadcasts); ``xp=jnp`` gives
    the traced rendering the scanned engine's device clock uses.
    """
    d = xp.asarray(dropout, xp.float32)
    vbytes = float(quantize.value_bytes(comm.qbits))
    values = xp.zeros_like(d)
    overhead = xp.zeros_like(d)
    for c, e in spec.leaves:
        kept = xp.clip(xp.ceil(c * (1.0 - d)), 0.0, float(c))
        values = values + kept * (e / c) * vbytes
        if comm.qbits == 8:
            overhead = overhead + 4.0 * (kept > 0).astype(xp.float32)
        if comm.codec != "dense":
            bm = float(codecs.HEADER_BYTES + codecs.bitmask_bytes(c))
            if comm.codec in ("index", "auto"):
                gap = xp.maximum(c / xp.maximum(kept, 1.0) - 1.0, 0.0)
                gap_b = varint_bytes_f(gap, xp)
                ix = codecs.HEADER_BYTES + kept * gap_b
                if comm.codec == "index":
                    overhead = overhead + ix
                else:
                    overhead = (overhead + codecs.AUTO_TAG_BYTES
                                + xp.minimum(ix, bm))
            else:
                overhead = overhead + bm
    return values + overhead


def delivered_prefix_counts(spec: WireSpec, dropout: float,
                            comm: CommConfig,
                            delivered_bytes: float) -> np.ndarray:
    """Per-leaf kept-channel counts a truncated upload actually delivered.

    The serialized upload walks leaves in flatten order, each leaf's mask
    framing first and then its kept channels in ascending channel index
    (:func:`encode_upload`), so a byte cut maps exactly to a per-leaf
    prefix of kept channels: the partial-aggregation feature of the
    deadline policy (sim/faults.py) feeds these counts to
    :func:`repro.core.aggregation.truncate_masks_to_prefix`.

    The per-leaf kept counts and framing mirror
    :func:`analytic_wire_bytes` (and therefore the mask builder's
    ``kept = clip(ceil(C*(1-D)), 0, C)``) bit for bit: a cut at the
    analytic total delivers every kept channel, a cut at 0 delivers none.
    Returns an (L,) int32 array, one entry per spec leaf.
    """
    remaining = float(delivered_bytes)
    vbytes = float(quantize.value_bytes(comm.qbits))
    counts = np.zeros(len(spec.leaves), np.int32)
    for li, (c, e) in enumerate(spec.leaves):
        kept = int(np.clip(np.ceil(c * (1.0 - float(dropout))), 0.0,
                           float(c)))
        per_kept = (e / c) * vbytes
        frame = 0.0
        if comm.qbits == 8 and kept > 0:
            frame += 4.0
        if comm.codec != "dense":
            bm = float(codecs.HEADER_BYTES + codecs.bitmask_bytes(c))
            if comm.codec in ("index", "auto"):
                gap = max(c / max(kept, 1.0) - 1.0, 0.0)
                gap_b = float(varint_bytes_f(gap))
                ix = codecs.HEADER_BYTES + kept * gap_b
                if comm.codec == "index":
                    per_kept += gap_b
                    frame += codecs.HEADER_BYTES
                elif ix < bm:
                    per_kept += gap_b
                    frame += codecs.AUTO_TAG_BYTES + codecs.HEADER_BYTES
                else:
                    frame += codecs.AUTO_TAG_BYTES + bm
            else:
                frame += bm
        if remaining < frame or kept == 0:
            break
        remaining -= frame
        got = (kept if per_kept <= 0.0
               else min(kept, int(np.floor(remaining / per_kept + 1e-9))))
        counts[li] = got
        remaining -= got * per_kept
        if got < kept:
            break
    return counts


def varint_bytes_f(v, xp=np):
    """Float rendering of codecs.varint_bytes for the analytic model
    (expected gaps are fractional)."""
    out = xp.ones_like(xp.asarray(v, xp.float32))
    for t in (1 << 7, 1 << 14, 1 << 21, 1 << 28):
        out = out + (xp.asarray(v) >= t).astype(xp.float32)
    return out


def analytic_uplink_vector(specs, dropout_vec, comm: CommConfig
                           ) -> np.ndarray:
    """Per-client analytic uplink bytes for a (possibly ragged) fleet:
    ``specs`` is one WireSpec per client, ``dropout_vec`` the (N,) rates.
    The host-side vector the Eq. (12) clock and the sim's event scheduling
    charge when the codec is not dense."""
    d = np.asarray(dropout_vec, np.float64)
    out = np.empty_like(d)
    cache = {}
    for i, spec in enumerate(specs):
        key = (spec, float(d[i]))
        if key not in cache:
            cache[key] = float(analytic_wire_bytes(spec, d[i], comm,
                                                   xp=np))
        out[i] = cache[key]
    return out
