"""Wire-format subsystem — what a FedDD upload actually costs on the wire.

The core protocol's byte accounting is analytic: ``density x model_bytes``.
A real sparse upload must also ship *which* parameters survived the dropout
(a mask encoding) and may quantize the surviving values (Caldas et al.,
1812.07210; Coded Federated Dropout, 2201.11036).  This package is the
transport layer that makes those costs first-class:

  codecs     sparse-set encodings for the per-leaf channel mask — packed
             bitmask, sorted-index delta+varint, the dense (values-only)
             idealization, and an auto per-leaf minimum — with exact,
             jax-traceable byte-size formulas (integer arithmetic only, so
             the scanned multi-round engine carries them bit-stably)
  quantize   value codecs for the kept payload: fp32 (lossless), fp16
             (deterministic cast), int8 with PRNG-keyed stochastic
             rounding (unbiased, deterministic cross-process)
  payload    per-client encode_upload / decode_upload over masked pytrees,
             the CommConfig / WireSpec plumbing, and the byte-accounting
             helpers every driver charges through (uplink_bytes_raw /
             account_uplink / analytic_wire_bytes)

Routing: ``ProtocolConfig(comm=CommConfig(codec=..., qbits=...))``.  With
the default ``CommConfig()`` (dense codec, 32-bit values) every driver is
bit-identical to the pre-comm accounting: ``RoundRecord.wire_bytes ==
uploaded_bytes`` exactly, and the Eq. (12) clock is untouched.  Sparse
codecs add the measured mask overhead to ``wire_bytes`` and charge the
codec's analytic bytes on the uplink leg of the clock; ``qbits < 32``
additionally quantizes the values the server aggregates (the client's own
Eq. (5) update keeps its local full-precision weights).

The bitmask/index crossover: a packed bitmask costs ceil(C/8) bytes per
leaf regardless of density, delta+varint index coding costs ~1 byte per
kept channel at low density — index wins below density ~1/8 (~0.125),
bitmask above (benchmarks/wire_formats.py measures it on the real grid).
"""

from repro.comm.codecs import (AUTO_TAG_BYTES, CODECS, HEADER_BYTES,
                               bitmask_bytes, decode_mask, encode_mask,
                               index_bytes, mask_overhead_bytes,
                               mask_overhead_bytes_stacked, varint_bytes)
from repro.comm.payload import (CommConfig, UploadPayload, WireSpec,
                                account_uplink, analytic_uplink_vector,
                                analytic_wire_bytes, decode_upload,
                                encode_upload, uplink_bytes_raw)
from repro.comm.quantize import (QBITS, quantize_dequantize,
                                 quantize_dequantize_stacked, scale_bytes,
                                 value_bytes)
