"""Value codecs for the kept upload payload — fp32 / fp16 / int8-SR.

The mask codecs (repro.comm.codecs) say WHICH parameters ship; this module
says how many bytes each surviving value costs and what the server decodes:

* ``qbits=32`` — lossless: the identity.  4 bytes/value.
* ``qbits=16`` — IEEE fp16 cast roundtrip.  Deterministic (no key), 2
  bytes/value; cast-roundtrip error is the usual half-precision ulp.
* ``qbits=8``  — symmetric int8 with PRNG-keyed STOCHASTIC rounding
  (Caldas et al., 1812.07210 style): per leaf, scale = max|x| / 127 and
  q = clip(floor(x/scale + u), -127, 127) with u ~ U[0,1) drawn from a
  jax PRNG key.  Unbiased (E[q*scale] = x), error bounded by one scale
  step, and — because the noise is counter-based threefry on an explicit
  key — deterministic across processes and across the per-client /
  batched / scanned execution paths.  1 byte/value + a 4-byte scale per
  leaf (charged with the mask framing in codecs.mask_overhead_bytes*).

Key discipline mirrors mask building exactly: the round key is folded as
``fold_in(round_key, 20_000 + client_index)`` (masks use 10_000 +) and
then per-leaf ``fold_in(client_key, leaf_index)`` in flatten order, so the
per-client loop, the stacked engine, the grouped engine, and the
multi-round scan all draw the SAME noise for the same client/leaf — the
cross-path bit-exactness contracts extend to quantized uploads.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

QBITS = (32, 16, 8)

# PRNG fold namespace for quantization keys (masks use 10_000 + i).
_QKEY_OFFSET = 20_000


def value_bytes(qbits: int) -> int:
    """Bytes per surviving parameter value."""
    if qbits not in QBITS:
        raise ValueError(f"qbits must be one of {QBITS}, got {qbits}")
    return qbits // 8


def scale_bytes(qbits: int) -> int:
    """Per-leaf framing bytes for the value codec (int8 ships a scale)."""
    return 4 if qbits == 8 else 0


def _int8_scale(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0


def quantize_leaf(x: jax.Array, qbits: int, key: Optional[jax.Array] = None):
    """Encode one leaf -> (codes, scale).  fp32/fp16 codes are the values
    themselves in the target dtype; int8 codes are the SR integers."""
    if qbits == 32:
        return x.astype(jnp.float32), None
    if qbits == 16:
        return x.astype(jnp.float16), None
    if key is None:
        raise ValueError("qbits=8 stochastic rounding requires a PRNG key")
    xf = x.astype(jnp.float32)
    scale = _int8_scale(xf)
    u = jax.random.uniform(key, xf.shape)
    q = jnp.clip(jnp.floor(xf / jnp.maximum(scale, 1e-30) + u), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_leaf(codes: jax.Array, scale: Optional[jax.Array],
                    qbits: int) -> jax.Array:
    if qbits == 32:
        return codes.astype(jnp.float32)
    if qbits == 16:
        return codes.astype(jnp.float32)
    return jnp.where(scale > 0, codes.astype(jnp.float32) * scale, 0.0)


def qdq_leaf(x: jax.Array, qbits: int,
             key: Optional[jax.Array] = None) -> jax.Array:
    """quantize -> dequantize one leaf (what the server's aggregate sees).
    Identity for qbits=32; preserves the input dtype."""
    if qbits == 32:
        return x
    codes, scale = quantize_leaf(x, qbits, key)
    return dequantize_leaf(codes, scale, qbits).astype(x.dtype)


def quantize_dequantize(params, key: Optional[jax.Array], qbits: int):
    """Per-client QDQ over a pytree, folding the leaf index into ``key``
    in flatten order (the per-client reference-loop rendering).

    Bitwise stability: inputs and outputs are fenced with
    ``lax.optimization_barrier`` (as is :func:`quantize_dequantize_stacked`)
    so the QDQ subgraph is opaque to any enclosing fusion — without the
    fence, XLA folds the trailing ``q * scale`` into the engine's Eq. (4)
    multiply chain as an fma.  With the fence, every JITTED rendering
    (per-round engine, grouped engine, multi-round scan) returns the same
    bits; the EAGER per-op rendering may still legally differ by an ulp
    in the division chain (XLA compiles per program — see the int8
    engine-vs-loop test), which is why the reference-loop contract for
    int8 is ulp-scale rather than bitwise."""
    if qbits == 32:
        return params
    leaves, treedef = jax.tree_util.tree_flatten(params)
    leaves = list(jax.lax.optimization_barrier(tuple(leaves)))
    out = [qdq_leaf(l, qbits,
                    jax.random.fold_in(key, i) if key is not None else None)
           for i, l in enumerate(leaves)]
    out = list(jax.lax.optimization_barrier(tuple(out)))
    return jax.tree_util.tree_unflatten(treedef, out)


def client_quant_key(round_key: jax.Array, client_index) -> jax.Array:
    """The per-client quantization key: fold_in(round_key, 20_000 + i)."""
    return jax.random.fold_in(round_key, _QKEY_OFFSET + client_index)


def quantize_dequantize_stacked(stacked, rng: Optional[jax.Array],
                                qbits: int, client_indices=None):
    """Client-stacked QDQ: leaves (N, *leaf) -> same, with per-client keys
    ``fold_in(fold_in(rng, 20_000 + i), leaf_index)`` — bit-identical to
    looping :func:`quantize_dequantize` with
    ``key=client_quant_key(rng, i)`` (scale is a max reduction, exact in
    any order; everything else is elementwise).

    ``client_indices`` defaults to ``arange(N)``; shape groups pass their
    members' fleet positions, async merges their buffer rows — exactly the
    mask builder's convention.  Traced values are fine (scan-safe).
    """
    if qbits == 32:
        return stacked
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    leaves = list(jax.lax.optimization_barrier(tuple(leaves)))
    n = leaves[0].shape[0]
    client_keys = None
    if rng is not None:
        ids = (jnp.asarray(client_indices)
               if client_indices is not None else jnp.arange(n))
        client_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng, i))(_QKEY_OFFSET + ids)
    out = []
    for i, l in enumerate(leaves):
        if qbits == 16:
            out.append(qdq_leaf(l, qbits))
            continue
        leaf_keys = jax.vmap(lambda k: jax.random.fold_in(k, i))(client_keys)
        out.append(jax.vmap(lambda x, k: qdq_leaf(x, qbits, k))(l, leaf_keys))
    # opaque outputs: see quantize_dequantize — keeps the jitted engine's
    # aggregation from fma-fusing across the QDQ boundary
    out = list(jax.lax.optimization_barrier(tuple(out)))
    return jax.tree_util.tree_unflatten(treedef, out)
