"""Batching pipeline: deterministic, epoch-shuffled minibatch iterators for
client shards + a packed-sequence LM batcher for the pod-scale drivers."""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic import SyntheticImageDataset


@dataclasses.dataclass
class BatchIterator:
    """Epoch-shuffled minibatches over one client's shard.

    Deterministic given (seed, epoch): reshuffles at every epoch boundary;
    the final short batch is dropped (matching the paper's per-epoch SGD).
    """
    x: np.ndarray
    y: np.ndarray
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        if len(self.x) != len(self.y):
            raise ValueError("x/y length mismatch")

    def epoch(self, epoch_idx: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1000 * epoch_idx)
        idx = rng.permutation(len(self.x))
        n_full = len(idx) // self.batch_size
        for i in range(n_full):
            sl = idx[i * self.batch_size:(i + 1) * self.batch_size]
            yield self.x[sl], self.y[sl]

    def steps_per_epoch(self) -> int:
        return len(self.x) // self.batch_size


def client_iterators(ds: SyntheticImageDataset, parts, batch_size: int,
                     *, flatten: bool = False, seed: int = 0):
    """One BatchIterator per client shard."""
    its = []
    for ci, p in enumerate(parts):
        x = ds.x[p]
        if flatten:
            x = x.reshape(len(p), -1)
        its.append(BatchIterator(x, ds.y[p], batch_size, seed=seed + ci))
    return its


@dataclasses.dataclass
class PackedLMBatcher:
    """Fixed-length LM batches from a token stream (pod-scale training)."""
    tokens: np.ndarray            # (N,) int32
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        starts = rng.integers(0, len(self.tokens) - self.seq_len - 1,
                              self.batch_size)
        return {"tokens": np.stack([self.tokens[s:s + self.seq_len]
                                    for s in starts])}
