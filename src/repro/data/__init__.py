from repro.data.synthetic import (SyntheticImageDataset, make_dataset,
                                  make_lm_dataset)
from repro.data.partition import (partition_class_imbalanced,
                                  partition_dirichlet, partition_iid,
                                  partition_noniid_a, partition_noniid_b,
                                  label_distribution, label_coverage_score)
