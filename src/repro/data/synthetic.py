"""Synthetic datasets (offline container — no MNIST/FMNIST/CIFAR10 files).

``make_dataset`` builds a Gaussian-mixture image-classification set whose
shapes match the paper's datasets:

  mnist-like    (28, 28, 1), 10 classes
  fmnist-like   (28, 28, 1), 10 classes
  cifar10-like  (32, 32, 3), 10 classes

Each class is a mixture of ``modes_per_class`` anisotropic Gaussians over a
low-dimensional latent space projected through a fixed random linear map +
tanh, which gives datasets that (a) are learnable by the paper's MLP/CNN
models, (b) have non-trivial class structure so Non-IID splits genuinely
hurt, and (c) are fully reproducible from a seed.  DESIGN.md §8 records this
deviation (comparative trends, not absolute accuracies, are the target).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticImageDataset:
    x: np.ndarray        # (N, H, W, C) float32 in [-1, 1]
    y: np.ndarray        # (N,) int32
    num_classes: int
    name: str

    def __len__(self) -> int:
        return len(self.y)

    def subset(self, idx: np.ndarray) -> "SyntheticImageDataset":
        return SyntheticImageDataset(self.x[idx], self.y[idx],
                                     self.num_classes, self.name)


_SHAPES = {
    "mnist": (28, 28, 1),
    "fmnist": (28, 28, 1),
    "cifar10": (32, 32, 3),
}


def make_dataset(name: str, *, num_train: int = 20_000,
                 num_test: int = 4_000, num_classes: int = 10,
                 latent_dim: int = 32, modes_per_class: int = 3,
                 class_sep: float = 3.2, noise: float = 0.9,
                 seed: int = 0) -> Tuple[SyntheticImageDataset,
                                         SyntheticImageDataset]:
    """Returns (train, test)."""
    if name not in _SHAPES:
        raise ValueError(f"unknown dataset {name!r}; options {list(_SHAPES)}")
    h, w, c = _SHAPES[name]
    d_out = h * w * c
    # crc32, not hash(): str hash is salted per process (PYTHONHASHSEED), so
    # the "same seed" would otherwise generate a different dataset each run.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2 ** 16))
    proj = rng.normal(0, 1.0 / np.sqrt(latent_dim), (latent_dim, d_out))
    centers = rng.normal(0, class_sep,
                         (num_classes, modes_per_class, latent_dim))

    def _sample(n: int, seed_off: int):
        r = np.random.default_rng(seed + seed_off)
        y = r.integers(0, num_classes, n).astype(np.int32)
        mode = r.integers(0, modes_per_class, n)
        z = centers[y, mode] + r.normal(0, noise, (n, latent_dim))
        x = np.tanh(z @ proj).astype(np.float32).reshape(n, h, w, c)
        return x, y

    xtr, ytr = _sample(num_train, 1)
    xte, yte = _sample(num_test, 2)
    return (SyntheticImageDataset(xtr, ytr, num_classes, name),
            SyntheticImageDataset(xte, yte, num_classes, name))


def make_lm_dataset(*, vocab_size: int, num_tokens: int = 1 << 20,
                    order: int = 2, seed: int = 0) -> np.ndarray:
    """Synthetic token stream with Markov structure (so an LM has something
    to learn); used by the federated pod-training example."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition structure
    fanout = min(32, vocab_size)
    nxt = rng.integers(0, vocab_size, (vocab_size, fanout))
    toks = np.empty(num_tokens, np.int32)
    t = rng.integers(0, vocab_size)
    for i in range(num_tokens):
        toks[i] = t
        t = nxt[t, rng.integers(0, fanout)]
    return toks
