"""Client data partitioners — the paper's §6.1 heterogeneity settings.

  IID        every class uniformly across clients
  Non-IID-a  each client holds a random number (2..C) of classes
  Non-IID-b  each client holds exactly 3 random classes
  Dirichlet  standard Dir(alpha) label-skew partition (extra)
  class-imbalanced  global dataset with rare classes (paper §6.7)

All return a list of index arrays (one per client).
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import SyntheticImageDataset


def _split_among(idx: np.ndarray, owners: List[int], rng,
                 parts: List[List[int]]):
    rng.shuffle(idx)
    chunks = np.array_split(idx, len(owners))
    for o, ch in zip(owners, chunks):
        parts[o].extend(ch.tolist())


def partition_iid(ds: SyntheticImageDataset, num_clients: int,
                  seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = np.arange(len(ds))
    rng.shuffle(idx)
    return [np.sort(a) for a in np.array_split(idx, num_clients)]


def _partition_by_classes(ds, num_clients, classes_per_client, seed):
    rng = np.random.default_rng(seed)
    c = ds.num_classes
    client_classes = [rng.choice(c, size=k, replace=False)
                      for k in classes_per_client]
    parts: List[List[int]] = [[] for _ in range(num_clients)]
    for cls in range(c):
        owners = [i for i in range(num_clients)
                  if cls in client_classes[i]]
        if not owners:   # ensure every class is held somewhere
            owners = [int(rng.integers(num_clients))]
        idx = np.where(ds.y == cls)[0].copy()
        _split_among(idx, owners, rng, parts)
    return [np.sort(np.asarray(p, np.int64)) for p in parts]


def partition_noniid_a(ds: SyntheticImageDataset, num_clients: int,
                       seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    ks = rng.integers(2, ds.num_classes + 1, num_clients)
    return _partition_by_classes(ds, num_clients, ks.tolist(), seed + 1)


def partition_noniid_b(ds: SyntheticImageDataset, num_clients: int,
                       seed: int = 0) -> List[np.ndarray]:
    return _partition_by_classes(ds, num_clients, [3] * num_clients, seed)


def partition_dirichlet(ds: SyntheticImageDataset, num_clients: int,
                        alpha: float = 0.5, seed: int = 0
                        ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    parts: List[List[int]] = [[] for _ in range(num_clients)]
    for cls in range(ds.num_classes):
        idx = np.where(ds.y == cls)[0].copy()
        rng.shuffle(idx)
        p = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
        for o, ch in enumerate(np.split(idx, cuts)):
            parts[o].extend(ch.tolist())
    return [np.sort(np.asarray(p, np.int64)) for p in parts]


def partition_class_imbalanced(ds: SyntheticImageDataset, num_clients: int,
                               rare_classes=(0, 1, 2), rare_ratio: float = 0.4,
                               seed: int = 0) -> List[np.ndarray]:
    """Paper §6.7: rare classes keep only ``rare_ratio`` of their samples
    globally; clients then get 3 random classes each (like Non-IID-b)."""
    rng = np.random.default_rng(seed)
    keep = []
    for cls in range(ds.num_classes):
        idx = np.where(ds.y == cls)[0]
        if cls in rare_classes:
            idx = rng.choice(idx, size=int(len(idx) * rare_ratio),
                             replace=False)
        keep.extend(idx.tolist())
    keep = np.sort(np.asarray(keep))
    sub = ds.subset(keep)
    parts_local = partition_noniid_b(sub, num_clients, seed + 1)
    return [keep[p] for p in parts_local]


def label_distribution(ds: SyntheticImageDataset, idx: np.ndarray
                       ) -> np.ndarray:
    """dis_n^c — proportion of each label in a client's shard."""
    counts = np.bincount(ds.y[idx], minlength=ds.num_classes).astype(float)
    return counts / max(counts.sum(), 1.0)


def label_coverage_score(ds: SyntheticImageDataset, idx: np.ndarray
                         ) -> float:
    """sum_c min(C * dis_n^c, 1) — the Eq. (13) data-distribution term.

    Clients report this single scalar (privacy-mild, per paper §4.1)."""
    c = ds.num_classes
    dis = label_distribution(ds, idx)
    return float(np.sum(np.minimum(c * dis, 1.0)))
