"""Population-scale serving: cohort sampling, availability churn, and
sticky client state for populations far larger than any round's fleet.

FedDD's dropout-rate LP was pitched against partial client selection on
fleets where every client is live.  A production FL service instead
samples a small cohort per round from a mostly-offline population
(Caldas et al., 1812.07210).  This package splits the two notions:

* :mod:`repro.population.store` — :class:`Population`: per-client
  sticky state in O(1)-per-client host arrays (economy, losses, dropout
  rates, Oort utilities, params of past participants);
* :mod:`repro.population.availability` — who is online each epoch
  (always-on, Bernoulli, diurnal with per-client phase, trace-driven),
  keyed with the fault layer's ``(seed, tag, epoch, client)`` RNG
  discipline but vectorized for 100k+ populations;
* :mod:`repro.population.sampler` — cohort samplers over the online set
  (identity, uniform, availability-weighted, Oort top-k + exploration)
  returning exactly ``cohort_size`` ids so engine shapes never wobble.

Entry point: ``run_sim(..., population=Population(tel, ...),
cohort_size=K)`` (see :mod:`repro.sim.runner`).  Contract: a population
whose size equals the fleet, with always-on availability and the
default sampler, is bit-identical to today's fleet runs on every engine
path.
"""

from repro.population.availability import (AlwaysOn,  # noqa: F401
                                           AvailabilityModel,
                                           BernoulliAvailability,
                                           DiurnalAvailability,
                                           TraceAvailability,
                                           make_availability,
                                           uniform_draws)
from repro.population.sampler import (AvailabilityWeightedSampler,  # noqa: F401
                                      CohortSampler, IdentitySampler,
                                      OortSampler, UniformSampler,
                                      make_sampler)
from repro.population.store import Population  # noqa: F401
