"""Cohort samplers: draw the round's fleet from the online population.

Each sampler maps ``(epoch, k, online ids, store)`` to EXACTLY ``k``
sorted global client ids.  The fixed cohort size is load-bearing: the
stacked/grouped engines jit-compile against the cohort's leading axis,
so a wobbling ``k`` would force a recompile every time availability
churned.  When fewer than ``k`` clients are online, the shortfall is
topped up deterministically from the offline set (most recently
participating first, then lowest id) — the sim treats them as reachable
but slow to respond rather than shrinking the round.

Randomized samplers use the same vectorized ``(seed, tag, epoch,
client)`` keyed uniforms as the availability layer
(:func:`repro.population.availability.uniform_draws`), so cohorts are
call-order independent and cross-process identical.

* :class:`IdentitySampler` — the whole population, in id order, every
  round (``static``: engines never rebind); with always-on availability
  this is the bit-identity contract's configuration;
* :class:`UniformSampler` — uniform without replacement over the online
  set (k smallest keyed uniforms);
* :class:`AvailabilityWeightedSampler` — Efraimidis–Spirakis weighted
  reservoir over the online set, weight ``1 / (1 + rounds_participated)``
  — rarely-served clients are favored when they do come online;
* :class:`OortSampler` — top-``(1 - explore) * k`` by the store's sticky
  Oort utility among seen online clients, the rest exploration slots
  for never-seen clients (Lai et al., Oort).
"""

from __future__ import annotations

import numpy as np

from repro.population.availability import _TAG_SAMPLE, uniform_draws


def _top_up(chosen: np.ndarray, k: int, online_ids: np.ndarray,
            store) -> np.ndarray:
    """Fill ``chosen`` up to exactly ``k`` ids, deterministically.

    Preference order for the fill: remaining ONLINE clients first (by
    id), then offline clients by most recent participation
    (``last_round`` descending, id ascending).  Pure function of the
    store's sticky state — no RNG.
    """
    chosen = np.asarray(chosen, dtype=np.int64)
    if len(chosen) >= k:
        return np.sort(chosen[:k])
    need = k - len(chosen)
    taken = np.zeros(store.size, dtype=bool)
    taken[chosen] = True
    spare_online = online_ids[~taken[online_ids]]
    fill = spare_online[:need]
    chosen = np.concatenate([chosen, fill])
    taken[fill] = True
    need = k - len(chosen)
    if need > 0:
        rest = np.flatnonzero(~taken)
        order = np.lexsort((rest, -store.last_round[rest]))
        chosen = np.concatenate([chosen, rest[order[:need]]])
    return np.sort(chosen)


class CohortSampler:
    """Base: ``sample(epoch, k, online_ids, store)`` -> k sorted ids."""

    #: True when the cohort is the same every round (engines keep their
    #: buffers bound for the whole run) — required by the mesh path.
    static = False

    def sample(self, epoch: int, k: int, online_ids: np.ndarray,
               store) -> np.ndarray:
        raise NotImplementedError


class IdentitySampler(CohortSampler):
    """The full population, in id order, every round."""

    static = True

    def sample(self, epoch: int, k: int, online_ids: np.ndarray,
               store) -> np.ndarray:
        if k != store.size:
            raise ValueError(
                f"identity sampler needs cohort_size == population size "
                f"({store.size}), got {k}")
        return np.arange(store.size, dtype=np.int64)


class UniformSampler(CohortSampler):
    """Uniform without replacement over the online set."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def sample(self, epoch: int, k: int, online_ids: np.ndarray,
               store) -> np.ndarray:
        if len(online_ids) == 0:
            return _top_up(np.empty(0, np.int64), k, online_ids, store)
        u = uniform_draws(self.seed, _TAG_SAMPLE, epoch, online_ids)
        take = min(k, len(online_ids))
        pick = online_ids[np.argsort(u, kind="stable")[:take]]
        return _top_up(pick, k, online_ids, store)


class AvailabilityWeightedSampler(CohortSampler):
    """Efraimidis–Spirakis weighted sampling without replacement over
    the online set; weight ``1 / (1 + rounds_participated)`` steers
    rounds toward clients the service has rarely reached."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def sample(self, epoch: int, k: int, online_ids: np.ndarray,
               store) -> np.ndarray:
        if len(online_ids) == 0:
            return _top_up(np.empty(0, np.int64), k, online_ids, store)
        u = uniform_draws(self.seed, _TAG_SAMPLE, epoch, online_ids)
        w = 1.0 / (1.0 + store.rounds_participated[online_ids])
        # E-S key: u^(1/w); log-space for numerical sanity
        key = np.log(np.maximum(u, 1e-300)) / w
        take = min(k, len(online_ids))
        pick = online_ids[np.argsort(-key, kind="stable")[:take]]
        return _top_up(pick, k, online_ids, store)


class OortSampler(CohortSampler):
    """Utility top-k with an exploration budget: the exploit slots take
    the highest sticky Oort utility among SEEN online clients, the
    explore slots take never-seen online clients (keyed-uniform order)."""

    def __init__(self, explore: float = 0.1, seed: int = 0):
        if not 0.0 <= explore <= 1.0:
            raise ValueError(f"explore must be in [0, 1], got {explore}")
        self.explore = float(explore)
        self.seed = int(seed)

    def sample(self, epoch: int, k: int, online_ids: np.ndarray,
               store) -> np.ndarray:
        if len(online_ids) == 0:
            return _top_up(np.empty(0, np.int64), k, online_ids, store)
        seen = store.seen[online_ids]
        k_explore = int(round(self.explore * k))
        u = uniform_draws(self.seed, _TAG_SAMPLE, epoch, online_ids)
        unseen_ids = online_ids[~seen]
        explore_pick = unseen_ids[np.argsort(u[~seen], kind="stable")
                                  [:min(k_explore, len(unseen_ids))]]
        k_exploit = k - len(explore_pick)
        seen_ids = online_ids[seen]
        util = store.utility[seen_ids]
        # tie-break by id: lexsort minor key first
        order = np.lexsort((seen_ids, -util))
        exploit_pick = seen_ids[order[:min(k_exploit, len(seen_ids))]]
        pick = np.concatenate([exploit_pick, explore_pick])
        return _top_up(pick, k, online_ids, store)


def make_sampler(name, *, seed: int = 0, **kw) -> CohortSampler:
    """Factory: ``identity`` | ``uniform`` | ``weighted`` | ``oort``
    (or pass a :class:`CohortSampler` through unchanged)."""
    if isinstance(name, CohortSampler):
        return name
    if name == "identity":
        return IdentitySampler()
    if name == "uniform":
        return UniformSampler(seed=seed, **kw)
    if name == "weighted":
        return AvailabilityWeightedSampler(seed=seed, **kw)
    if name == "oort":
        return OortSampler(seed=seed, **kw)
    raise ValueError(f"unknown cohort sampler {name!r} "
                     "(expected identity|uniform|weighted|oort)")
