"""Availability processes: who, out of a 100k+ client population, is
online at each epoch.

A production FL service never sees its whole population at once — most
devices are offline, charging, or on a metered link (Caldas et al.,
1812.07210).  The models here decide the ONLINE SET each epoch; the
cohort sampler (repro.population.sampler) then draws the round's fleet
from that set.

RNG discipline
--------------
Draws follow the same ``(seed, tag, epoch, client)`` keying contract as
``repro/sim/faults.py``: every per-client uniform is a pure function of
that tuple, so draws are call-order independent, prefix/permutation
invariant, and identical across processes.  The fault layer realises the
contract with one ``np.random.default_rng((seed, tag, epoch, i))`` per
client — fine for fleets of tens, but a Python-level generator per
client is O(population) interpreter work per epoch.  Availability must
answer "who is online" over the FULL population every epoch, so here the
same keyed-tuple semantics are realised with a vectorized counter-based
hash (splitmix64's finalizer) over ``np.uint64`` lanes: one fused numpy
expression yields all N uniforms at once.  Distinct ``tag`` bytes keep
these streams out of the fault layer's (0xFA) and corruption (0xC0)
domains.

Models
------
* :class:`AlwaysOn` — everyone online every epoch (the identity-contract
  default: population == fleet degenerates to today's runs);
* :class:`BernoulliAvailability` — i.i.d. online with probability ``p``
  per (epoch, client);
* :class:`DiurnalAvailability` — deterministic sine on/off with a
  per-client phase (drawn once at epoch 0), modelling timezone-staggered
  charging windows; ``duty`` sets the online fraction of each period;
* :class:`TraceAvailability` — replay a ``(T, N)`` boolean trace,
  row ``epoch % T``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

# Domain tags for the (seed, tag, epoch, client) keying — disjoint from
# the fault layer's _TAG_FAULTS (0xFA) / _TAG_CORRUPT (0xC0).
_TAG_AVAIL = 0xA1      # per-(epoch, client) availability uniforms
_TAG_PHASE = 0xA2      # per-client diurnal phase (epoch pinned to 0)
_TAG_SAMPLE = 0xA3     # per-(epoch, client) cohort-sampling uniforms

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)     # splitmix64 increment
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over uint64 lanes (vectorized)."""
    x = (x ^ (x >> np.uint64(30))) * _MIX1
    x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


def uniform_draws(seed: int, tag: int, epoch: int,
                  clients: np.ndarray) -> np.ndarray:
    """Uniform(0, 1) per client, a pure function of
    ``(seed, tag, epoch, client)``.

    ``clients`` is an integer array of GLOBAL client ids; the result has
    the same shape.  Restricting or permuting ``clients`` never changes
    any individual client's draw (the per-client key is independent of
    the others) — the property the determinism tests pin.
    """
    c = np.asarray(clients, dtype=np.uint64)
    with np.errstate(over="ignore"):
        h = np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
        h = _mix64(np.asarray(h ^ (np.uint64(tag) * _GOLDEN)))
        h = _mix64(h ^ (np.uint64(epoch & 0xFFFFFFFFFFFFFFFF) * _GOLDEN))
        u = _mix64(_mix64(h ^ (c * _GOLDEN)))
    # 53-bit mantissa route: exact doubles in [0, 1)
    return (u >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


class AvailabilityModel:
    """Base: ``online(epoch)`` returns a boolean mask over the population
    (or, with ``clients=``, the draws restricted to those ids)."""

    size: int

    def online(self, epoch: int,
               clients: Optional[np.ndarray] = None) -> np.ndarray:
        raise NotImplementedError

    def _ids(self, clients: Optional[np.ndarray]) -> np.ndarray:
        if clients is None:
            return np.arange(self.size, dtype=np.int64)
        return np.asarray(clients, dtype=np.int64)


class AlwaysOn(AvailabilityModel):
    """Everyone online every epoch — population degenerates to fleet."""

    def __init__(self, size: int):
        self.size = int(size)

    def online(self, epoch: int,
               clients: Optional[np.ndarray] = None) -> np.ndarray:
        return np.ones(len(self._ids(clients)), dtype=bool)


class BernoulliAvailability(AvailabilityModel):
    """i.i.d. online with probability ``p`` per (epoch, client)."""

    def __init__(self, size: int, p: float = 0.7, seed: int = 0):
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"availability p must be in [0, 1], got {p}")
        self.size = int(size)
        self.p = float(p)
        self.seed = int(seed)

    def online(self, epoch: int,
               clients: Optional[np.ndarray] = None) -> np.ndarray:
        ids = self._ids(clients)
        return uniform_draws(self.seed, _TAG_AVAIL, epoch, ids) < self.p


class DiurnalAvailability(AvailabilityModel):
    """Sine on/off with a per-client phase: client ``i`` is online iff

        sin(2*pi*(epoch / period + phase_i)) >= sin(pi*(0.5 - duty))

    so a ``duty`` fraction of each ``period`` is spent online, and the
    phases (one keyed draw per client, epoch pinned to 0) stagger the
    fleet across "timezones".  Fully deterministic given (seed, epoch).
    """

    def __init__(self, size: int, period: float = 24.0, duty: float = 0.5,
                 seed: int = 0):
        if period <= 0.0:
            raise ValueError(f"period must be positive, got {period}")
        if not 0.0 < duty <= 1.0:
            raise ValueError(f"duty must be in (0, 1], got {duty}")
        self.size = int(size)
        self.period = float(period)
        self.duty = float(duty)
        self.seed = int(seed)
        self._threshold = float(np.sin(np.pi * (0.5 - self.duty)))

    def _phase(self, ids: np.ndarray) -> np.ndarray:
        return uniform_draws(self.seed, _TAG_PHASE, 0, ids)

    def online(self, epoch: int,
               clients: Optional[np.ndarray] = None) -> np.ndarray:
        ids = self._ids(clients)
        wave = np.sin(2.0 * np.pi * (epoch / self.period
                                     + self._phase(ids)))
        return wave >= self._threshold


class TraceAvailability(AvailabilityModel):
    """Replay a ``(T, N)`` boolean availability trace, row ``epoch % T``."""

    def __init__(self, trace: Sequence[Sequence[bool]]):
        tr = np.asarray(trace, dtype=bool)
        if tr.ndim != 2 or tr.shape[0] < 1:
            raise ValueError("trace must be a (T, N) boolean array")
        self.trace = tr
        self.size = int(tr.shape[1])

    def online(self, epoch: int,
               clients: Optional[np.ndarray] = None) -> np.ndarray:
        row = self.trace[int(epoch) % self.trace.shape[0]]
        return row[self._ids(clients)]


def make_availability(name, size: int, *, seed: int = 0,
                      **kw) -> AvailabilityModel:
    """Factory: ``always`` | ``bernoulli`` | ``diurnal`` | ``trace``
    (or pass an :class:`AvailabilityModel` through unchanged)."""
    if isinstance(name, AvailabilityModel):
        if name.size != size:
            raise ValueError(
                f"availability model covers {name.size} clients, "
                f"population has {size}")
        return name
    if name == "always":
        return AlwaysOn(size)
    if name == "bernoulli":
        return BernoulliAvailability(size, seed=seed, **kw)
    if name == "diurnal":
        return DiurnalAvailability(size, seed=seed, **kw)
    if name == "trace":
        return TraceAvailability(**kw)
    raise ValueError(f"unknown availability model {name!r} "
                     "(expected always|bernoulli|diurnal|trace)")
