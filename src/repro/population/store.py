"""The :class:`Population`: sticky per-client state for populations far
larger than any round's fleet.

A population is the service's durable view of every registered client —
most of whom are offline at any moment and many of whom have never been
served.  State lives in flat host numpy arrays indexed by GLOBAL client
id, O(1) per client, so a 100k-client population costs a few MB and a
handful of O(population) passes at construction only.  On the round hot
path the work is confined to the sampled cohort: the availability mask
and sampler are the single O(population) vectorized step, and every
read-modify-write after that touches ``cohort_size`` rows.

Sticky state per client:

* economy — cumulative uploaded bytes, failure count, rounds
  participated, last participation round;
* learning — last observed train loss (runner prior: 1.0), last
  FedDD dropout rate (Algorithm 1 prior: 0.0), sticky Oort utility
  (prior: ``num_samples * sqrt(max(train_loss, 0))``), and, for clients
  whose local model has diverged from the global, their parameter
  pytree (bounded by the number of DISTINCT participants, not the
  population);
* ``seen`` — whether the client has ever been materialized into a
  cohort; first-contact clients can fall back to population-mean
  telemetry in the Eq. (9)-(11) LP (``cold_start="mean"``) instead of
  their individual prior (``cold_start="prior"``, the default — and the
  bit-identity-preserving choice).

The telemetry EWMAs themselves live in the runner's
:class:`repro.sim.runner.ObservedTelemetry`, which in population mode is
sized to the population and indexed by global id, so estimates survive
cohort churn without aliasing between clients.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.allocation import ClientTelemetry
from repro.population.availability import (AvailabilityModel,
                                           make_availability)
from repro.population.sampler import CohortSampler, make_sampler

# prior-telemetry fields a cold-start "mean" LP solve replaces for
# never-seen cohort members (model_bytes is structural, never averaged)
_MEAN_FIELDS = ("uplink_rate", "downlink_rate", "compute_latency",
                "num_samples", "label_coverage")


class Population:
    """Sticky per-client state + availability + cohort sampling.

    ``telemetry`` is the population-sized prior :class:`ClientTelemetry`
    (what the service knows about each client before ever serving it).
    ``availability`` and ``sampler`` accept the factory names of
    :func:`repro.population.availability.make_availability` /
    :func:`repro.population.sampler.make_sampler` or model instances.
    """

    def __init__(self, telemetry: ClientTelemetry, *,
                 availability="always", sampler="uniform",
                 cold_start: str = "prior", seed: int = 0):
        if cold_start not in ("prior", "mean"):
            raise ValueError(
                f"cold_start must be 'prior' or 'mean', got {cold_start!r}")
        self.telemetry = telemetry
        self.size = int(len(np.asarray(telemetry.num_samples)))
        if self.size < 1:
            raise ValueError("population telemetry is empty")
        self.availability: AvailabilityModel = make_availability(
            availability, self.size, seed=seed)
        self.sampler: CohortSampler = make_sampler(sampler, seed=seed)
        self.cold_start = cold_start
        self.seed = int(seed)

        n = self.size
        self.seen = np.zeros(n, dtype=bool)
        self.last_round = np.full(n, -1, dtype=np.int64)
        self.rounds_participated = np.zeros(n, dtype=np.int64)
        self.uploaded_bytes = np.zeros(n, dtype=np.float64)
        self.failures = np.zeros(n, dtype=np.int64)
        self.loss = np.ones(n, dtype=np.float64)          # runner prior
        self.dropout = np.zeros(n, dtype=np.float64)      # Algorithm 1 D=0
        self.utility = (np.asarray(telemetry.num_samples, float)
                        * np.sqrt(np.maximum(
                            np.asarray(telemetry.train_loss, float), 0.0)))
        self._params: Dict[int, object] = {}
        self._means: Optional[Dict[str, float]] = None

    # -- cohort selection (THE per-round O(population) step) ---------------

    def sample_cohort(self, epoch: int, k: int) -> np.ndarray:
        """Sorted global ids of this epoch's cohort (exactly ``k``)."""
        if not 1 <= k <= self.size:
            raise ValueError(
                f"cohort size {k} outside [1, {self.size}]")
        online = self.availability.online(epoch)
        online_ids = np.flatnonzero(online).astype(np.int64)
        ids = np.asarray(
            self.sampler.sample(epoch, k, online_ids, self),
            dtype=np.int64)
        if len(ids) != k:
            raise ValueError(
                f"sampler returned {len(ids)} ids, expected {k}")
        return ids

    def first_contact(self, ids: np.ndarray) -> int:
        """How many of ``ids`` have never been in a cohort before."""
        return int(np.count_nonzero(~self.seen[np.asarray(ids)]))

    # -- cohort materialization -------------------------------------------

    def cohort_params(self, ids: np.ndarray, global_params):
        """Per-client parameter pytrees for the cohort: each client's
        sticky params if it has diverged from the global, else the
        current global model (first contact downloads the global)."""
        return [self._params.get(int(g), global_params) for g in ids]

    def cohort_dropout(self, ids: np.ndarray) -> np.ndarray:
        return self.dropout[np.asarray(ids)].copy()

    def losses_for(self, ids: np.ndarray) -> np.ndarray:
        return self.loss[np.asarray(ids)].copy()

    def seed_params(self, params_list) -> None:
        """Install explicit per-client initial params (len == size)."""
        if len(params_list) != self.size:
            raise ValueError(
                f"expected {self.size} client param trees, "
                f"got {len(params_list)}")
        for g, p in enumerate(params_list):
            self._params[g] = p

    # -- post-round write-back (O(cohort)) ---------------------------------

    def record_round(self, epoch: int, ids: np.ndarray, *,
                     arrived: np.ndarray, failed: np.ndarray,
                     losses: np.ndarray, uplink_bytes: np.ndarray,
                     utilities: Optional[np.ndarray] = None) -> None:
        """Fold one round's observations back into the sticky arrays.

        All cohort-shaped: ``arrived`` (contributed to Eq. (4)),
        ``failed`` (crashed/aborted), ``losses`` (the runner's running
        loss view), ``uplink_bytes`` (bytes actually charged to the
        wire, 0 for non-contributors), ``utilities`` (fresh Oort
        utilities; only arrived rows are folded in).
        """
        ids = np.asarray(ids)
        arrived = np.asarray(arrived, bool)
        self.seen[ids] = True
        hit = ids[arrived]
        self.last_round[hit] = int(epoch)
        self.rounds_participated[hit] += 1
        self.uploaded_bytes[ids] += np.asarray(uplink_bytes, float)
        self.failures[ids[np.asarray(failed, bool)]] += 1
        self.loss[ids] = np.asarray(losses, float)
        if utilities is not None:
            u = np.asarray(utilities, float)
            ok = arrived & np.isfinite(u)
            self.utility[ids[ok]] = u[ok]

    def fold_back(self, ids: np.ndarray, params_list, *,
                  dropout: np.ndarray, losses: np.ndarray) -> None:
        """Park the outgoing cohort's learning state before rebinding
        the engines to a new cohort."""
        ids = np.asarray(ids)
        self.dropout[ids] = np.asarray(dropout, float)
        self.loss[ids] = np.asarray(losses, float)
        for g, p in zip(ids, params_list):
            self._params[int(g)] = p

    # -- allocation integration --------------------------------------------

    def _prior_means(self) -> Dict[str, float]:
        if self._means is None:
            self._means = {
                f: float(np.mean(np.asarray(getattr(self.telemetry, f),
                                            float)))
                for f in _MEAN_FIELDS}
        return self._means

    def lp_telemetry(self, tel: ClientTelemetry,
                     ids: np.ndarray) -> ClientTelemetry:
        """Cold-start view of the cohort telemetry for the Eq. (9)-(11)
        solve: under ``cold_start="mean"``, never-seen cohort members
        take population-mean prior telemetry (and the mean of the seen
        members' losses) instead of their individual rows.  Under the
        default ``"prior"`` the telemetry passes through untouched —
        the identity-contract configuration."""
        if self.cold_start == "prior":
            return tel
        unseen = ~self.seen[np.asarray(ids)]
        if not unseen.any():
            return tel
        m = self._prior_means()
        repl = {}
        for f in _MEAN_FIELDS:
            arr = np.asarray(getattr(tel, f), float).copy()
            arr[unseen] = m[f]
            repl[f] = arr
        tl = np.asarray(tel.train_loss, float).copy()
        if (~unseen).any():
            tl[unseen] = float(np.mean(tl[~unseen]))
        repl["train_loss"] = tl
        return dataclasses.replace(tel, **repl)
