"""Microbenchmarks for the three Pallas kernels (interpret mode on CPU:
numbers are correctness-path timings, not TPU perf — TPU perf comes from
the dry-run roofline)."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))   # compile/warm, fully retired
    t0 = time.perf_counter()
    for _ in range(iters):
        # block every iteration: async dispatch would otherwise overlap the
        # timed region and hide nearly all device work.
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(full: bool = False, out_dir=None):
    from repro.kernels.importance import ops as imp_ops
    from repro.kernels.masked_merge import ops as mm_ops
    from repro.kernels.sparse_agg import ops as agg_ops

    key = jax.random.PRNGKey(0)
    c, f = (1024, 4096) if full else (256, 512)
    n = 8
    wo = jax.random.normal(key, (c, f), jnp.float32)
    wn = wo * 1.01
    rows = []
    t = _time(imp_ops.channel_importance, wo, wn)
    rows.append(csv_row("kernel_importance", t, f"shape={c}x{f}"))

    sw = jax.random.normal(key, (n, c, f))
    sm = (jax.random.uniform(key, (n, c, 1)) > 0.5).astype(jnp.float32)
    wts = jnp.ones(n)
    t = _time(agg_ops.masked_weighted_sum, sw, sm, wts)
    rows.append(csv_row("kernel_sparse_agg", t, f"shape={n}x{c}x{f}"))

    m = (jax.random.uniform(key, (c,)) > 0.5).astype(jnp.float32)
    # mask is per-channel; (c, f) tensors here are channel-major (axis 0)
    t = _time(lambda a, b, mm: mm_ops.masked_merge(a, b, mm, channel_axis=0),
              wo, wn, m)
    rows.append(csv_row("kernel_masked_merge", t, f"shape={c}x{f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full):
        print(r)


if __name__ == "__main__":
    main()
