"""Paper Fig. 21: generalisation on class-imbalanced data.

Rare classes (0,1,2) hold 40% of a common class's samples; A_server=20%.
Headline: client-selection baselines score ~0 on rare classes; FedDD keeps
them close to FedAvg."""

from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from benchmarks.common import csv_row, run_experiment, timed, write_json

SCHEMES = ("feddd", "fedavg", "fedcs", "oort")
RARE = (0, 1, 2)


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 20 if full else 8
    clients = 20 if full else 10
    rows, results = [], {}
    for scheme in SCHEMES:
        res, wall = timed(lambda: run_experiment(
            "mnist", "imbalanced", scheme, rounds=rounds,
            num_clients=clients, a_server=0.2, per_class_eval=True))
        m = res.history[-1].metrics
        rare_acc = float(np.mean([m[f"acc_class_{c}"] for c in RARE]))
        common_acc = float(np.mean(
            [m[f"acc_class_{c}"] for c in range(10) if c not in RARE]))
        results[scheme] = {"rare": rare_acc, "common": common_acc,
                           "per_class": {k: v for k, v in m.items()
                                         if k.startswith("acc_class")}}
        rows.append(csv_row(f"fig21_{scheme}", wall,
                            f"rare_acc={rare_acc:.4f};"
                            f"common_acc={common_acc:.4f}"))
    if out_dir:
        write_json(out_dir, "class_imbalance.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
