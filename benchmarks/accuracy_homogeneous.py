"""Paper Figs. 4-6: final test accuracy, model-homogeneous setting.

Grid: {mnist, fmnist, cifar10} x {iid, noniid_a, noniid_b} x
{feddd, fedavg, fedcs, oort}.  Headline (paper §6.3): under Non-IID-b the
client-selection baselines lose accuracy vs FedDD; under IID everyone ties.
"""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import csv_row, run_experiment, timed, write_json

SCHEMES = ("feddd", "fedavg", "fedcs", "oort")


def run(full: bool = False, out_dir: Path | None = None):
    datasets = ("mnist", "fmnist", "cifar10") if full else ("mnist",)
    partitions = ("iid", "noniid_a", "noniid_b") if full else ("noniid_b",)
    rounds = 20 if full else 6
    clients = 20 if full else 8
    rows = []
    results = {}
    for ds in datasets:
        for part in partitions:
            for scheme in SCHEMES:
                res, wall = timed(lambda: run_experiment(
                    ds, part, scheme, rounds=rounds, num_clients=clients))
                accs = [r.metrics["accuracy"] for r in res.history]
                results[f"{ds}/{part}/{scheme}"] = accs
                rows.append(csv_row(
                    f"fig4-6_{ds}_{part}_{scheme}", wall,
                    f"final_acc={accs[-1]:.4f}"))
    if out_dir:
        write_json(out_dir, "accuracy_homogeneous.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
