"""Loop-vs-engine A/B for the FedDD round engine (rounds/sec).

Runs the same homogeneous FedDD simulation four ways and reports
rounds/sec + the speedup over the per-client loop:

  loop     — ProtocolConfig(batched=False): the original Python loop over
             clients (per-client build_masks dispatches, per-leaf float()
             host syncs, list-based aggregation);
  batched  — ProtocolConfig(batched=True): per-client Python training, but
             the whole server side of the round is ONE jitted device step
             (core/round_engine.py);
  fused    — batched_train_fn: local training vmapped over clients too, so
             the entire round is device-resident and the only host traffic
             is the per-round (losses, densities) telemetry struct;
  scanned  — rounds_per_dispatch=K: the round LOOP fuses too — K rounds
             (training, masks, Eq. (4)-(6), the Eq. (9)-(11) re-allocation
             and the Eq. (12) clock) run as ONE lax.scan dispatch with a
             single stacked-telemetry transfer per chunk.

All modes run ``allocator="jax"`` so results are bit-comparable across the
whole axis (the scanned path requires the traceable allocator; the
sequential paths accept either — tests/test_allocation.py pins the
numpy/jax parity).  All four produce bit-identical global parameters for a
fixed seed (also asserted by tests/test_round_engine.py); the A/B prints
the max deviation.

    PYTHONPATH=src python benchmarks/perf_federated.py \
        [--clients 64] [--rounds 5] [--rounds-per-dispatch 8] [--use-kernel]

``--smoke`` is the CI parity gate: tiny grid (8 clients, 2 rounds, K=2),
no perf thresholds, non-zero exit unless the scanned digests (params +
history) exactly match sequential dispatch.  ``run()`` (the
benchmarks/run.py entry) writes ``results/perf_federated.csv``;
``bench_json()`` writes the machine-readable rounds/sec trajectory
``results/BENCH_round_engine.json`` (16/64 clients) that CI uploads so
future PRs can track engine regressions.
"""

from __future__ import annotations

import argparse
import hashlib
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import csv_row, write_json, write_table  # noqa: E402
from repro.core import FedDDServer, ProtocolConfig  # noqa: E402
from repro.core.round_engine import make_batched_train_fn  # noqa: E402
from repro.core.selection import SelectionConfig  # noqa: E402
from repro.fl import (init_cnn_spec, model_bytes,  # noqa: E402
                      sample_system_telemetry)
from repro.fl.models import apply_spec  # noqa: E402

SPEC = [("fc", 64, 128), ("fc", 128, 64), ("fc", 64, 10)]
MODES = ("loop", "batched", "fused", "scanned")


def make_setup(num_clients: int, shard: int, seed: int = 0):
    """Homogeneous clients with equal-size synthetic shards (stackable)."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(num_clients, shard, 64)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, size=(num_clients, shard)))
    params = init_cnn_spec(jax.random.PRNGKey(seed), SPEC)
    tel = sample_system_telemetry(
        num_clients, [model_bytes(params)] * num_clients,
        [shard] * num_clients, [1.0] * num_clients, seed=seed)

    def _loss(p, x, y):
        logits = apply_spec(p, SPEC, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def _sgd_step(p, x, y):
        loss, g = jax.value_and_grad(_loss)(p, x, y)
        return jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw, p, g), loss

    step = jax.jit(_sgd_step)

    def local_train(p, idx, rng_):
        del rng_
        return step(p, xs[idx], ys[idx])

    batched_train = jax.jit(make_batched_train_fn(_sgd_step, (xs, ys)))
    return params, tel, local_train, batched_train


def run_mode(mode: str, params, tel, local_train, batched_train, *,
             rounds: int, use_kernel: bool, seed: int = 0,
             rounds_per_dispatch: int = 8):
    cfg = ProtocolConfig(
        scheme="feddd", rounds=rounds, a_server=0.6, h=5, seed=seed,
        batched=(mode != "loop"), allocator="jax",
        mesh=(True if mode == "sharded" else None),
        rounds_per_dispatch=(rounds_per_dispatch if mode == "scanned"
                             else 1),
        selection=SelectionConfig(use_kernel=use_kernel))
    server = FedDDServer(params, cfg, tel)
    t0 = time.perf_counter()
    if mode in ("fused", "scanned", "sharded"):
        res = server.run(batched_train_fn=batched_train)
    else:
        res = server.run(local_train)
    jax.block_until_ready(jax.tree_util.tree_leaves(res.global_params))
    return res, time.perf_counter() - t0


def run_ab(clients: int, rounds: int, *, use_kernel: bool = False,
           rounds_per_dispatch: int = 8, modes=MODES, seed: int = 0):
    """Time every mode (warm-up run first so compiles — including both
    scan chunk lengths — land outside the timed region).  Returns
    ``(rows, results)`` with ``results[mode] = (RunResult, wall, rps)``.

    ``rounds_per_dispatch`` is clamped to the EFFECTIVE chunk length
    ``min(K, rounds)`` so rows/JSON never label a configuration that was
    not actually executed (the protocol clamps trailing chunks the same
    way); K < 2 is rejected — rounds_per_dispatch=1 is per-round
    dispatch, which is the ``fused`` mode, not ``scanned``.
    """
    rounds_per_dispatch = min(rounds_per_dispatch, rounds)
    if "scanned" in modes and rounds_per_dispatch < 2:
        raise ValueError(
            "scanned mode needs an effective rounds_per_dispatch >= 2 "
            "(K=1 IS the per-round fused path)")
    setup = make_setup(clients, 32, seed=seed)
    kw = dict(rounds=rounds, use_kernel=use_kernel, seed=seed,
              rounds_per_dispatch=rounds_per_dispatch)
    results = {}
    for mode in modes:
        run_mode(mode, *setup, **kw)                       # warm-up
        res, wall = run_mode(mode, *setup, **kw)
        results[mode] = (res, wall, rounds / wall)

    base_mode = "loop" if "loop" in results else modes[0]
    base = results[base_mode][2]
    g_base = jax.tree_util.tree_leaves(results[base_mode][0].global_params)
    rows = []
    for mode, (res, wall, rps) in results.items():
        dev = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            g_base, jax.tree_util.tree_leaves(res.global_params)))
        extra = (f" rounds_per_dispatch={rounds_per_dispatch}"
                 if mode == "scanned" else "")
        rows.append(csv_row(
            f"fed_round_{mode}", wall / rounds,
            f"rounds_per_sec={rps:.2f} speedup_vs_{base_mode}="
            f"{rps / base:.2f}x max_dev_vs_{base_mode}={dev:.1e} "
            f"clients={clients}{extra}"))
    return rows, results


def _digest(res) -> str:
    """Bit-level digest of a run's LEARNING state: global params + the
    per-round losses / upload fractions / participation.

    The dropout rates are deliberately excluded: XLA compiles the
    Eq. (9)-(11) golden-section search per program, and even fenced with
    optimization_barrier the search's last float32 bit is context
    sensitive for some loss inputs (sequential dispatch vs scan-inlined
    are different XLA programs).  The learning state must match exactly;
    the rates are asserted to within one f32 ulp separately.
    """
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(res.global_params):
        h.update(np.asarray(leaf).tobytes())
    for r in res.history:
        h.update(np.asarray(
            [r.mean_loss, r.uploaded_fraction,
             float(r.participants)]).tobytes())
    return h.hexdigest()


def smoke(clients: int = 8, rounds: int = 2, rounds_per_dispatch: int = 2
          ) -> int:
    """CI gate: scanned dispatch must reproduce sequential dispatch —
    learning-state digests exactly, allocator outputs within 2 float32
    ulps at unit scale on the [0, 1] dropout domain.  No perf
    thresholds."""
    setup = make_setup(clients, 32)
    kw = dict(rounds=rounds, use_kernel=False,
              rounds_per_dispatch=rounds_per_dispatch)
    seq, _ = run_mode("fused", *setup, **kw)
    scan, _ = run_mode("scanned", *setup, **kw)
    d_seq, d_scan = _digest(seq), _digest(scan)
    print(f"sequential digest: {d_seq}")
    print(f"scanned    digest: {d_scan}")
    if d_seq != d_scan:
        print("# FAIL: scanned dispatch diverged from sequential "
              "(params/losses/participation)", file=sys.stderr)
        return 1
    # The search's context sensitivity is an ABSOLUTE perturbation (one
    # ulp of the t_star bracket propagated through the knapsack), so the
    # gate is absolute on the [0, 1] dropout domain: 2 ulps at unit scale.
    unit_ulp = float(np.spacing(np.float32(1.0)))          # 1.19e-07
    rate_dev = max(float(np.max(np.abs(a.dropout_rates - b.dropout_rates)))
                   for a, b in zip(seq.history, scan.history))
    time_dev = max(abs(a.sim_time - b.sim_time) / max(a.sim_time, 1e-9)
                   for a, b in zip(seq.history, scan.history))
    print(f"# allocator max dev: rates={rate_dev / unit_ulp:.1f} f32 ulps "
          f"at unit scale ({rate_dev:.2e}), Eq.(12) rel dev={time_dev:.2e}")
    if rate_dev > 2 * unit_ulp or time_dev > 1e-6:
        print("# FAIL: allocator drifted beyond 2 unit-scale f32 ulps",
              file=sys.stderr)
        return 1
    print(f"# OK: rounds_per_dispatch={rounds_per_dispatch} matches "
          f"per-round dispatch ({clients} clients, {rounds} rounds)")
    return 0


def sharded_ab(clients_list=(256, 1024), rounds: int = 6) -> dict:
    """Sharded-vs-fused scaling curve on the VISIBLE device mesh.

    Runs the same homogeneous FedDD simulation as the per-round ``fused``
    mode and the client-sharded ``sharded`` mode (ProtocolConfig mesh=True
    -> ShardedRoundEngine over every visible device) and reports
    rounds/sec, the sharded speedup, and the scaling efficiency
    (speedup / devices).  Meant to run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU (or on
    a real accelerator mesh); on a 1-device process the sharded mode
    degenerates to shard_map overhead measurement (~2%).

    ``physical_parallelism`` records whether the host can actually run the
    shard programs concurrently (cpu_count >= devices on the CPU backend);
    the acceptance gate only binds where it is true — an 8-way virtual
    mesh round-robining on one core measures dispatch serialization, not
    the engine's scaling.
    """
    import os
    devices = jax.device_count()
    cpus = os.cpu_count() or 1
    physical = (jax.default_backend() != "cpu") or cpus >= devices
    out = {
        "devices": devices,
        "cpu_count": cpus,
        "physical_parallelism": bool(physical),
        "clients": {},
    }
    for c in clients_list:
        setup = make_setup(c, 8)
        kw = dict(rounds=rounds, use_kernel=False, rounds_per_dispatch=8)
        per = {}
        for mode in ("fused", "sharded"):
            run_mode(mode, *setup, **{**kw, "rounds": 2})       # warm-up
            _, wall = run_mode(mode, *setup, **kw)
            per[mode] = rounds / wall
        speedup = per["sharded"] / per["fused"]
        out["clients"][str(c)] = {
            "fused_rounds_per_sec": per["fused"],
            "sharded_rounds_per_sec": per["sharded"],
            "sharded_speedup": speedup,
            "scaling_efficiency": speedup / max(devices, 1),
        }
    return out


def _sharded_subprocess(clients_list, rounds: int, devices: int = 8):
    """Collect the sharded scaling curve in a child process with
    ``devices`` virtual CPU devices (XLA fixes the device count at
    import, so the parent cannot re-mesh itself)."""
    import json
    import os
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    code = (
        "import json\n"
        "from benchmarks.perf_federated import sharded_ab\n"
        f"print(json.dumps(sharded_ab({tuple(clients_list)!r}, "
        f"rounds={rounds})))\n"
    )
    root = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = f"{root}/src:{root}"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, cwd=root,
                         check=True)
    return json.loads(proc.stdout.splitlines()[-1])


def bench_json(out_dir: Path, *, clients=(16, 64), rounds: int = 6,
               rounds_per_dispatch: int = 8,
               sharded_clients=(256, 1024), mesh_devices: int = 8) -> Path:
    """Machine-readable perf trajectory: rounds/sec per execution path at
    each fleet size -> results/BENCH_round_engine.json (CI artifact, the
    regression baseline future PRs compare against).  The ``sharded``
    section is the client-sharded scaling curve, collected in a child
    process carrying an ``mesh_devices``-way virtual CPU mesh."""
    rounds_per_dispatch = min(rounds_per_dispatch, rounds)  # effective K
    payload = {
        "bench": "round_engine",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "rounds": rounds,
        "rounds_per_dispatch": rounds_per_dispatch,
        "clients": {},
    }
    for c in clients:
        _, results = run_ab(c, rounds,
                            rounds_per_dispatch=rounds_per_dispatch)
        payload["clients"][str(c)] = {
            mode: {"rounds_per_sec": rps,
                   "sec_per_round": wall / rounds}
            for mode, (_, wall, rps) in results.items()
        }
    payload["sharded"] = _sharded_subprocess(sharded_clients, rounds,
                                             devices=mesh_devices)
    biggest = str(max(clients))
    per = payload["clients"][biggest]
    speedup = (per["scanned"]["rounds_per_sec"]
               / per["batched"]["rounds_per_sec"])
    scan_ge_fused = all(
        modes["scanned"]["rounds_per_sec"] >= modes["fused"]["rounds_per_sec"]
        for modes in payload["clients"].values())
    sh = payload["sharded"]
    sh_big = sh["clients"][str(max(sharded_clients))]
    sharded_ok = (sh_big["sharded_speedup"] >= 3.0
                  if sh["physical_parallelism"] else None)
    payload["acceptance"] = {
        "scanned_vs_batched_at_max_clients": speedup,
        "target": 1.5,
        "scanned_ge_fused_at_every_client_count": bool(scan_ge_fused),
        "sharded_speedup_at_max_clients": sh_big["sharded_speedup"],
        "sharded_target": 3.0,
        "sharded_gate_binding": sh["physical_parallelism"],
        "sharded_pass": sharded_ok,
        "pass": bool(speedup >= 1.5 and scan_ge_fused
                     and (sharded_ok is not False)),
    }
    return write_json(out_dir, "BENCH_round_engine.json", payload)


def _write_csv(out_dir: Path, rows) -> None:
    write_table(out_dir, "perf_federated.csv",
                ["name,us_per_round,derived"] + list(rows))


def run(full: bool = False, out_dir: Path | None = None):
    """benchmarks/run.py entry: reduced A/B over the rounds-per-dispatch
    axis, written to results/perf_federated.csv."""
    clients = 64 if full else 8
    rounds = 10 if full else 4
    k = 8 if full else 2
    rows, _ = run_ab(clients, rounds, rounds_per_dispatch=k)
    if out_dir:
        _write_csv(out_dir, rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--shard", type=int, default=32)
    ap.add_argument("--rounds-per-dispatch", type=int, default=8,
                    help="chunk length K of the scanned mode (lax.scan "
                         "over K rounds per device dispatch)")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity gate: 8 clients, 2 rounds, K=2; "
                         "asserts scanned == sequential digests")
    ap.add_argument("--json", action="store_true",
                    help="write results/BENCH_round_engine.json "
                         "(rounds/sec per path at 16/64 clients + the "
                         "sharded scaling curve on an 8-way virtual mesh)")
    ap.add_argument("--sharded", action="store_true",
                    help="print the sharded-vs-fused scaling curve on the "
                         "VISIBLE devices (run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(smoke())
    if args.sharded:
        import json as _json
        print(_json.dumps(sharded_ab((args.clients,), rounds=args.rounds),
                          indent=1))
        return
    out_dir = Path(__file__).resolve().parents[1] / "results"
    if args.json:
        out = bench_json(out_dir)
        print(out.read_text())
        return

    rows, results = run_ab(args.clients, args.rounds,
                           use_kernel=args.use_kernel,
                           rounds_per_dispatch=args.rounds_per_dispatch)
    for r in rows:
        print(r)
    _write_csv(out_dir, rows)
    base = results["loop"][2]
    speedup = results["batched"][2] / base
    scan_gain = results["scanned"][2] / results["batched"][2]
    k_eff = min(args.rounds_per_dispatch, args.rounds)
    print(f"# batched engine speedup at {args.clients} clients: "
          f"{speedup:.2f}x (target >= 3x)")
    print(f"# scanned (K={k_eff}) vs per-round engine: "
          f"{scan_gain:.2f}x (target >= 1.5x)")
    failed = False
    if speedup < 3.0:
        print("# FAIL: batched below the 3x acceptance target",
              file=sys.stderr)
        failed = True
    if scan_gain < 1.5:
        print("# FAIL: scanned below the 1.5x acceptance target",
              file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
