"""Loop-vs-batched A/B for the FedDD round engine (rounds/sec).

Runs the same homogeneous FedDD simulation three ways and reports
rounds/sec + the speedup over the per-client loop:

  loop     — ProtocolConfig(batched=False): the original Python loop over
             clients (per-client build_masks dispatches, per-leaf float()
             host syncs, list-based aggregation);
  batched  — ProtocolConfig(batched=True): per-client Python training, but
             the whole server side of the round is ONE jitted device step
             (core/round_engine.py);
  fused    — batched_train_fn: local training vmapped over clients too, so
             the entire round is device-resident and the only host traffic
             is the (losses, densities) telemetry struct.

All three produce bit-identical global parameters for a fixed seed (also
asserted by tests/test_round_engine.py); the A/B prints the max deviation.

    PYTHONPATH=src python benchmarks/perf_federated.py \
        [--clients 64] [--rounds 5] [--use-kernel]
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import csv_row  # noqa: E402
from repro.core import FedDDServer, ProtocolConfig  # noqa: E402
from repro.core.round_engine import make_batched_train_fn  # noqa: E402
from repro.core.selection import SelectionConfig  # noqa: E402
from repro.fl import (init_cnn_spec, model_bytes,  # noqa: E402
                      sample_system_telemetry)
from repro.fl.models import apply_spec  # noqa: E402

SPEC = [("fc", 64, 128), ("fc", 128, 64), ("fc", 64, 10)]


def make_setup(num_clients: int, shard: int, seed: int = 0):
    """Homogeneous clients with equal-size synthetic shards (stackable)."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(num_clients, shard, 64)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, size=(num_clients, shard)))
    params = init_cnn_spec(jax.random.PRNGKey(seed), SPEC)
    tel = sample_system_telemetry(
        num_clients, [model_bytes(params)] * num_clients,
        [shard] * num_clients, [1.0] * num_clients, seed=seed)

    def _loss(p, x, y):
        logits = apply_spec(p, SPEC, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def _sgd_step(p, x, y):
        loss, g = jax.value_and_grad(_loss)(p, x, y)
        return jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw, p, g), loss

    step = jax.jit(_sgd_step)

    def local_train(p, idx, rng_):
        del rng_
        return step(p, xs[idx], ys[idx])

    batched_train = jax.jit(make_batched_train_fn(_sgd_step, (xs, ys)))
    return params, tel, local_train, batched_train


def run_mode(mode: str, params, tel, local_train, batched_train, *,
             rounds: int, use_kernel: bool, seed: int = 0):
    cfg = ProtocolConfig(
        scheme="feddd", rounds=rounds, a_server=0.6, h=5, seed=seed,
        batched=(mode != "loop"),
        selection=SelectionConfig(use_kernel=use_kernel))
    server = FedDDServer(params, cfg, tel)
    t0 = time.perf_counter()
    if mode == "fused":
        res = server.run(batched_train_fn=batched_train)
    else:
        res = server.run(local_train)
    jax.block_until_ready(jax.tree_util.tree_leaves(res.global_params))
    return res, time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--shard", type=int, default=32)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()

    setup = make_setup(args.clients, args.shard)
    results = {}
    for mode in ("loop", "batched", "fused"):
        # warm-up over a full h=5 cycle compiles BOTH round variants
        # (sparse + dense-broadcast) outside the timed region
        run_mode(mode, *setup, rounds=5, use_kernel=args.use_kernel)
        res, wall = run_mode(mode, *setup, rounds=args.rounds,
                             use_kernel=args.use_kernel)
        results[mode] = (res, wall, args.rounds / wall)

    base = results["loop"][2]
    g_loop = jax.tree_util.tree_leaves(results["loop"][0].global_params)
    for mode, (res, wall, rps) in results.items():
        dev = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            g_loop, jax.tree_util.tree_leaves(res.global_params)))
        print(csv_row(
            f"fed_round_{mode}", wall / args.rounds,
            f"rounds_per_sec={rps:.2f} speedup_vs_loop={rps / base:.2f}x "
            f"max_dev_vs_loop={dev:.1e} clients={args.clients}"))
    speedup = results["batched"][2] / base
    print(f"# batched engine speedup at {args.clients} clients: "
          f"{speedup:.2f}x (target >= 3x)")
    if speedup < 3.0:
        print("# FAIL: below the 3x acceptance target", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
