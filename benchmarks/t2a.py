"""Paper Fig. 7 (and Fig. 10): time-to-target-accuracy, normalised to
FedAvg = 1.  Headline claim: FedDD reduces training time >75% vs FedAvg."""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import csv_row, run_experiment, timed, write_json

SCHEMES = ("fedavg", "feddd", "fedcs", "oort")


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 25 if full else 10
    clients = 20 if full else 10
    targets = (0.80, 0.90, 0.95)
    rows = []
    results = {}
    histories = {}
    for scheme in SCHEMES:
        res, wall = timed(lambda: run_experiment(
            "mnist", "noniid_b", scheme, rounds=rounds,
            num_clients=clients))
        histories[scheme] = res
        rows.append(csv_row(f"fig7_run_{scheme}", wall,
                            f"rounds={rounds}"))
    for tgt in targets:
        base = histories["fedavg"].time_to_accuracy(tgt)
        for scheme in SCHEMES:
            t = histories[scheme].time_to_accuracy(tgt)
            norm = (t / base) if (t is not None and base) else None
            results[f"t2a@{tgt}/{scheme}"] = norm
            rows.append(csv_row(
                f"fig7_t2a{int(tgt * 100)}_{scheme}", 0.0,
                f"normalized_t2a={'fail' if norm is None else f'{norm:.3f}'}"))
    if out_dir:
        write_json(out_dir, "t2a.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
