"""Accuracy vs ON-WIRE bytes: the wire-format grid (scheme x codec x qbits).

The core protocol's byte axis was analytic (``density x model_bytes``);
the repro.comm subsystem charges what a sparse upload actually costs —
the kept values at the codec's precision PLUS the encoding of WHICH
parameters survived.  This grid asks the Caldas-et-al question: where on
the accuracy-per-byte frontier does each (mask codec, value precision)
combination land, and where does the bitmask/index crossover sit on a
real model?

Grid (reduced mode):
  scheme   feddd (sparse uploads) + a fedavg full-upload reference
  codec    dense (the analytic idealization) | bitmask | index | auto
  qbits    32 | 8 (int8 stochastic rounding)

Output columns: final accuracy, CUMULATIVE on-wire MB vs raw
(idealized) MB, overhead fraction, and simulated time — accuracy per
wire-byte is the headline.  A second CSV section sweeps the analytic
byte model over density to report each leaf census's measured
bitmask/index crossover (~density 1/8).

Writes ``wire_formats.csv`` to the results dir; CI uploads it as a
build artifact.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np  # noqa: E402

from benchmarks.common import (csv_row, run_experiment,  # noqa: E402
                               timed, write_table)
from repro.comm.payload import (CommConfig, WireSpec,  # noqa: E402
                                analytic_wire_bytes)

CODECS = ("dense", "bitmask", "index", "auto")


def _crossover_rows(spec: WireSpec):
    """Density where index coding stops beating the packed bitmask."""
    dens = np.linspace(0.005, 0.995, 199)
    ix = np.asarray([float(analytic_wire_bytes(
        spec, 1.0 - d, CommConfig(codec="index"))) for d in dens])
    bm = np.asarray([float(analytic_wire_bytes(
        spec, 1.0 - d, CommConfig(codec="bitmask"))) for d in dens])
    worse = np.flatnonzero(ix > bm)
    cross = float(dens[worse[0]]) if worse.size else float("nan")
    return cross


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 16 if full else 6
    clients = 16 if full else 8
    qbits_grid = (32, 16, 8) if full else (32, 8)
    rows = []
    table = ["scheme,codec,qbits,final_acc,wire_mb,raw_mb,overhead_frac,"
             "sim_s"]
    cells = [("fedavg", "dense", 32)]
    cells += [("feddd", c, q) for c in CODECS for q in qbits_grid]
    for scheme, codec, qbits in cells:
        comm = CommConfig(codec=codec, qbits=qbits)
        res, wall = timed(lambda: run_experiment(
            "mnist", "noniid_b", scheme, num_clients=clients,
            rounds=rounds, num_train=2000, num_test=500, seed=0,
            comm=comm))
        final = res.history[-1]
        acc = (final.metrics or {}).get("accuracy", float("nan"))
        wire = sum(r.wire_bytes for r in res.history)
        raw = sum(r.uploaded_bytes for r in res.history)
        over = (wire - raw * qbits / 32.0) / max(wire, 1e-9)
        name = f"wire_{scheme}_{codec}_q{qbits}"
        rows.append(csv_row(
            name, wall,
            f"acc={acc:.3f};wire_mb={wire / 1e6:.3f};"
            f"overhead={over:.1%}"))
        table.append(f"{scheme},{codec},{qbits},{acc:.4f},"
                     f"{wire / 1e6:.4f},{raw / 1e6:.4f},{over:.4f},"
                     f"{final.sim_time:.1f}")
    # analytic crossover of the benchmark model's leaf census
    from repro.fl import MLP_SPEC, init_cnn_spec  # noqa: E402
    import jax  # noqa: E402

    spec = WireSpec.from_params(init_cnn_spec(jax.random.PRNGKey(0),
                                              MLP_SPEC))
    cross = _crossover_rows(spec)
    table.append(f"crossover,index>bitmask,-,-,-,-,-,{cross:.4f}")
    rows.append(csv_row("wire_crossover_density", 0.0,
                        f"density={cross:.4f}"))
    if out_dir:
        write_table(out_dir, "wire_formats.csv", table)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    for r in run(full=args.full, out_dir=out_dir):
        print(r)
    print((out_dir / "wire_formats.csv").read_text())


if __name__ == "__main__":
    main()
