"""Shared FL experiment harness for the paper-figure benchmarks.

Each benchmark module reproduces one paper table/figure on the synthetic
datasets (DESIGN.md §8).  ``run_experiment`` wires dataset + partition +
scheme and returns the round history; ``run_sim_experiment`` routes the
same setup through the event-driven simulator (repro/sim) with a chosen
aggregation policy and network model; ``csv_row`` prints the harness's
``name,us_per_call,derived`` convention (derived = the figure's headline
quantity).

Two time axes appear in results — never mix them:

* ``RoundRecord.sim_time`` / ``sim_round_time`` — SIMULATED seconds on the
  paper's Eq. (12) clock (what the modelled clients would take).  All
  time-to-accuracy figures are on this axis.
* ``RoundRecord.host_wall_time`` (and the ``us_per_call`` column emitted
  by :func:`csv_row` via :func:`timed`) — REAL host seconds this
  implementation spent computing; a throughput measure only.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.obs import MetricsRegistry  # noqa: E402

from repro.core import run_scheme  # noqa: E402
from repro.core.selection import SelectionConfig  # noqa: E402
from repro.data import (label_coverage_score, make_dataset,  # noqa: E402
                        partition_class_imbalanced, partition_iid,
                        partition_noniid_a, partition_noniid_b)
from repro.fl import (CNN1_SPEC, CNN2_SPEC, MLP_SPEC,  # noqa: E402
                      HETERO_A_SPECS, HETERO_B_SPECS, init_cnn_spec,
                      make_eval_fn, make_local_train_fn, model_bytes,
                      sample_system_telemetry)

PARTITIONS = {
    "iid": partition_iid,
    "noniid_a": partition_noniid_a,
    "noniid_b": partition_noniid_b,
    "imbalanced": partition_class_imbalanced,
}

DATASET_MODEL = {
    "mnist": (MLP_SPEC, True, 0.1),      # (spec, flatten, lr)
    "fmnist": (CNN1_SPEC, False, 0.05),
    "cifar10": (CNN2_SPEC, False, 0.05),
}


def setup_experiment(
    dataset: str = "mnist",
    partition: str = "noniid_b",
    *,
    num_clients: int = 10,
    num_train: int = 4000,
    num_test: int = 1000,
    hetero_specs: Optional[List] = None,
    per_class_eval: bool = False,
    seed: int = 0,
):
    """Dataset + partition + model + telemetry plumbing shared by the
    protocol-driver and sim-driver entry points.

    Returns ``(global_params, telemetry, local_train_fn, eval_fn,
    client_params)`` (client_params is None for homogeneous runs).
    """
    train, test = make_dataset(dataset, num_train=num_train,
                               num_test=num_test, seed=seed)
    parts = PARTITIONS[partition](train, num_clients, seed=seed)
    if hetero_specs is not None:
        specs = [hetero_specs[i % len(hetero_specs)]
                 for i in range(num_clients)]
        clients = [init_cnn_spec(jax.random.PRNGKey(100 + i), s)
                   for i, s in enumerate(specs)]
        global_params = init_cnn_spec(jax.random.PRNGKey(0), hetero_specs[0])
        lr = 0.05
        fns = [make_local_train_fn(specs[i], train, parts, lr=lr)
               for i in range(num_clients)]

        def ltf(params, idx, rng):
            return fns[idx](params, idx, rng)

        ef = make_eval_fn(hetero_specs[0], test, per_class=per_class_eval)
        mbytes = [model_bytes(p) for p in clients]
    else:
        spec, flatten, lr = DATASET_MODEL[dataset]
        clients = None
        global_params = init_cnn_spec(jax.random.PRNGKey(0), spec)
        ltf = make_local_train_fn(spec, train, parts, flatten=flatten, lr=lr)
        ef = make_eval_fn(spec, test, flatten=flatten,
                          per_class=per_class_eval)
        mbytes = [model_bytes(global_params)] * num_clients
    tel = sample_system_telemetry(
        num_clients, mbytes, [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=seed)
    return global_params, tel, ltf, ef, clients


def run_experiment(
    dataset: str = "mnist",
    partition: str = "noniid_b",
    scheme: str = "feddd",
    *,
    num_clients: int = 10,
    rounds: int = 10,
    num_train: int = 4000,
    num_test: int = 1000,
    a_server: float = 0.6,
    d_max: float = 0.8,
    delta: float = 1.0,
    h: int = 5,
    selection_scheme: str = "feddd",
    hetero_specs: Optional[List] = None,
    per_class_eval: bool = False,
    seed: int = 0,
    batched: bool = True,
    comm=None,
):
    global_params, tel, ltf, ef, clients = setup_experiment(
        dataset, partition, num_clients=num_clients, num_train=num_train,
        num_test=num_test, hetero_specs=hetero_specs,
        per_class_eval=per_class_eval, seed=seed)
    extra = {} if comm is None else {"comm": comm}
    return run_scheme(scheme, global_params, tel, ltf, ef,
                      client_params=clients, rounds=rounds,
                      a_server=a_server, d_max=d_max, delta=delta, h=h,
                      selection=SelectionConfig(scheme=selection_scheme),
                      seed=seed, batched=batched, **extra)


def run_sim_experiment(
    dataset: str = "mnist",
    partition: str = "noniid_b",
    scheme: str = "feddd",
    *,
    policy: str = "sync",
    network: str = "static",
    num_clients: int = 10,
    rounds: int = 10,
    num_train: int = 4000,
    num_test: int = 1000,
    a_server: float = 0.6,
    d_max: float = 0.8,
    delta: float = 1.0,
    h: int = 5,
    seed: int = 0,
    network_kw: Optional[Dict] = None,
    policy_kw: Optional[Dict] = None,
    eval_every: int = 1,
    hetero_specs: Optional[List] = None,
    faults=None,
    robust_agg: str = "mean",
):
    """The same experiment, time axis owned by the event-driven simulator
    (repro/sim): ``policy`` in {sync, deadline, retry, async}, ``network``
    in {static, markov, straggler} (see repro.sim.network for trace-driven
    models).  ``hetero_specs`` builds a ragged-width fleet — the sim
    drives the shape-grouped engine, so stragglers x ragged models
    compose.  ``faults`` attaches a :class:`repro.sim.faults.FaultModel`
    (churn / lossy uplinks / corruption / quorum degradation)."""
    from repro.sim import SimConfig, make_network, run_sim

    global_params, tel, ltf, ef, clients = setup_experiment(
        dataset, partition, num_clients=num_clients, num_train=num_train,
        num_test=num_test, hetero_specs=hetero_specs, seed=seed)
    net = make_network(network, tel, seed=seed, **(network_kw or {}))
    sim = SimConfig(policy=policy, policy_kw=policy_kw or {},
                    eval_every=eval_every)
    return run_sim(scheme, global_params, tel, ltf, ef, sim=sim,
                   network=net, client_params=clients, rounds=rounds,
                   a_server=a_server, d_max=d_max, delta=delta, h=h,
                   seed=seed, faults=faults, robust_agg=robust_agg)


# One registry per benchmark process: every csv_row feeds it, and
# ``benchmarks/run.py`` exports the whole sweep as Prometheus text
# (results/benchmarks.prom) after the module loop.
REGISTRY = MetricsRegistry()


def csv_row(name: str, wall_s: float, derived: str) -> str:
    """``us_per_call`` is HOST time (from :func:`timed`) — simulated-clock
    quantities belong in the ``derived`` column."""
    REGISTRY.set("benchmark_us_per_call", wall_s * 1e6, name=name)
    return f"{name},{wall_s * 1e6:.0f},{derived}"


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


# -- artifact writers (shared by all benchmarks/*.py modules) -------------

def write_json(out_dir: Path, filename: str, payload) -> Path:
    """Write a JSON artifact under ``out_dir`` (mkdir'd), newline-terminated."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / filename
    path.write_text(json.dumps(payload, indent=1) + "\n")
    return path


def write_table(out_dir: Path, filename: str, lines: List[str]) -> Path:
    """Write a line-oriented artifact (CSV/markdown table) under ``out_dir``."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / filename
    path.write_text("\n".join(lines) + "\n")
    return path


def export_registry(out_dir: Path, filename: str = "benchmarks.prom") -> Path:
    """Dump the process-wide :data:`REGISTRY` as Prometheus text."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / filename
    path.write_text(REGISTRY.prometheus_text())
    return path
