"""Population-scale serving: time-to-accuracy over cohort size x
availability, plus the 100k-population throughput demonstration.

Every other benchmark serves the WHOLE fleet each round.  This grid runs
the production shape instead (repro/population): a large, mostly-offline
population served ``cohort_size`` clients at a time, asking

* what partial service costs in time-to-accuracy — smaller cohorts move
  fewer bytes per round but need more rounds, and availability churn
  (Bernoulli vs diurnal phase-staggered) decides who CAN be served when
  the sampler wants them;
* what population scale costs in host throughput — the acceptance
  criterion: a 100,000-client population served 256 at a time must run
  at the same order of rounds/sec as today's 256-client full fleet
  (the O(population) work per round is one vectorized availability +
  sampling pass; everything else touches only the cohort).

Client data is sharded by GLOBAL client id (``id % shards``), so a
client keeps its shard no matter which cohort it lands in — the
population runner hands train fns global ids for exactly this reason.

Writes ``population_scale.csv`` to the results dir; CI uploads it as a
build artifact (the ``population`` lane runs ``--smoke``).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np  # noqa: E402

from benchmarks.common import (csv_row, setup_experiment,  # noqa: E402
                               timed, write_table)
from repro.core.allocation import ClientTelemetry  # noqa: E402
from repro.population import Population, make_availability  # noqa: E402
from repro.sim import SimConfig, run_sim  # noqa: E402

TARGET_ACC = 0.70
THROUGHPUT_POP = 100_000
THROUGHPUT_COHORT = 256


def _fmt(x) -> str:
    return "fail" if x is None else f"{x:.1f}"


def _tile_tel(tel: ClientTelemetry, n: int) -> ClientTelemetry:
    """Population-sized telemetry from a data-shard-sized sample: tile
    the per-shard rows so client ``g`` shares shard ``g % shards``'s
    system profile (keeps telemetry consistent with the data mapping)."""
    return ClientTelemetry(**{
        f: np.resize(np.asarray(getattr(tel, f)), n)
        for f in ("model_bytes", "uplink_rate", "downlink_rate",
                  "compute_latency", "num_samples", "label_coverage",
                  "train_loss")})


def _shard_ltf(ltf, shards: int):
    def wrapped(p, gid, key):
        return ltf(p, int(gid) % shards, key)
    return wrapped


def run(full: bool = False, out_dir: Path | None = None,
        smoke: bool = False):
    if smoke:
        population, rounds, shards = 48, 4, 12
        cohorts = (8, 16)
        grids = (("always", {}), ("bernoulli", {"p": 0.6}))
        thr_rounds = 2
    elif full:
        population, rounds, shards = 512, 16, 32
        cohorts = (16, 64, 256)
        grids = (("always", {}), ("bernoulli", {"p": 0.6}),
                 ("diurnal", {"duty": 0.5}))
        thr_rounds = 4
    else:
        population, rounds, shards = 64, 8, 16
        cohorts = (8, 16, 32)
        grids = (("always", {}), ("bernoulli", {"p": 0.6}),
                 ("diurnal", {"duty": 0.5}))
        thr_rounds = 3
    num_train = 4000 if full else 1500
    num_test = 1000 if full else 400

    # one dataset + model for the whole grid: `shards` data partitions,
    # telemetry tiled to the population
    gp, shard_tel, ltf, ef, _ = setup_experiment(
        "mnist", "noniid_b", num_clients=shards, num_train=num_train,
        num_test=num_test, seed=0)
    pop_tel = _tile_tel(shard_tel, population)
    pop_ltf = _shard_ltf(ltf, shards)

    rows = []
    table = ["kind,availability,population,cohort,rounds,t2a_sim_s,"
             "final_acc,final_sim_s,distinct_served,first_contact_total,"
             "rounds_per_sec"]

    def t2a_run(avail_name, avail_kw, k):
        pop = Population(
            pop_tel,
            availability=make_availability(avail_name, population,
                                           seed=7, **avail_kw),
            sampler="uniform", seed=7)
        res, wall = timed(lambda: run_sim(
            "feddd", gp, pop_tel, pop_ltf, ef,
            population=pop, cohort_size=k,
            sim=SimConfig(policy="sync", eval_every=1),
            rounds=rounds, a_server=0.6, h=3, seed=0))
        t2a = res.time_to_accuracy(TARGET_ACC)
        final = res.history[-1]
        acc = (final.metrics or {}).get("accuracy", float("nan"))
        served = int(pop.seen.sum())
        rps = rounds / wall if wall > 0 else float("inf")
        name = f"pop_{avail_name}_P{population}_K{k}"
        rows.append(csv_row(
            name, wall,
            f"t2a{int(TARGET_ACC * 100)}={_fmt(t2a)};"
            f"final_acc={acc:.3f};served={served}"))
        table.append(
            f"t2a,{avail_name},{population},{k},{rounds},{_fmt(t2a)},"
            f"{acc:.4f},{final.sim_time:.1f},{served},{served},"
            f"{rps:.3f}")

    for avail_name, avail_kw in grids:
        for k in cohorts:
            t2a_run(avail_name, avail_kw, k)

    # --- throughput: 100k population / 256 cohort vs 256 full fleet ------
    def thr_run(kind, n_pop, k):
        tel = _tile_tel(shard_tel, n_pop)
        kw = dict(sim=SimConfig(policy="sync"),
                  rounds=thr_rounds, a_server=0.6, h=3, seed=0)
        if kind == "fleet":
            res, wall = timed(lambda: run_sim(
                "feddd", gp, tel, _shard_ltf(ltf, shards), None, **kw))
            served = n_pop
        else:
            pop = Population(tel, availability="bernoulli",
                             sampler="uniform", seed=7)
            res, wall = timed(lambda: run_sim(
                "feddd", gp, tel, _shard_ltf(ltf, shards), None,
                population=pop, cohort_size=k, **kw))
            served = int(pop.seen.sum())
        rps = thr_rounds / wall if wall > 0 else float("inf")
        final = res.history[-1]
        avail = "always" if kind == "fleet" else "bernoulli"
        rows.append(csv_row(f"pop_throughput_{kind}_N{n_pop}_K{k}", wall,
                            f"rounds_per_sec={rps:.3f}"))
        table.append(
            f"throughput_{kind},{avail},{n_pop},{k},{thr_rounds},,"
            f",{final.sim_time:.1f},{served},{served},{rps:.3f}")
        return rps

    base_rps = thr_run("fleet", THROUGHPUT_COHORT, THROUGHPUT_COHORT)
    pop_rps = thr_run("population", THROUGHPUT_POP, THROUGHPUT_COHORT)
    # the acceptance check: same ORDER of rounds/sec (>= 0.1x the fleet)
    rows.append(csv_row(
        "pop_throughput_ratio", 0.0,
        f"pop/fleet={pop_rps / base_rps:.3f};pass="
        f"{pop_rps >= 0.1 * base_rps}"))

    if out_dir:
        write_table(out_dir, "population_scale.csv", table)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (512 population, 16 rounds)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (bounded minutes, incl. the "
                         "100k-population throughput demo)")
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    for r in run(full=args.full, out_dir=out_dir, smoke=args.smoke):
        print(r)
    print((out_dir / "population_scale.csv").read_text())


if __name__ == "__main__":
    main()
