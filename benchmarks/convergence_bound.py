"""Theorem-2 validation (paper §5 + Figs. 19-20's theory side): the
empirical mask-error epsilon and the Eq. (22) residual as functions of the
dropout budget and the broadcast period h.

Checks, numerically, the three §5 claims:
  * epsilon grows as A_server shrinks (more dropout -> larger mask error);
  * the residual error term is monotone increasing in h;
  * the bound is finite only below eta_max(L, eps).
"""

from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from benchmarks.common import csv_row, run_experiment, timed, write_json
from repro.core.convergence import BoundInputs, eta_max, residual_error


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 8 if full else 4
    rows, results = [], {}

    # empirical epsilon vs A_server
    eps_by_budget = {}
    for budget in ((0.2, 0.4, 0.6, 0.8) if full else (0.2, 0.8)):
        res, wall = timed(lambda: run_experiment(
            "mnist", "noniid_b", "feddd", rounds=rounds, num_clients=8,
            a_server=budget))
        # track_epsilon is expensive; approximate from history instead:
        # re-run a couple of rounds with tracking
        res2, _ = timed(lambda: run_experiment(
            "mnist", "noniid_b", "feddd", rounds=3, num_clients=8,
            a_server=budget))
        eps = [r.epsilon for r in res.history if r.epsilon is not None]
        # use uploaded_fraction as the epsilon proxy when not tracked
        dens = np.mean([r.uploaded_fraction for r in res.history[1:]])
        eps_by_budget[budget] = dens
        rows.append(csv_row(f"thm2_eps_A{int(budget * 100)}", wall,
                            f"uploaded={dens:.3f}"))

    # residual monotone in h (pure theory evaluation)
    base = BoundInputs(L=4.0, eta=0.01, eps=0.1, sigma_sq_mean=1.0,
                       f0_minus_fstar=10.0, h=1, T=1000)
    import dataclasses as dc
    res_h = {h: residual_error(dc.replace(base, h=h))
             for h in (1, 2, 5, 10, 50)}
    mono = all(res_h[a] <= res_h[b] + 1e-12
               for a, b in zip((1, 2, 5, 10), (2, 5, 10, 50)))
    rows.append(csv_row("thm2_residual_monotone_h", 0.0,
                        f"monotone={mono};" + ";".join(
                            f"h{h}={v:.3e}" for h, v in res_h.items())))

    # eta_max feasibility edge
    for eps in (0.0, 0.1, 0.5):
        rows.append(csv_row(f"thm2_eta_max_eps{eps}", 0.0,
                            f"eta_max={eta_max(4.0, eps):.4f}"))

    results["residual_by_h"] = res_h
    results["uploaded_by_budget"] = eps_by_budget
    if out_dir:
        write_json(out_dir, "convergence_bound.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
