"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default mode runs reduced-size
versions of every experiment (bounded CPU time); run the individual modules
with ``--full`` for the paper-scale grids.

``--json`` runs ONLY the round-engine perf A/B (loop / batched / fused /
scanned at 16 and 64 clients) and writes the machine-readable trajectory
``results/BENCH_round_engine.json`` — the regression baseline CI uploads
so future PRs can track engine rounds/sec.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (accuracy_homogeneous, class_imbalance,  # noqa: E402
                        convergence_bound, fault_tolerance, heterogeneous,
                        kernels_bench, perf_federated, population_scale,
                        roofline, selection_variants, sensitivity,
                        straggler_policies, t2a, wire_formats)

MODULES = [
    ("fig4-6 accuracy (model-homogeneous)", accuracy_homogeneous),
    ("fig7 time-to-accuracy", t2a),
    ("fig9-10 model-heterogeneous", heterogeneous),
    ("fig11-15 selection variants", selection_variants),
    ("fig16-20 sensitivity", sensitivity),
    ("fig21 class imbalance", class_imbalance),
    ("thm2 convergence bound", convergence_bound),
    ("straggler policies (event-driven sim)", straggler_policies),
    ("fault tolerance (t2a vs fault rate)", fault_tolerance),
    ("population scale (cohort x availability)", population_scale),
    ("wire formats (accuracy vs on-wire bytes)", wire_formats),
    ("round-engine perf (loop/batched/fused/scanned)", perf_federated),
    ("pallas kernels", kernels_bench),
    ("dry-run roofline", roofline),
]


def check_bench(out_dir: Path) -> None:
    """Fail LOUDLY if the committed round-engine baseline is absent or
    malformed — the CI regression gate calls this so a silently-missing
    ``results/BENCH_round_engine.json`` can't pass as green."""
    import json

    path = out_dir / "BENCH_round_engine.json"
    if not path.exists():
        print(f"# FAIL: {path} is missing — regenerate with "
              "`python benchmarks/run.py --json` and commit it",
              file=sys.stderr)
        sys.exit(1)
    try:
        payload = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        print(f"# FAIL: {path} is not valid JSON: {e}", file=sys.stderr)
        sys.exit(1)
    missing = [k for k in ("clients", "acceptance") if k not in payload]
    if missing or not payload.get("clients"):
        print(f"# FAIL: {path} lacks required keys {missing or ['clients']}",
              file=sys.stderr)
        sys.exit(1)
    # scanned must beat (or match) the per-round fused path at EVERY
    # recorded client count — the committed baseline may not regress the
    # multi-round scan anywhere on the curve
    for c, modes in sorted(payload["clients"].items(), key=lambda kv:
                           int(kv[0])):
        if "scanned" not in modes or "fused" not in modes:
            continue
        s = modes["scanned"]["rounds_per_sec"]
        f = modes["fused"]["rounds_per_sec"]
        if s < f:
            print(f"# FAIL: scanned ({s:.2f} r/s) below fused ({f:.2f} "
                  f"r/s) at {c} clients — the committed baseline must "
                  "have scanned >= fused at every client count",
                  file=sys.stderr)
            sys.exit(1)
    # sharded scaling gate: binds only where the recording host could run
    # the shard programs concurrently (acceptance.sharded_gate_binding)
    acc = payload["acceptance"]
    if acc.get("sharded_gate_binding") and acc.get("sharded_pass") is False:
        print(f"# FAIL: sharded speedup "
              f"{acc.get('sharded_speedup_at_max_clients'):.2f}x below "
              f"the {acc.get('sharded_target')}x target on parallel "
              "hardware", file=sys.stderr)
        sys.exit(1)
    if not acc.get("pass"):
        print(f"# FAIL: committed baseline records a failing acceptance "
              f"({acc})", file=sys.stderr)
        sys.exit(1)
    sh = payload.get("sharded", {})
    print(f"# OK: {path} present "
          f"(clients={sorted(payload['clients'])}, "
          f"sharded_devices={sh.get('devices')}, "
          f"acceptance_pass={acc.get('pass')})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="only run the round-engine A/B and write "
                         "results/BENCH_round_engine.json")
    ap.add_argument("--check-bench", action="store_true",
                    help="verify results/BENCH_round_engine.json exists "
                         "and is well-formed; exit non-zero otherwise")
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    out_dir.mkdir(exist_ok=True)
    if args.check_bench:
        check_bench(out_dir)
        return
    if args.json:
        import json

        out = perf_federated.bench_json(out_dir)
        payload = json.loads(out.read_text())
        print(json.dumps(payload, indent=1))
        if not payload["acceptance"]["pass"]:
            print("# FAIL: scanned engine below the acceptance target "
                  f"({payload['acceptance']})", file=sys.stderr)
            sys.exit(1)
        return
    print("name,us_per_call,derived")
    for title, mod in MODULES:
        print(f"# --- {title} ---", flush=True)
        try:
            for row in mod.run(full=False, out_dir=out_dir):
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}",
                  flush=True)
    from benchmarks.common import export_registry

    prom = export_registry(out_dir)
    print(f"# metrics registry exported to {prom}", flush=True)


if __name__ == "__main__":
    main()
