"""Paper Figs. 16-20: sensitivity analyses.

  Figs. 16-17  final accuracy vs communication budget A_server (20%..80%):
               FedDD stays stable; FedCS/Oort degrade rapidly.
  Fig. 18      penalty factor delta sweep.
  Figs. 19-20  full-broadcast period h sweep (residual error grows with h,
               matching Theorem 2).
"""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import csv_row, run_experiment, timed, write_json


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 15 if full else 6
    clients = 16 if full else 8
    budgets = (0.2, 0.4, 0.6, 0.8) if full else (0.2, 0.6)
    deltas = (0.0, 1.0, 10.0) if full else (0.0, 1.0)
    hs = (1, 5, 10) if full else (1, 10)
    rows, results = [], {}

    for budget in budgets:
        for scheme in ("feddd", "fedcs", "oort"):
            res, wall = timed(lambda: run_experiment(
                "mnist", "noniid_b", scheme, rounds=rounds,
                num_clients=clients, a_server=budget))
            acc = res.history[-1].metrics["accuracy"]
            results[f"budget{budget}/{scheme}"] = acc
            rows.append(csv_row(f"fig16_A{int(budget * 100)}_{scheme}",
                                wall, f"final_acc={acc:.4f}"))

    for d in deltas:
        res, wall = timed(lambda: run_experiment(
            "mnist", "noniid_a", "feddd", rounds=rounds,
            num_clients=clients, delta=d))
        acc = res.history[-1].metrics["accuracy"]
        t = res.history[-1].sim_time
        results[f"delta{d}"] = {"acc": acc, "sim_time": t}
        rows.append(csv_row(f"fig18_delta{d}", wall,
                            f"final_acc={acc:.4f};sim_time={t:.0f}"))

    for h in hs:
        res, wall = timed(lambda: run_experiment(
            "mnist", "noniid_b", "feddd", rounds=rounds,
            num_clients=clients, h=h))
        acc = res.history[-1].metrics["accuracy"]
        results[f"h{h}"] = acc
        rows.append(csv_row(f"fig19_h{h}", wall, f"final_acc={acc:.4f}"))

    if out_dir:
        write_json(out_dir, "sensitivity.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
