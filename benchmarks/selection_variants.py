"""Paper Figs. 11-15: FedDD parameter-selection variants
(feddd / random / max / delta / ordered).  Headline: the Eq. (21) index is
the most robust across distributions; max/ordered collapse under Non-IID-b."""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import csv_row, run_experiment, timed, write_json

VARIANTS = ("feddd", "random", "max", "delta", "ordered")


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 20 if full else 6
    clients = 20 if full else 8
    datasets = ("mnist", "fmnist", "cifar10") if full else ("mnist",)
    parts = ("iid", "noniid_a", "noniid_b") if full else ("noniid_b",)
    rows, results = [], {}
    for ds in datasets:
        for part in parts:
            for var in VARIANTS:
                res, wall = timed(lambda: run_experiment(
                    ds, part, "feddd", rounds=rounds, num_clients=clients,
                    selection_scheme=var, a_server=0.4))
                accs = [r.metrics["accuracy"] for r in res.history]
                results[f"{ds}/{part}/{var}"] = accs
                rows.append(csv_row(f"fig11-15_{ds}_{part}_{var}", wall,
                                    f"final_acc={accs[-1]:.4f}"))
    if out_dir:
        write_json(out_dir, "selection_variants.json", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
