"""Time-to-accuracy under dynamic networks: scheme x policy x network grid.

The paper's Fig. 7 compares schemes on a STATIC network with a synchronous
server.  This grid runs the event-driven simulator (repro/sim) instead and
asks the question FedDD's premise raises: when links fade mid-run and the
server may stop waiting for stragglers, which serving discipline reaches
the target accuracy first, and does differential dropout still pay?

Grid (reduced mode):
  scheme   feddd + a fedavg reference
  policy   sync (wait-for-all), deadline (drops late uploads),
           async (staleness-weighted buffered merges)
  network  static (Table 4) and markov (two-state fading stragglers)

Headline column: simulated seconds to 0.80 test accuracy (``sim_time``
axis — see benchmarks/common.py for the sim vs host time distinction).
Async gets proportionally more (smaller) merge rounds so every policy
performs the same number of client updates.

Writes ``straggler_policies.csv`` to the results dir; CI uploads it as a
build artifact.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np  # noqa: E402

from benchmarks.common import (csv_row, run_sim_experiment,  # noqa: E402
                               timed, write_table)
from repro.sim import AsyncPolicy  # noqa: E402

TARGET_ACC = 0.80
POLICIES = ("sync", "deadline", "async")
NETWORKS = ("static", "markov")
MARKOV_KW = dict(p_fade=0.25, p_recover=0.5, fade_factor=0.1)


def _fmt(x) -> str:
    return "fail" if x is None else f"{x:.1f}"


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 20 if full else 8
    clients = 20 if full else 8
    schemes = ("feddd", "fedavg", "fedcs", "oort") if full \
        else ("feddd", "fedavg")
    rows = []
    table = ["scheme,policy,network,t2a_sim_s,final_acc,final_sim_s,"
             "mean_participants,mean_uploaded_frac"]
    for scheme in schemes:
        for policy in POLICIES:
            for network in NETWORKS:
                if scheme != "feddd" and policy != "sync":
                    continue     # baselines: sync reference only
                kw = dict(network_kw=MARKOV_KW) if network == "markov" \
                    else {}
                # async merges cover buffer_size clients each; scale the
                # merge count so total client updates match the waves.
                buf = AsyncPolicy().resolved_buffer(clients)
                n_rounds = rounds * (clients // buf) \
                    if policy == "async" else rounds
                res, wall = timed(lambda: run_sim_experiment(
                    "mnist", "noniid_b", scheme, policy=policy,
                    network=network, num_clients=clients,
                    rounds=n_rounds, num_train=2000, num_test=500,
                    seed=0, **kw))
                t2a = res.time_to_accuracy(TARGET_ACC)
                final = res.history[-1]
                acc = (final.metrics or {}).get("accuracy", float("nan"))
                parts = float(np.mean([r.participants
                                       for r in res.history]))
                upfrac = float(np.mean([r.uploaded_fraction
                                        for r in res.history]))
                name = f"straggler_{scheme}_{policy}_{network}"
                rows.append(csv_row(
                    name, wall,
                    f"t2a{int(TARGET_ACC * 100)}={_fmt(t2a)};"
                    f"final_acc={acc:.3f};sim_s={final.sim_time:.1f}"))
                table.append(
                    f"{scheme},{policy},{network},{_fmt(t2a)},{acc:.4f},"
                    f"{final.sim_time:.1f},{parts:.2f},{upfrac:.3f}")
    if out_dir:
        write_table(out_dir, "straggler_policies.csv", table)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    for r in run(full=args.full, out_dir=out_dir):
        print(r)
    print((out_dir / "straggler_policies.csv").read_text())


if __name__ == "__main__":
    main()
