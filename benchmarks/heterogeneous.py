"""Paper Figs. 9-10: model-heterogeneous setting (Tables 3/6 sub-models),
plus the grouped-engine-vs-loop A/B on ragged fleets.

Accuracy headline: under model-heterogeneous-b + Non-IID, client selection
collapses (FedCS/Oort 17-33% below FedDD) while FedDD tracks FedAvg.

Perf headline: ragged fleets used to be the one scenario stuck on the
per-client Python loop.  The shape-grouped engine
(core/round_engine.py GroupedRoundEngine) runs ONE jit-compiled step per
round over the whole fleet — bit-identical results (the A/B prints the max
deviation), so time-to-accuracy on the simulated axis is unchanged and the
win is host throughput:

    PYTHONPATH=src python benchmarks/heterogeneous.py --perf \
        [--clients 64] [--rounds 5]

exits non-zero below the 3x rounds/sec acceptance target at 64 clients.

``run()`` (the benchmarks/run.py + CI entry) executes the reduced accuracy
grid with a loop-vs-grouped A/B row and writes ``results/heterogeneous.csv``
(uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import (HETERO_A_SPECS, HETERO_B_SPECS, csv_row,  # noqa: E402
                               run_experiment, timed, write_json,
                               write_table)
from repro.core import FedDDServer, ProtocolConfig  # noqa: E402
from repro.fl import (init_cnn_spec, model_bytes,  # noqa: E402
                      sample_system_telemetry)
from repro.fl.models import apply_spec  # noqa: E402

SCHEMES = ("feddd", "fedavg", "fedcs", "oort")
TARGET_ACC = 0.30          # reduced-grid t2a target (few rounds, tiny data)

# ragged perf fleet: three nested-width MLP sub-models (HeteroFL slices)
PERF_WIDTHS = (128, 96, 64)


def _perf_spec(w: int):
    return [("fc", 64, w), ("fc", w, 64), ("fc", 64, 10)]


def make_perf_setup(num_clients: int, shard: int = 32, seed: int = 0):
    """Ragged fleet cycling the three widths + per-spec jitted trainers."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(num_clients, shard, 64)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, size=(num_clients, shard)))
    specs = [_perf_spec(PERF_WIDTHS[i % len(PERF_WIDTHS)])
             for i in range(num_clients)]
    clients = [init_cnn_spec(jax.random.PRNGKey(100 + i), s)
               for i, s in enumerate(specs)]
    global_params = init_cnn_spec(jax.random.PRNGKey(seed),
                                  _perf_spec(max(PERF_WIDTHS)))
    tel = sample_system_telemetry(
        num_clients, [model_bytes(p) for p in clients],
        [shard] * num_clients, [1.0] * num_clients, seed=seed)

    def _loss(spec, p, x, y):
        logits = apply_spec(p, spec, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    steps = {}
    for w in PERF_WIDTHS:
        spec = _perf_spec(w)

        def _sgd(p, x, y, spec=spec):
            loss, g = jax.value_and_grad(
                lambda q: _loss(spec, q, x, y))(p)
            return (jax.tree_util.tree_map(
                lambda wt, gw: wt - 0.05 * gw, p, g), loss)

        steps[w] = jax.jit(_sgd)

    widths = [PERF_WIDTHS[i % len(PERF_WIDTHS)] for i in range(num_clients)]

    def local_train(p, idx, rng_):
        del rng_
        return steps[widths[idx]](p, xs[idx], ys[idx])

    return global_params, tel, local_train, clients


def run_perf_mode(batched: bool, setup, *, rounds: int, seed: int = 0):
    global_params, tel, local_train, clients = setup
    cfg = ProtocolConfig(scheme="feddd", rounds=rounds, a_server=0.6, h=5,
                         seed=seed, batched=batched)
    server = FedDDServer(global_params, cfg, tel, client_params=clients)
    t0 = time.perf_counter()
    res = server.run(local_train)
    jax.block_until_ready(jax.tree_util.tree_leaves(res.global_params))
    return res, time.perf_counter() - t0


def perf_ab(clients: int = 64, rounds: int = 5, *, gate: bool = True,
            seed: int = 0):
    """Grouped-engine vs per-client loop on a ragged fleet: rounds/sec A/B.

    Returns CSV rows; with ``gate`` the process exits non-zero below the
    3x acceptance target.
    """
    setup = make_perf_setup(clients, seed=seed)
    rows = []
    results = {}
    for mode, batched in (("loop", False), ("grouped", True)):
        # warm-up over a full h=5 cycle compiles BOTH round variants
        # (sparse + dense-broadcast) outside the timed region
        run_perf_mode(batched, setup, rounds=5, seed=seed)
        res, wall = run_perf_mode(batched, setup, rounds=rounds, seed=seed)
        results[mode] = (res, wall, rounds / wall)
    base = results["loop"][2]
    g_loop = jax.tree_util.tree_leaves(results["loop"][0].global_params)
    for mode, (res, wall, rps) in results.items():
        dev = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            g_loop, jax.tree_util.tree_leaves(res.global_params)))
        rows.append(csv_row(
            f"hetero_round_{mode}", wall / rounds,
            f"rounds_per_sec={rps:.2f} speedup_vs_loop={rps / base:.2f}x "
            f"max_dev_vs_loop={dev:.1e} clients={clients} "
            f"widths={'/'.join(map(str, PERF_WIDTHS))}"))
    speedup = results["grouped"][2] / base
    rows.append(f"# grouped engine speedup at {clients} ragged clients: "
                f"{speedup:.2f}x (target >= 3x)")
    if gate and speedup < 3.0:
        print("\n".join(rows))
        print("# FAIL: below the 3x acceptance target", file=sys.stderr)
        sys.exit(1)
    return rows


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 15 if full else 3
    clients = 10 if full else 5
    num_train = 2000 if full else 1200
    num_test = 500 if full else 400
    settings = ([("hetero_a", HETERO_A_SPECS), ("hetero_b", HETERO_B_SPECS)]
                if full else [("hetero_b", HETERO_B_SPECS)])
    parts = ("iid", "noniid_a", "noniid_b") if full else ("noniid_b",)
    rows, results = [], {}
    table = ["setting,partition,scheme,engine,final_acc,"
             f"t2a{int(TARGET_ACC * 100)}_sim_s,host_s"]
    for tag, specs in settings:
        for part in parts:
            for scheme in SCHEMES:
                # full mode adds a loop A/B for the headline scheme (the
                # engines are pinned bit-identical; this shows the
                # host-time gap on a real training workload) — reduced/CI
                # mode proves the same gap on the cheap ragged-MLP perf
                # fleet below instead
                engines = (("grouped", True), ("loop", False)) \
                    if full and scheme == "feddd" else (("grouped", True),)
                for ename, batched in engines:
                    res, wall = timed(lambda b=batched: run_experiment(
                        "cifar10", part, scheme, rounds=rounds,
                        num_clients=clients, hetero_specs=specs,
                        num_train=num_train, num_test=num_test, batched=b))
                    accs = [r.metrics["accuracy"] for r in res.history]
                    t2a = res.time_to_accuracy(TARGET_ACC)
                    key = f"{tag}/{part}/{scheme}"
                    if ename == "grouped":
                        results[key] = accs
                        rows.append(csv_row(
                            f"fig9_{tag}_{part}_{scheme}", wall,
                            f"final_acc={accs[-1]:.4f}"))
                    table.append(
                        f"{tag},{part},{scheme},{ename},{accs[-1]:.4f},"
                        f"{'' if t2a is None else f'{t2a:.1f}'},"
                        f"{wall:.2f}")
    # grouped-engine vs loop rounds/sec on the ragged perf fleet (no hard
    # gate here; `--perf` applies the 3x gate at 64 clients)
    perf_clients, perf_rounds = (64, 5) if full else (16, 3)
    perf_rows = perf_ab(perf_clients, perf_rounds, gate=False)
    rows += perf_rows
    table += ["", "perf_ab (name,us_per_round,derived)"] + perf_rows
    if out_dir:
        write_json(out_dir, "heterogeneous.json", results)
        write_table(out_dir, "heterogeneous.csv", table)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--perf", action="store_true",
                    help="grouped-engine vs loop rounds/sec A/B on a "
                         "ragged fleet (exits non-zero below 3x)")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    if args.perf:
        for r in perf_ab(args.clients, args.rounds):
            print(r)
        return
    for r in run(full=args.full, out_dir=out_dir):
        print(r)
    print((out_dir / "heterogeneous.csv").read_text())


if __name__ == "__main__":
    main()
