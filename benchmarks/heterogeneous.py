"""Paper Figs. 9-10: model-heterogeneous setting (Tables 3/6 sub-models).

Headline: under model-heterogeneous-b + Non-IID, client selection collapses
(FedCS/Oort 17-33% below FedDD) while FedDD tracks FedAvg."""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import (HETERO_A_SPECS, HETERO_B_SPECS, csv_row,
                               run_experiment, timed)

SCHEMES = ("feddd", "fedavg", "fedcs", "oort")


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 15 if full else 4
    clients = 10 if full else 5
    settings = ([("hetero_a", HETERO_A_SPECS), ("hetero_b", HETERO_B_SPECS)]
                if full else [("hetero_b", HETERO_B_SPECS)])
    parts = ("iid", "noniid_a", "noniid_b") if full else ("noniid_b",)
    rows, results = [], {}
    for tag, specs in settings:
        for part in parts:
            for scheme in SCHEMES:
                res, wall = timed(lambda: run_experiment(
                    "cifar10", part, scheme, rounds=rounds,
                    num_clients=clients, hetero_specs=specs,
                    num_train=2000, num_test=500))
                accs = [r.metrics["accuracy"] for r in res.history]
                results[f"{tag}/{part}/{scheme}"] = accs
                rows.append(csv_row(f"fig9_{tag}_{part}_{scheme}", wall,
                                    f"final_acc={accs[-1]:.4f}"))
    if out_dir:
        (out_dir / "heterogeneous.json").write_text(
            json.dumps(results, indent=1))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(full=args.full,
                 out_dir=Path(__file__).resolve().parents[1] / "results"):
        print(r)


if __name__ == "__main__":
    main()
