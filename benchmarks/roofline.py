"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json and prints, per (arch x shape x mesh):
compute/memory/collective terms (seconds), the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS usefulness ratio, and bytes/device."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _analytic(rec: dict) -> dict:
    """Loop-aware analytic terms (launch/analytic_cost.py) — XLA's
    cost_analysis counts scan bodies once, so raw HLO numbers undercount."""
    from repro.configs import get_config
    from repro.launch import specs as specs_mod
    from repro.launch.analytic_cost import analytic_terms
    cfg = get_config(rec["arch"])
    seq, batch, kind = specs_mod.SHAPES[rec["shape"]]
    pol = specs_mod.policy_for(cfg)
    return analytic_terms(cfg, seq, batch, kind, rec["num_devices"],
                          optimizer=pol.optimizer)


def load_records(tag: str = "") -> List[dict]:
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if not isinstance(r, dict):        # e.g. federated_sync sweep lists
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def table(recs: List[dict], analytic: bool = True) -> List[str]:
    rows = []
    hdr = (f"{'arch':<22} {'shape':<12} {'mesh':<7} {'status':<6} "
           f"{'GB/dev':>7} {'hlo_cmp_s':>10} {'hlo_mem_s':>10} "
           f"{'collect_s':>10} {'ana_cmp_s':>10} {'ana_mem_s':>10} "
           f"{'dom':>10} {'mfu_ub%':>8}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in recs:
        if r["status"] != "ok":
            rows.append(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<7} "
                        f"{r['status']:<6} {r.get('reason', r.get('error', ''))[:60]}")
            continue
        rl = r["roofline"]
        mem = r["memory"]["peak_estimate_bytes"] / 1e9
        a = _analytic(r) if analytic else {}
        ac = a.get("analytic_compute_term_s", 0.0)
        am = a.get("analytic_memory_term_s", 0.0)
        coll = rl["collective_term_s"]
        terms = {"compute": ac, "memory": am, "collective": coll}
        dom = max(terms, key=terms.get) if analytic else rl["dominant"]
        # MFU upper bound: model-flops time / bound (= dominant term)
        mf = r.get("model_flops_total", 0.0)
        t_model = mf / (r["num_devices"] * 197e12)
        bound = max(terms.values()) if analytic else None
        mfu = (t_model / bound * 100) if bound else None
        rows.append(
            f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<7} ok     "
            f"{mem:>7.2f} {rl['compute_term_s']:>10.3e} "
            f"{rl['memory_term_s']:>10.3e} {coll:>10.3e} "
            f"{ac:>10.3e} {am:>10.3e} {dom:>10} "
            f"{'' if mfu is None else f'{mfu:>7.1f}%'}")
    return rows


def run(full: bool = False, out_dir=None):
    recs = load_records()
    rows = table(recs)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    err = sum(1 for r in recs if r["status"] == "error")
    rows.append(f"totals: ok={ok} skip={skip} error={err}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    for row in table(load_records(args.tag)):
        print(row)


if __name__ == "__main__":
    main()
