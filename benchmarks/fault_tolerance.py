"""Time-to-accuracy under injected faults: scheme x policy x fault-rate grid,
plus the survivability axes (correlated cell outages, robust aggregation).

The straggler grid (benchmarks/straggler_policies.py) asks which serving
discipline wins when links merely FADE.  This grid injects actual failures
(repro/sim/faults.py) — client crashes mid-round, lossy uplinks with
retransmit/backoff, corrupted payloads the server must quarantine — and
asks how gracefully each scheme x policy degrades as the fault rate rises:
does the retry/timeout discipline buy accuracy per simulated second over
plain sync, and does FedDD's survivor-renormalized Eq. (4) aggregation
hold its time-to-accuracy edge when a fraction of the fleet keeps dying?

Two survivability axes ride on top of the independent-fault grid:

* correlated outages (repro/sim/outages.py) — a two-state Markov cell
  process takes whole groups of clients down at once (cells x severity),
  stressing the survivor-only LP re-solve far harder than independent
  churn at the same marginal rate;
* robust aggregation (``robust_agg``) — the trimmed-mean engine variant
  vs the plain masked mean under wire corruption, measuring what the
  Byzantine-robust fusion costs (or buys) in time-to-accuracy.

Grid (reduced mode):
  scheme      feddd + a fedavg reference
  policy      sync (wait-for-survivors) and retry (timeout serving)
  fault rate  0.0 / 0.15 / 0.35 — crash_rate = r/2, loss_rate = r,
              corrupt_rate = r/4, quorum = 1/4 of the fleet
  outages     (cells, p_out) in (2, 0.3) / (4, 0.15), feddd x sync
  agg         mean vs trimmed:0.25 at the non-zero fault rates

Headline column: simulated seconds to 0.75 test accuracy on the fault-
extended Eq. (12) clock (retransmitted chunks and backoff push arrivals
back; skipped rounds still spend their deadline).  The CSV also accounts
the failure economy per run: retries, skipped rounds, abandoned and
quarantined bytes.

Writes ``fault_tolerance.csv`` to the results dir; CI uploads it as a
build artifact.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np  # noqa: E402

from benchmarks.common import (csv_row, run_sim_experiment,  # noqa: E402
                               timed, write_table)
from repro.sim import (CellOutageModel, FaultConfig,  # noqa: E402
                       OutageConfig, RandomFaults)

TARGET_ACC = 0.75
POLICIES = ("sync", "retry")
TRIMMED = "trimmed:0.25"


def _fmt(x) -> str:
    return "fail" if x is None else f"{x:.1f}"


def _faults(rate: float, n_clients: int, seed: int,
            cells: int = 0, p_out: float = 0.0):
    inner = None
    if rate > 0.0:
        inner = RandomFaults(FaultConfig(
            crash_rate=rate / 2, loss_rate=rate, corrupt_rate=rate / 4,
            quorum=max(1, n_clients // 4), seed=seed))
    if cells > 0:
        return CellOutageModel(
            n_clients,
            OutageConfig(cells=cells, p_out=p_out, p_back=0.5, seed=seed),
            inner=inner)
    return inner            # rate 0, no cells: bit-identical baseline


def run(full: bool = False, out_dir: Path | None = None):
    rounds = 20 if full else 8
    clients = 20 if full else 8
    rates = (0.0, 0.1, 0.25, 0.5) if full else (0.0, 0.15, 0.35)
    schemes = ("feddd", "fedavg")
    # correlated-outage axis: cell count x outage severity, feddd x sync
    # on top of a moderate independent-fault floor
    outage_rate = rates[1]
    outages = (((2, 0.15), (2, 0.3), (4, 0.15), (4, 0.3)) if full
               else ((2, 0.3), (4, 0.15)))
    rows = []
    table = ["scheme,policy,fault_rate,cells,p_out,agg,t2a_sim_s,"
             "final_acc,final_sim_s,mean_survivors,skipped_rounds,"
             "retries,abandoned_kb,quarantined_kb"]

    def one(scheme, policy, rate, cells=0, p_out=0.0, agg="mean"):
        res, wall = timed(lambda: run_sim_experiment(
            "mnist", "noniid_b", scheme, policy=policy,
            network="static", num_clients=clients, rounds=rounds,
            num_train=2000, num_test=500, seed=0,
            faults=_faults(rate, clients, seed=17,
                           cells=cells, p_out=p_out),
            robust_agg=agg))
        t2a = res.time_to_accuracy(TARGET_ACC)
        final = res.history[-1]
        acc = (final.metrics or {}).get("accuracy", float("nan"))
        surv = float(np.mean([r.survivors for r in res.history]))
        skipped = sum(r.skipped for r in res.history)
        retries = sum(r.retries for r in res.history)
        ab_kb = sum(r.abandoned_bytes for r in res.history) / 1e3
        q_kb = sum(r.quarantined_bytes for r in res.history) / 1e3
        tag = "" if agg == "mean" else f"_{agg.split(':')[0]}"
        cell_tag = f"_c{cells}o{p_out:g}" if cells else ""
        name = f"fault_{scheme}_{policy}_r{rate:g}{cell_tag}{tag}"
        rows.append(csv_row(
            name, wall,
            f"t2a{int(TARGET_ACC * 100)}={_fmt(t2a)};"
            f"final_acc={acc:.3f};skipped={skipped};"
            f"retries={retries}"))
        table.append(
            f"{scheme},{policy},{rate:g},{cells},{p_out:g},{agg},"
            f"{_fmt(t2a)},{acc:.4f},{final.sim_time:.1f},{surv:.2f},"
            f"{skipped},{retries},{ab_kb:.1f},{q_kb:.1f}")

    for scheme in schemes:
        for policy in POLICIES:
            for rate in rates:
                if scheme != "feddd" and policy != "sync":
                    continue     # baseline: sync reference only
                one(scheme, policy, rate)
    # robust-agg column: the trimmed-mean engine variant at the faulted
    # rates (corruption active), feddd only — fedavg shares the engine
    for policy in POLICIES:
        for rate in rates[1:]:
            one("feddd", policy, rate, agg=TRIMMED)
    # correlated-outage axis
    for cells, p_out in outages:
        one("feddd", "sync", outage_rate, cells=cells, p_out=p_out)
    if out_dir:
        write_table(out_dir, "fault_tolerance.csv", table)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out_dir = Path(__file__).resolve().parents[1] / "results"
    for r in run(full=args.full, out_dir=out_dir):
        print(r)
    print((out_dir / "fault_tolerance.csv").read_text())


if __name__ == "__main__":
    main()
