import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""End-to-end driver: FedDD federated pre-training of a transformer across
pods (the TPU adaptation of the paper — DESIGN.md §3).

Each of 4 "pods" (host devices standing in for pod slices) trains a local
replica of a small LM on its own shard of a synthetic token stream; every
round the pods exchange ONLY the top-(1-D) channels of each parameter via
the compacted sparse all-gather (core/sparse_collective.py), aggregated per
Eq. (4) with the FedDD importance index (Eq. (20)) selecting channels.

    PYTHONPATH=src python examples/federated_pods.py --rounds 10

Scale knobs: --d-model/--layers reach ~100M params on real hardware; the
CPU default is a ~1M-param model so the example finishes in minutes.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.importance import channel_importance  # noqa: E402
from repro.core.sparse_collective import (dense_allreduce_mean,  # noqa: E402
                                          sparse_allgather_mean)
from repro.data import make_lm_dataset  # noqa: E402
from repro.models import lm  # noqa: E402


def build(args):
    cfg = get_config("granite_3_8b", reduced=True)
    cfg = dataclasses.replace(
        cfg, num_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 2, vocab_size=512,
        num_heads=4, num_kv_heads=2, head_dim=max(32, args.d_model // 4))
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dropout-rate", type=float, default=0.5,
                    help="FedDD D: fraction of channels NOT exchanged")
    ap.add_argument("--dense", action="store_true",
                    help="baseline: dense all-reduce (FedAvg-style)")
    ap.add_argument("--lr", type=float, default=3e-2)
    args = ap.parse_args()

    n_pods = len(jax.devices())
    mesh = jax.make_mesh((n_pods,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = build(args)
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"pods={n_pods} params={n_params / 1e6:.2f}M  "
          f"D={args.dropout_rate} mode={'dense' if args.dense else 'feddd'}")

    # pod-stacked replicas + per-pod data
    stacked = jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (n_pods,) + t.shape), params)
    toks = make_lm_dataset(vocab_size=cfg.vocab_size,
                           num_tokens=n_pods * 50_000, seed=0)
    shards = toks.reshape(n_pods, -1)

    def sample_batch(rng, pod):
        starts = jax.random.randint(rng, (args.batch,), 0,
                                    shards.shape[1] - args.seq - 1)
        return jax.vmap(lambda s: jax.lax.dynamic_slice(
            jnp.asarray(shards)[pod], (s,), (args.seq,)))(starts)

    d_rate = 0.0 if args.dense else args.dropout_rate

    def round_fn(p_stacked, batch_stacked):
        """shard_map body: local steps + FedDD exchange over 'pod'."""
        p_local = jax.tree_util.tree_map(lambda t: t[0], p_stacked)
        batch = batch_stacked[0]
        p_old = p_local

        def loss_of(p, tokens):
            l, _ = lm.loss_fn(p, cfg, {"tokens": tokens}, remat=False)
            return l

        loss = 0.0
        for i in range(args.local_steps):
            l, g = jax.value_and_grad(loss_of)(
                p_local, batch[i % 1])       # reuse batch across steps
            p_local = jax.tree_util.tree_map(
                lambda p_, g_: (p_.astype(jnp.float32)
                                - args.lr * g_.astype(jnp.float32)
                                ).astype(p_.dtype), p_local, g)
            loss = l

        # FedDD exchange: per-tensor channel importance -> top-k compaction
        def exchange(old, new):
            if new.ndim == 0:
                return new
            if args.dense or new.ndim == 1:
                return dense_allreduce_mean(new, "pod")
            cax = new.ndim - 1                     # channels = last axis
            nm = jnp.moveaxis(new, cax, 0)
            om = jnp.moveaxis(old, cax, 0)
            c = nm.shape[0]
            k = max(1, int(np.ceil(c * (1.0 - d_rate))))
            scores = channel_importance(
                om.reshape(c, -1), nm.reshape(c, -1), channel_axis=0)
            agg = sparse_allgather_mean(nm, scores, k, "pod")
            return jnp.moveaxis(agg, 0, cax)

        p_new = jax.tree_util.tree_map(exchange, p_old, p_local)
        out = jax.tree_util.tree_map(lambda t: t[None], p_new)
        return out, jnp.asarray(loss)[None]

    rf = jax.jit(jax.shard_map(
        round_fn, mesh=mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod")),
        check_vma=False))

    full_bytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(params))
    print(f"per-round exchange (theoretical): "
          f"{(1 - d_rate) * full_bytes / 1e6:.2f} MB/pod "
          f"(dense would be {full_bytes / 1e6:.2f} MB)")

    rng = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for r in range(1, args.rounds + 1):
        rng, bk = jax.random.split(rng)
        batches = jnp.stack([sample_batch(jax.random.fold_in(bk, p), p)
                             [None] for p in range(n_pods)])
        stacked, losses = rf(stacked, batches)
        print(f"round {r:3d}  mean_loss={float(losses.mean()):.4f}  "
              f"({time.perf_counter() - t0:.1f}s)", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
