"""Batched autoregressive serving of a reduced model with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3_27b

Demonstrates the serve_step path the decode dry-run shapes lower: batched
requests, static cache (ring-buffered for sliding-window layers), greedy
sampling.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.models import lm  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_27b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    serve = jax.jit(lm.make_serve_step(cfg), donate_argnums=(1,))
    enc = (jax.random.normal(key, (args.batch, 24, cfg.d_model),
                             jnp.bfloat16) if cfg.is_encdec else None)
    state = lm.init_decode_state(params, cfg, args.batch, args.steps + 8,
                                 enc_frames=enc)
    tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size)
    outs = [tok]
    t0 = time.perf_counter()
    for t in range(args.steps):
        logits, state = serve(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.concatenate(outs, 1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"{dt / args.steps * 1e3:.1f} ms/token (CPU)")
    print("sampled token ids (first request):",
          seqs[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
