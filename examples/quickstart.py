"""Quickstart: FedDD federated training on a synthetic MNIST-like task.

    PYTHONPATH=src python examples/quickstart.py [--rounds 10] [--loop]

Trains the paper's MLP across 10 heterogeneous clients with differential
parameter dropout, then compares against FedAvg: same model, ~60% of the
bytes, large simulated wall-clock win.

Homogeneous FedDD runs go through the batched round engine
(core/round_engine.py) by default — one jit-compiled device step per round.
``--loop`` forces the per-client Python loop (bit-identical results, just
slower); ``benchmarks/perf_federated.py`` measures the gap.

Heterogeneous fleets (ragged width-sliced sub-models, paper §6.4) run the
same way since the shape-grouped engine: one fused step per shape group —
see ``examples/heterogeneous_models.py`` and
``benchmarks/heterogeneous.py --perf`` for that A/B.

Going faster still — multi-round scanning: when local training is
device-fused (``batched_train_fn``) and the allocator is the jit-able one
(``allocator="jax"``), ``rounds_per_dispatch=K`` runs K whole rounds —
training, masks, aggregation, dropout-rate re-allocation, round clock —
as ONE ``lax.scan`` device dispatch.  When does it pay off?  The scan
compiles once per chunk length but removes a Python dispatch + allocator
call + device->host sync PER ROUND, so it wins whenever you run enough
rounds to amortise the compile: long simulations, sweeps re-using the
compile across configs, or small/medium models where the per-round host
overhead rivals the compute (~4.7x rounds/sec over per-round engine
dispatch at 64 clients on CPU — ``benchmarks/perf_federated.py``).  For
a handful of rounds, or when you need per-round ``eval_fn`` callbacks
(like this example) or per-client Python training, stay on per-round
dispatch.

Choosing a wire codec (``--codec`` / ``--qbits``, repro.comm): the
default ``dense`` is the analytic idealization — bytes are just
``density x model_bytes``.  A real sparse upload also ships WHICH
channels survived: pick ``index`` (delta+varint) below ~12.5% upload
density, ``bitmask`` (packed bits, ceil(C/8) per leaf) above it, or
``auto`` to take the per-leaf minimum — the crossover sits at density
~1/8 because a varint gap costs ~1 byte per kept channel while the
bitmask costs C/8 regardless.  ``--qbits 8`` additionally quantizes the
uploaded values (int8 stochastic rounding) for ~4x fewer wire bytes at
a small accuracy cost; ``RoundRecord.wire_bytes`` then reports what
actually crossed the uplink next to the raw ``uploaded_bytes``
(``benchmarks/wire_formats.py`` maps the full frontier).

Fault injection (``--fault-rate`` / ``--quorum``, repro.sim.faults): a
non-zero fault rate routes the run through the event-driven simulator
and makes clients crash mid-round (rate/2), lose uplink chunks (rate,
retransmitted with exponential backoff and charged real bytes), and
occasionally ship corrupted payloads (rate/4) that the server's
validation screen quarantines.  ``--quorum`` sets the minimum number of
surviving contributors below which the server skips the round and holds
the global model (``benchmarks/fault_tolerance.py`` maps accuracy vs
fault rate).

Sharding the client axis (``--mesh N``, repro.launch.mesh): the stacked
fleet can run over an N-device ``("clients",)`` mesh — per-shard fused
training and mask building under ``shard_map``, Eq. (4) aggregated
cross-device (dense ``psum`` by default; ``mesh_collective="sparse"``
ships only each shard's surviving channels — see
``core/sparse_collective.py``).  On a 1-device mesh the learning state
is bit-identical to the batched engine; multi-device is allclose (the
psum reorders the f32 reduction).  CPUs expose one device by default, so
to try an 8-way mesh locally split the host first::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/quickstart.py --mesh 8

(virtual CPU devices share the physical cores — this demonstrates the
SPMD program, real speedups need real parallel hardware;
``benchmarks/perf_federated.py --sharded`` measures the scaling curve).
``--mesh`` composes with everything except fault injection with
corruption and deadline-partial aggregation, which are single-device
engine features (the runner raises a clear error).

Survivability (``--cells`` / ``--robust-agg`` / ``--checkpoint-dir`` /
``--resume``): ``--cells K`` groups the fleet into K correlated-failure
cells, each driven by a two-state Markov outage chain
(repro.sim.outages) — a downed cell crashes ALL its members at once and
the dropout LP re-solves on the survivors.  ``--robust-agg
trimmed[:beta]`` (or ``clip[:factor]``) swaps the Eq. (4) weighted mean
for a Byzantine-robust variant fused into the same engine step — with
corrupt clients in the fleet the mean diverges while the trimmed mean
holds (``benchmarks/fault_tolerance.py`` quantifies it).
``--checkpoint-dir DIR`` snapshots the full run state atomically every
round; after a crash (or a SIGKILL), re-running with ``--resume``
continues from the last snapshot with BIT-IDENTICAL history::

    PYTHONPATH=src python examples/quickstart.py --rounds 10 \\
        --fault-rate 0.2 --cells 3 --checkpoint-dir results/ckpt
    # ... killed mid-run ...
    PYTHONPATH=src python examples/quickstart.py --rounds 10 \\
        --fault-rate 0.2 --cells 3 --checkpoint-dir results/ckpt --resume

Population-scale serving (``--population`` / ``--cohort`` /
``--availability``, repro.population): a production FL service samples a
small cohort per round from a mostly-offline population instead of
serving every registered client.  ``--population N`` registers N clients
(sticky per-client state: telemetry EWMAs, losses, dropout rates, byte
economy, per-client params), ``--cohort K`` serves K of them per round,
and ``--availability`` picks who is online (``always``, i.i.d.
``bernoulli``, or phase-staggered ``diurnal``).  Client data stays
sharded by GLOBAL id (``id % --clients``), so a client trains on the
same shard no matter which cohort it lands in.  A population the size of
the fleet with ``always`` availability is bit-identical to the plain
run.  Serving 100,000 clients costs roughly what serving the cohort
costs — the only O(population) work per round is one vectorized
availability + sampling pass::

    PYTHONPATH=src python examples/quickstart.py --rounds 10 \\
        --clients 32 --population 100000 --cohort 256 \\
        --availability bernoulli

(32 data shards, 100k registered clients, 256 served per round;
``benchmarks/population_scale.py`` maps time-to-accuracy over cohort
size x availability and pins the throughput claim.)

Observability (``--log-jsonl`` / ``--trace``, repro.obs): pass a path to
write a structured JSONL run log — one schema-versioned event per round,
pipeline span, and fault incident, derived entirely from host data the
run already pulls (no extra device syncs; with observability off the
learning state is bit-identical).  Inspect it afterwards with the
run-inspection CLI::

    PYTHONPATH=src python examples/quickstart.py --rounds 5 \\
        --fault-rate 0.2 --log-jsonl results/quickstart_run.jsonl
    PYTHONPATH=src python -m repro.obs.report results/quickstart_run.jsonl

which prints per-phase time breakdowns, the byte/failure economy, and
per-client straggler timelines, and exports CSV (``--csv``) or
Prometheus text (``--prom``).  ``--trace`` additionally wraps the host
spans in ``jax.profiler`` trace annotations so they line up with device
activity under a profiler.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.comm import CommConfig  # noqa: E402
from repro.core import run_scheme  # noqa: E402
from repro.data import (label_coverage_score, make_dataset,  # noqa: E402
                        partition_noniid_b)
from repro.fl import (MLP_SPEC, init_cnn_spec, make_eval_fn,  # noqa: E402
                      make_local_train_fn, model_bytes,
                      sample_system_telemetry)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--a-server", type=float, default=0.6)
    ap.add_argument("--loop", action="store_true",
                    help="force the per-client loop instead of the "
                         "batched round engine")
    ap.add_argument("--codec", default="dense",
                    choices=("dense", "bitmask", "index", "auto"),
                    help="upload mask wire codec (repro.comm); dense is "
                         "the analytic idealization")
    ap.add_argument("--qbits", type=int, default=32, choices=(32, 16, 8),
                    help="uploaded-value precision (8 = int8 stochastic "
                         "rounding)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="inject faults at this rate (crashes at rate/2, "
                         "lossy uplink chunks at rate, corrupted payloads "
                         "at rate/4); 0 keeps the closed-form driver")
    ap.add_argument("--quorum", type=int, default=1,
                    help="minimum surviving contributors per round; below "
                         "it the server skips the round (fault runs only)")
    ap.add_argument("--cells", type=int, default=0, metavar="K",
                    help="group clients into K correlated-failure cells, "
                         "each driven by a two-state Markov outage chain "
                         "(repro.sim.outages); composes with --fault-rate "
                         "and routes through the simulator like it")
    ap.add_argument("--robust-agg", default="mean", metavar="SPEC",
                    help="Eq. (4) aggregation variant: 'mean' (default), "
                         "'trimmed[:beta]' (coordinate-wise trimmed mean) "
                         "or 'clip[:factor]' (per-client norm clipping)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="snapshot the full run state to DIR/run_state.npz "
                         "every round (atomic writes; survives SIGKILL)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the --checkpoint-dir snapshot; the "
                         "continued run is bit-identical to an "
                         "uninterrupted one")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="shard the client axis over an N-device mesh "
                         "(run under XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N to split a CPU host); omit for "
                         "the single-device engines")
    ap.add_argument("--population", type=int, default=None, metavar="N",
                    help="serve an N-client population (repro.population) "
                         "instead of a fixed fleet; data is sharded by "
                         "global id (id %% --clients)")
    ap.add_argument("--cohort", type=int, default=None, metavar="K",
                    help="clients served per round in population mode "
                         "(default: the whole population)")
    ap.add_argument("--availability", default="always",
                    choices=("always", "bernoulli", "diurnal"),
                    help="who is online each round in population mode")
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="write a structured JSONL run log here "
                         "(repro.obs); inspect with "
                         "`python -m repro.obs.report PATH`")
    ap.add_argument("--trace", action="store_true",
                    help="wrap host spans in jax.profiler trace "
                         "annotations (implies observability on)")
    args = ap.parse_args()

    train, test = make_dataset("mnist", num_train=6000, num_test=1500)
    parts = partition_noniid_b(train, args.clients, seed=0)
    params = init_cnn_spec(jax.random.PRNGKey(0), MLP_SPEC)
    tel = sample_system_telemetry(
        args.clients, [model_bytes(params)] * args.clients,
        [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=0)
    ltf = make_local_train_fn(MLP_SPEC, train, parts, flatten=True, lr=0.1)
    ef = make_eval_fn(MLP_SPEC, test, flatten=True)

    pop_kw = {}
    fleet_n = args.clients
    if args.cohort is not None and args.population is None:
        ap.error("--cohort requires --population")
    if args.population is not None:
        from repro.population import Population
        P, shards = args.population, args.clients
        # population-sized telemetry: client g shares data shard g % C's
        # sample count / coverage, so telemetry matches the data mapping
        tel = sample_system_telemetry(
            P, [model_bytes(params)] * P,
            [len(parts[g % shards]) for g in range(P)],
            [label_coverage_score(train, parts[g % shards])
             for g in range(P)], seed=0)
        shard_ltf = ltf

        def ltf(p, gid, key):                    # noqa: F811
            return shard_ltf(p, int(gid) % shards, key)

        def make_pop():
            # one store per run: sticky state is mutated by serving
            return Population(tel, availability=args.availability,
                              sampler="uniform", seed=0)

        pop_kw["population"] = make_pop()
        pop_kw["cohort_size"] = args.cohort
        fleet_n = args.cohort if args.cohort is not None else P

    engine = "per-client loop" if args.loop else "batched round engine"
    mesh_kw = {}
    if args.mesh is not None:
        if args.loop:
            ap.error("--mesh requires the batched engine (drop --loop)")
        engine = f"sharded round engine ({args.mesh}-device mesh)"
        mesh_kw["mesh"] = args.mesh
    comm = CommConfig(codec=args.codec, qbits=args.qbits)
    obs_kw = {}
    if args.log_jsonl or args.trace:
        from repro.obs import ObsConfig
        if args.log_jsonl:
            Path(args.log_jsonl).parent.mkdir(parents=True, exist_ok=True)
        obs_kw["obs"] = ObsConfig(enabled=True, jsonl_path=args.log_jsonl,
                                  trace=args.trace)
    faults = None
    if args.fault_rate > 0.0:
        from repro.sim import FaultConfig, RandomFaults
        faults = RandomFaults(FaultConfig(
            crash_rate=args.fault_rate / 2, loss_rate=args.fault_rate,
            corrupt_rate=args.fault_rate / 4, quorum=args.quorum, seed=0))
    if args.cells > 0:
        from repro.sim import CellOutageModel, OutageConfig
        faults = CellOutageModel(
            args.clients,
            OutageConfig(cells=args.cells, p_out=0.15, p_back=0.5, seed=0),
            inner=faults)
    surv_kw = {}
    if args.robust_agg != "mean":
        surv_kw["robust_agg"] = args.robust_agg
    if args.checkpoint_dir:
        ckpt = str(Path(args.checkpoint_dir) / "run_state.npz")
        surv_kw["checkpoint_every"] = 1
        surv_kw["checkpoint_path"] = ckpt
        if args.resume:
            if not Path(ckpt).exists():
                ap.error(f"--resume: no checkpoint at {ckpt}")
            surv_kw["resume_from"] = ckpt
    elif args.resume:
        ap.error("--resume requires --checkpoint-dir")
    pop_col = (f", population={args.population}/cohort={fleet_n}"
               f"/{args.availability}" if args.population else "")
    if faults is not None:
        cells_col = f", cells={args.cells}" if args.cells else ""
        print(f"== FedDD + faults (rate={args.fault_rate}, "
              f"quorum={args.quorum}{cells_col}, "
              f"agg={args.robust_agg}{pop_col}) ==")
    else:
        print(f"== FedDD (A_server={args.a_server}, {engine}, "
              f"codec={args.codec}/q{args.qbits}, "
              f"agg={args.robust_agg}{pop_col}) ==")
    feddd = run_scheme("feddd", params, tel, ltf, ef, rounds=args.rounds,
                       a_server=args.a_server, h=5, batched=not args.loop,
                       comm=comm, faults=faults, **mesh_kw, **obs_kw,
                       **surv_kw, **pop_kw)
    if args.log_jsonl:
        print(f"  run log -> {args.log_jsonl}  (inspect: python -m "
              f"repro.obs.report {args.log_jsonl})")
    for r in feddd.history:
        fault_col = ""
        if faults is not None:
            fault_col = (" SKIPPED" if r.skipped else
                         f"  surv={r.survivors}/{fleet_n}")
        print(f"  round {r.round:2d}  acc={r.metrics['accuracy']:.3f}  "
              f"sim_t={r.sim_time:8.1f}s  uploaded={r.uploaded_fraction:.0%}  "
              f"wire={r.wire_bytes / 1e3:.0f}kB  "
              f"host={r.host_wall_time:.2f}s{fault_col}")

    print("== FedAvg (full uploads) ==")
    if args.population is not None:
        pop_kw["population"] = make_pop()     # fresh sticky state
    fedavg = run_scheme("fedavg", params, tel, ltf, ef, rounds=args.rounds,
                        **pop_kw)
    for r in fedavg.history[-3:]:
        print(f"  round {r.round:2d}  acc={r.metrics['accuracy']:.3f}  "
              f"sim_t={r.sim_time:8.1f}s")

    tgt = 0.9
    t_dd, t_avg = (x.time_to_accuracy(tgt) for x in (feddd, fedavg))
    if t_dd and t_avg:
        print(f"\nTime to {tgt:.0%} accuracy: FedDD {t_dd:.0f}s vs "
              f"FedAvg {t_avg:.0f}s  ({1 - t_dd / t_avg:.0%} reduction)")


if __name__ == "__main__":
    main()
