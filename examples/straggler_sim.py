"""Straggler demo: FedDD on a FADING network under three serving policies.

    PYTHONPATH=src python examples/straggler_sim.py [--rounds 10]

Runs the same FedDD training through the event-driven simulator
(repro/sim) with a two-state Markov fading network — clients drop into
deep fades (10x slower links) and recover — under:

  sync      wait for every upload (the paper's protocol)
  deadline  semi-sync: abandon uploads missing an adaptive deadline
  async     buffered merges with staleness-decayed weights; clients
            re-dispatch immediately (no fleet barrier)

The server never sees the true link rates: it re-solves the dropout-rate
LP each round from telemetry observed on the event timeline, so FedDD's
differential dropout chases the fades.  Compare the simulated
time-to-accuracy across policies at the end.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.data import (label_coverage_score, make_dataset,  # noqa: E402
                        partition_noniid_b)
from repro.fl import (MLP_SPEC, init_cnn_spec, make_eval_fn,  # noqa: E402
                      make_local_train_fn, model_bytes,
                      sample_system_telemetry)
from repro.sim import (AsyncPolicy, MarkovFadingNetwork,  # noqa: E402
                       SimConfig, run_sim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--target", type=float, default=0.85)
    args = ap.parse_args()

    train, test = make_dataset("mnist", num_train=4000, num_test=1000)
    parts = partition_noniid_b(train, args.clients, seed=0)
    params = init_cnn_spec(jax.random.PRNGKey(0), MLP_SPEC)
    tel = sample_system_telemetry(
        args.clients, [model_bytes(params)] * args.clients,
        [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=0)
    ltf = make_local_train_fn(MLP_SPEC, train, parts, flatten=True, lr=0.1)
    ef = make_eval_fn(MLP_SPEC, test, flatten=True)

    results = {}
    for policy in ("sync", "deadline", "async"):
        # async merges buffer_size clients per (shorter) round: scale the
        # merge count so every policy does the same number of updates
        buf = AsyncPolicy().resolved_buffer(args.clients)
        rounds = (args.rounds * (args.clients // buf)
                  if policy == "async" else args.rounds)
        net = MarkovFadingNetwork(tel, p_fade=0.25, p_recover=0.5,
                                  fade_factor=0.1, seed=1)
        print(f"== FedDD / {policy} / markov-fading ==")
        res = run_sim("feddd", params, tel, ltf, ef,
                      sim=SimConfig(policy=policy), network=net,
                      rounds=rounds, a_server=0.6, h=5, seed=0)
        results[policy] = res
        step = max(1, len(res.history) // args.rounds)
        for r in res.history[::step]:
            print(f"  round {r.round:3d}  acc={r.metrics['accuracy']:.3f}  "
                  f"sim_t={r.sim_time:8.1f}s  "
                  f"parts={r.participants}  "
                  f"uploaded={r.uploaded_fraction:.0%}")

    print(f"\nSimulated time to {args.target:.0%} accuracy "
          f"(fading network):")
    for policy, res in results.items():
        t = res.time_to_accuracy(args.target)
        print(f"  {policy:9s} "
              f"{'not reached' if t is None else f'{t:8.1f}s'}")


if __name__ == "__main__":
    main()
