"""Model-heterogeneous FedDD (paper §6.4): five width-pruned VGG sub-models
(Table 3) federate into one full-width global model; the Eq. (21) coverage
rectification keeps rarely-covered channels uploaded.

Ragged fleets run the shape-grouped engine by default — clients partitioned
by sub-model shape, one jit-compiled device step per round
(core/round_engine.py GroupedRoundEngine).  ``--loop`` forces the
per-client reference loop (bit-identical results, just slower);
``benchmarks/heterogeneous.py --perf`` measures the gap.

    PYTHONPATH=src python examples/heterogeneous_models.py [--loop]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import FedDDServer, ProtocolConfig  # noqa: E402
from repro.data import (label_coverage_score, make_dataset,  # noqa: E402
                        partition_noniid_a)
from repro.fl import (HETERO_A_SPECS, init_cnn_spec,  # noqa: E402
                      make_eval_fn, make_local_train_fn, model_bytes,
                      sample_system_telemetry)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--loop", action="store_true",
                    help="force the per-client reference loop instead of "
                         "the shape-grouped engine")
    args = ap.parse_args()

    train, test = make_dataset("cifar10", num_train=3000, num_test=800)
    n = 5
    parts = partition_noniid_a(train, n, seed=0)
    specs = HETERO_A_SPECS
    clients = [init_cnn_spec(jax.random.PRNGKey(10 + i), s)
               for i, s in enumerate(specs)]
    global_params = init_cnn_spec(jax.random.PRNGKey(0), specs[0])
    tel = sample_system_telemetry(
        n, [model_bytes(p) for p in clients], [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=0)
    print("client model sizes (MB):",
          [round(model_bytes(p) / 1e6, 2) for p in clients])

    fns = [make_local_train_fn(specs[i], train, parts, lr=0.05)
           for i in range(n)]

    def ltf(params, idx, rng):
        return fns[idx](params, idx, rng)

    ef = make_eval_fn(specs[0], test)
    cfg = ProtocolConfig(scheme="feddd", rounds=args.rounds,
                         a_server=0.6, h=5, batched=not args.loop)
    server = FedDDServer(global_params, cfg, tel, client_params=clients)
    executor = server.executor_kind
    print(f"heterogeneous: {server.heterogeneous}  "
          f"(executor: {executor} — "
          f"{'per-client reference loop' if executor == 'loop' else 'one fused step per round over shape groups'})")
    # show coverage rates of the widest conv layer
    name = next(k for k in server.cr if "conv4" in k or "conv3" in k)
    print(f"coverage of {name}: "
          f"min={server.cr[name].min():.2f} max={server.cr[name].max():.2f}")
    res = server.run(ltf, ef)
    for r in res.history:
        print(f"round {r.round}: acc={r.metrics['accuracy']:.3f} "
              f"D=[{r.dropout_rates.min():.2f},{r.dropout_rates.max():.2f}] "
              f"uploaded={r.uploaded_fraction:.0%} "
              f"host={r.host_wall_time:.2f}s")


if __name__ == "__main__":
    main()
