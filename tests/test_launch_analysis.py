"""Launch-layer units: shape policies, HLO collective parser, analytic
cost model, and the dry-run skip table."""

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as specs_mod
from repro.launch.analytic_cost import (analytic_bytes, analytic_flops,
                                        total_params)
from repro.launch.hlo_analysis import (Hardware, Roofline, _shape_bytes,
                                       collective_bytes_per_device)

HLO_SAMPLE = """
HloModule test
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
ENTRY %main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ar = bf16[16,128]{1,0} all-reduce(%p0), to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%ar), dimensions={0}
  %a2a = bf16[16,128]{1,0} all-to-all(%ar), dimensions={0}
  %cp = bf16[16,128]{1,0} collective-permute(%ar)
  ROOT %t = tuple(%ag, %a2a, %cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(bf16[4,4], f32[2])") == 4 * 4 * 2 + 8


def test_collective_parser_counts_operands():
    out = collective_bytes_per_device(HLO_SAMPLE)
    sz = 16 * 128 * 2
    assert out["all-reduce"] == sz
    assert out["all-gather"] == sz          # operand, not gathered result
    assert out["all-to-all"] == sz
    assert out["collective-permute"] == sz
    assert out["reduce-scatter"] == 0


def test_roofline_terms_and_dominant():
    rl = Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                  collective_per_device={"all-reduce": int(50e9)},
                  num_devices=256)
    assert rl.compute_term == pytest.approx(1.0)
    assert rl.memory_term == pytest.approx(1.0)
    assert rl.collective_term == pytest.approx(1.0)
    rl2 = Roofline(flops_per_device=1, bytes_per_device=819e9,
                   collective_per_device={}, num_devices=1)
    assert rl2.dominant == "memory"


@pytest.mark.parametrize("arch,expect_b", [
    ("nemotron_4_340b", 340e9), ("granite_3_8b", 8e9),
    ("qwen3_moe_30b_a3b", 30e9), ("jamba_1p5_large_398b", 398e9),
    # assignment's 48L xlstm is ~1.5x the official 24-block 1.3B card
    ("xlstm_1p3b", 1.9e9), ("gemma3_27b", 27e9),
    ("pixtral_12b", 12e9), ("chatglm3_6b", 6e9),
    ("granite_moe_1b_a400m", 1.3e9), ("whisper_medium", 0.7e9),
])
def test_total_params_match_model_names(arch, expect_b):
    """Config param counts land within ~35% of the models' nameplates —
    catches layout/config regressions."""
    n = total_params(get_config(arch))
    assert 0.65 * expect_b < n < 1.45 * expect_b, f"{arch}: {n / 1e9:.2f}B"


def test_analytic_flops_monotone_in_tokens():
    cfg = get_config("granite_3_8b")
    f1 = analytic_flops(cfg, 4096, 8, "train")
    f2 = analytic_flops(cfg, 4096, 16, "train")
    assert f2 > 1.9 * f1


def test_analytic_decode_is_param_bound():
    cfg = get_config("granite_3_8b")
    by = analytic_bytes(cfg, 32768, 1, "decode")
    assert by > 2.0 * total_params(cfg)     # params read + cache


def test_should_run_skip_table():
    skips = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, reason = specs_mod.should_run(cfg, "long_500k")
        if not ok:
            skips.append(cfg.name)
            assert "quadratic" in reason
    assert sorted(skips) == sorted([
        "pixtral-12b", "chatglm3-6b", "qwen3-moe-30b-a3b", "granite-3-8b",
        "whisper-medium", "nemotron-4-340b", "granite-moe-1b-a400m"])
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert specs_mod.should_run(get_config(arch), shape)[0]


def test_input_specs_shapes():
    cfg = get_config("pixtral_12b")
    sp = specs_mod.input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096 - cfg.num_patch_tokens)
    assert sp["patch_embeds"].shape == (256, cfg.num_patch_tokens,
                                        cfg.d_model)
    cfg = get_config("whisper_medium")
    sp = specs_mod.input_specs(cfg, "prefill_32k")
    assert sp["enc_frames"].shape == (32, 32768, cfg.d_model)
    assert sp["tokens"].shape[1] <= 512


def test_decode_specs_long500k_windows_global_layers():
    cfg = get_config("gemma3_27b")
    state, tok = specs_mod.decode_specs(cfg, "long_500k")
    # every KV cache leaf must be capped at the windowed sizes
    import jax
    for path, leaf in jax.tree_util.tree_flatten_with_path(state.stack)[0]:
        if leaf.ndim >= 4:   # KV cache (L?, B, C, H, hd)
            cache_len = leaf.shape[-3]
            assert cache_len <= specs_mod.LONG_GLOBAL_WINDOW
    assert tok.shape == (1, 1)
