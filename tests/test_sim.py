"""Event-driven simulator (repro/sim): determinism, protocol equivalence,
and policy behaviour.

Pins the subsystem's three contracts:

* determinism — same seed gives the identical event order, sim times, and
  final parameters in any process (asserted via subprocess digests);
* fidelity — the synchronous policy over a static network reproduces
  core/protocol.py's Eq. (12) round times and global params EXACTLY;
* policy semantics — deadline drops stragglers and finishes earlier,
  async merges fixed-size buffers with staleness-decayed weights, and the
  observed-telemetry LP re-solve adapts dropout when links fade.
"""

import hashlib
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import run_scheme
from repro.core.allocation import ClientTelemetry
from repro.sim import (AsyncPolicy, DeadlinePolicy, MarkovFadingNetwork,
                       SimConfig, Simulator, StaticNetwork, SyncPolicy,
                       TraceNetwork, run_sim)
from repro.sim.engine import UPLOAD_DONE, EventQueue

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key):
    k1, k2 = jax.random.split(key)
    return {
        "fc0": {"w": jax.random.normal(k1, (20, 12)), "b": jnp.zeros(12)},
        "fc1": {"w": jax.random.normal(k2, (12, 5)), "b": jnp.zeros(5)},
    }


def _tel(n, seed=0):
    rng = np.random.default_rng(seed)
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(
                           _params(jax.random.PRNGKey(0)))))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    """Deterministic pseudo-training (no dataset needed)."""
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --- engine ------------------------------------------------------------------

def test_event_queue_orders_by_time_then_schedule_seq():
    q = EventQueue()
    q.push(2.0, "b", 1)
    q.push(1.0, "a", 2)
    q.push(1.0, "a2", 3)      # same time: scheduling order breaks the tie
    q.push(0.5, "z", 4)
    got = [(q.pop().kind) for _ in range(4)]
    assert got == ["z", "a", "a2", "b"]


def test_simulator_clock_monotone_and_traced():
    sim = Simulator()
    sim.schedule(3.0, "x", 1)
    sim.schedule(1.0, "y", 2)
    ev = sim.step()
    assert (ev.kind, sim.now) == ("y", 1.0)
    with pytest.raises(ValueError):
        sim.schedule_at(0.5, "past", 3)
    sim.step()
    assert sim.trace == [(1.0, "y", 2), (3.0, "x", 1)]
    with pytest.raises(ValueError):
        sim.advance_to(1.0)
    sim.advance_to(10.0)
    assert sim.now == 10.0


def test_queue_clear_cancels_pending():
    sim = Simulator()
    sim.schedule(1.0, "a")
    sim.schedule(2.0, "b")
    cancelled = sim.queue.clear()
    assert [e.kind for e in cancelled] == ["a", "b"]
    assert not sim.queue


# --- fidelity: sync + static == protocol.py ----------------------------------

def test_sync_static_reproduces_protocol_eq12_exactly():
    """The acceptance contract: event-driven sync over a static network is
    bit-identical to the closed-form driver — Eq. (12) round times AND the
    trained global parameters."""
    n = 6
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(rounds=5, a_server=0.6, h=3, seed=0)
    ref = run_scheme("feddd", params, tel, _ltf, None, **kw)
    got = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"), **kw)
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time          # exact, not approx
        # per-round duration re-derived from absolute event times: one-ulp
        # float-association slack, the cumulative clock stays exact
        assert rr.sim_round_time == pytest.approx(rg.sim_round_time,
                                                  rel=1e-12)
        assert rr.uploaded_fraction == pytest.approx(rg.uploaded_fraction,
                                                     abs=1e-12)
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
    assert _trees_equal(ref.global_params, got.global_params)


def test_run_scheme_sim_kwarg_routes_to_simulator():
    n = 4
    params = _params(jax.random.PRNGKey(1))
    tel = _tel(n, seed=1)
    res = run_scheme("feddd", params, tel, _ltf, None, sim=True,
                     rounds=2, a_server=0.6, h=5, seed=0)
    from repro.sim.runner import SimResult
    assert isinstance(res, SimResult)
    assert len(res.event_trace) == 3 * n * 2       # 3 events/client/round
    # an explicitly homogeneous client_params fleet routes identically
    # (ragged fleets are exercised in tests/test_grouped_engine.py)
    res2 = run_scheme("feddd", params, tel, _ltf, None, sim=True,
                      client_params=[params] * n,
                      rounds=2, a_server=0.6, h=5, seed=0)
    assert _trees_equal(res.global_params, res2.global_params)
    with pytest.raises(ValueError, match="client_params"):
        run_scheme("feddd", params, tel, _ltf, None, sim=True,
                   client_params=[params] * (n + 1), rounds=1)


# --- determinism across processes ---------------------------------------------

_DIGEST_SNIPPET = r"""
import hashlib, sys
import numpy as np
import jax, jax.numpy as jnp
from repro.core.allocation import ClientTelemetry
from repro.sim import MarkovFadingNetwork, SimConfig, run_sim

def params():
    return {"fc0": {"w": jax.random.normal(jax.random.PRNGKey(0), (20, 12)),
                    "b": jnp.zeros(12)},
            "fc1": {"w": jax.random.normal(jax.random.PRNGKey(9), (12, 5)),
                    "b": jnp.zeros(5)}}

def tel(n):
    rng = np.random.default_rng(0)
    p = params()
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(p)))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

def ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))

h = hashlib.sha256()
for policy in ("sync", "deadline", "async"):
    t = tel(5)
    net = MarkovFadingNetwork(t, p_fade=0.3, p_recover=0.4,
                              fade_factor=0.05, seed=7)
    res = run_sim("feddd", params(), t, ltf, None,
                  sim=SimConfig(policy=policy), network=net,
                  rounds=3, a_server=0.6, h=2, seed=0)
    times = np.asarray([e[0] for e in res.event_trace])
    h.update(times.tobytes())
    h.update(",".join(f"{e[1]}:{e[2]}" for e in res.event_trace).encode())
    h.update(np.asarray([r.sim_time for r in res.history]).tobytes())
    for leaf in jax.tree_util.tree_leaves(res.global_params):
        h.update(np.asarray(leaf).tobytes())
print(h.hexdigest())
"""


def test_deterministic_event_order_across_processes():
    """Same seed => identical event order, sim_time, and final params in
    independent processes (all three policies, fading network)."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _DIGEST_SNIPPET],
            capture_output=True, text=True,
            env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu", "HOME": "/tmp"},
            check=False)
        assert out.returncode == 0, out.stderr[-2000:]
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64


# --- policy semantics ---------------------------------------------------------

def _straggler_trace_net(tel, n, fade_from=1, factor=50.0):
    """Client 0's uplink collapses by ``factor`` from epoch ``fade_from``."""
    epochs = 12
    up = np.tile(tel.uplink_rate, (epochs, 1))
    up[fade_from:, 0] /= factor
    down = np.tile(tel.downlink_rate, (epochs, 1))
    cmp_ = np.tile(tel.compute_latency, (epochs, 1))
    return TraceNetwork(up, down, cmp_)


def test_deadline_drops_straggler_and_finishes_earlier():
    n = 6
    params = _params(jax.random.PRNGKey(2))
    tel = _tel(n, seed=3)
    kw = dict(rounds=5, a_server=0.6, h=3, seed=0)
    sync = run_sim("feddd", params, tel, _ltf, None,
                   sim=SimConfig(policy="sync"),
                   network=_straggler_trace_net(tel, n), **kw)
    dl = run_sim("feddd", params, tel, _ltf, None,
                 sim=SimConfig(policy="deadline"),
                 network=_straggler_trace_net(tel, n), **kw)
    assert all(r.participants == n for r in sync.history)
    assert any(r.participants < n for r in dl.history)
    assert all(r.participants >= 1 for r in dl.history)
    assert dl.history[-1].sim_time < sync.history[-1].sim_time


def test_async_buffer_and_staleness_scale():
    n = 8
    params = _params(jax.random.PRNGKey(3))
    tel = _tel(n, seed=4)
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="async"),
                  rounds=6, a_server=0.6, h=3, seed=0)
    k = AsyncPolicy().resolved_buffer(n)
    assert k == 2
    assert all(r.participants == k for r in res.history)
    times = [r.sim_time for r in res.history]
    assert all(b > a for a, b in zip(times, times[1:]))
    # staleness decay: (1+s)^-alpha
    pol = AsyncPolicy(alpha=0.5)
    np.testing.assert_allclose(pol.staleness_scale(np.array([0, 1, 3])),
                               [1.0, 2 ** -0.5, 0.5])


def test_observed_telemetry_adapts_dropout_to_fading_link():
    """The LP runs on OBSERVED rates: when client 0's uplink collapses, the
    server's estimate tracks it down and pushes D_0 toward D_max."""
    n = 6
    params = _params(jax.random.PRNGKey(4))
    tel = _tel(n, seed=5)
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  network=_straggler_trace_net(tel, n, fade_from=2),
                  rounds=8, a_server=0.6, d_max=0.9, h=20, seed=0)
    obs = res.observed_telemetry
    assert obs.uplink_rate[0] < 0.2 * tel.uplink_rate[0]
    d0 = np.asarray([r.dropout_rates[0] for r in res.history])
    assert d0[0] < 0.1                 # pre-fade: cheap link, keep it all
    assert d0[-1] > 0.6                # post-fade: shed most of the upload
    assert np.all(np.diff(d0) >= -1e-9)  # monotone as the EWMA converges


def test_static_exactness_of_markov_epoch0_and_memoisation():
    tel = _tel(5, seed=6)
    a = MarkovFadingNetwork(tel, seed=3)
    b = MarkovFadingNetwork(tel, seed=3)
    c0 = a.conditions(0)
    np.testing.assert_array_equal(c0.uplink_rate, tel.uplink_rate)
    # same seed => same chain, regardless of query order
    ca, cb = a.conditions(4), b.conditions(4)
    np.testing.assert_array_equal(ca.uplink_rate, cb.uplink_rate)
    np.testing.assert_array_equal(a.conditions(2).uplink_rate,
                                  b.conditions(2).uplink_rate)


def test_sim_baselines_select_on_observed_telemetry():
    n = 6
    params = _params(jax.random.PRNGKey(5))
    tel = _tel(n, seed=7)
    res = run_sim("fedcs", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  rounds=2, a_server=0.5, h=5, seed=0)
    assert all(0 < r.participants < n for r in res.history)
    assert all(r.uploaded_fraction <= 0.5 + 1e-9 for r in res.history)


def test_policy_horizons():
    exp = np.array([1.0, 2.0, 3.0, 4.0])
    assert SyncPolicy().horizon(exp) == float("inf")
    d = DeadlinePolicy(quantile=0.5, slack=2.0)
    assert d.horizon(exp) == pytest.approx(5.0)


def test_async_rejects_selection_baselines():
    """fedcs/oort are per-round selection baselines — no async analogue;
    combining them must raise, not silently degenerate to fedavg."""
    n = 4
    params = _params(jax.random.PRNGKey(6))
    tel = _tel(n, seed=8)
    for scheme in ("fedcs", "oort"):
        with pytest.raises(ValueError, match="async"):
            run_sim(scheme, params, tel, _ltf, None,
                    sim=SimConfig(policy="async"), rounds=1)


def test_deadline_dropped_straggler_loss_stays_stale():
    """The loss report ships WITH the upload: a client whose transfer was
    abandoned must not update the server's loss view (no oracle leak into
    the allocation LP / oort utilities)."""
    n = 6
    params = _params(jax.random.PRNGKey(7))
    tel = _tel(n, seed=3)

    counters = {i: 1.0 for i in range(n)}

    def halving_ltf(p, idx, key):
        """Loss halves every time a client trains: at round r every
        freshly-reported loss is exactly 2^-r."""
        counters[idx] *= 0.5
        return p, counters[idx]

    res = run_sim("feddd", params, tel, halving_ltf, None,
                  sim=SimConfig(policy="deadline"),
                  network=_straggler_trace_net(tel, n, factor=500.0),
                  rounds=4, a_server=0.6, h=5, seed=0)
    dropped = [r for r in res.history if r.participants < n]
    assert dropped, "straggler never dropped — scenario broken"
    for rec in res.history:
        fresh = 2.0 ** -rec.round
        if rec.participants == n:
            assert rec.mean_loss == pytest.approx(fresh)
        else:
            # a leak would make mean_loss exactly the all-fresh value;
            # stale entries (earlier, larger losses) keep it above it
            assert rec.mean_loss > fresh * (1 + 1e-9)
