"""Deterministic crash-resume (repro/checkpoint/run_state.py +
``ProtocolConfig.checkpoint_every/-path/resume_from``): snapshot
round-trips, bit-identical continuation on the protocol and sim paths,
the checkpointing-on inert contract, and the SIGKILL acceptance test.

Pins the crash-resume contracts:

* RunState round-trip — tensors, float64 history records, and extras
  survive ``save_run_state`` / ``load_run_state`` exactly;
* checkpointing ON is inert — a run that writes a snapshot every round
  is BIT-IDENTICAL to one that never checkpoints (snapshots only read);
* resume bit-identity — interrupt after round k, resume from the
  snapshot: the continued run reproduces the uninterrupted run's
  RoundRecord history, final params, and (sim path) event trace bit for
  bit — including with correlated outages + random faults + obs enabled,
  and on ragged (grouped wave) fleets;
* the SIGKILL scenario — a subprocess killed with SIGKILL mid-run and
  resumed yields the identical run digest as an uninterrupted process
  (the CI kill-and-resume lane runs the same recipe via
  scripts/kill_resume_smoke.py);
* routing — checkpoint/resume rejects the configurations whose state it
  cannot snapshot (grouped/sharded protocol executors, scanned
  multi-round dispatch, the async sim policy) loudly at config time or
  first snapshot.
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.core import ProtocolConfig, run_scheme
from repro.core.allocation import ClientTelemetry
from repro.core.protocol import RoundRecord
from repro.sim import (CellOutageModel, FaultConfig, OutageConfig,
                       RandomFaults, SimConfig, run_sim)

pytestmark = pytest.mark.flcore

SRC = str(Path(__file__).resolve().parents[1] / "src")


# --- shared fixtures ---------------------------------------------------------

def _params(key, w=12):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}


def _nbytes(p):
    return float(sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(p)))


def _tel(n, nbytes=None, seed=0):
    rng = np.random.default_rng(seed)
    if nbytes is None:
        nbytes = _nbytes(_params(jax.random.PRNGKey(0)))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes) if np.isscalar(nbytes)
        else np.asarray(nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _records_identical(ha, hb):
    assert len(ha) == len(hb)
    for ra, rb in zip(ha, hb):
        assert ra.round == rb.round
        assert ra.sim_time == rb.sim_time
        assert ra.mean_loss == rb.mean_loss
        assert ra.uploaded_bytes == rb.uploaded_bytes
        assert ra.wire_bytes == rb.wire_bytes
        assert ra.participants == rb.participants
        assert ra.survivors == rb.survivors
        assert ra.skipped == rb.skipped
        assert ra.retries == rb.retries
        assert ra.abandoned_bytes == rb.abandoned_bytes
        np.testing.assert_array_equal(ra.dropout_rates, rb.dropout_rates)


# --- RunState round-trip ------------------------------------------------------

def test_run_state_round_trip_exact(tmp_path):
    arrays = {"global": {"w": jnp.arange(6.0).reshape(2, 3)},
              "losses": np.asarray([0.1, 1 / 3], np.float64)}
    history = [RoundRecord(round=1, sim_time=1.23456789012345e2,
                           sim_round_time=1.0, host_wall_time=0.5,
                           mean_loss=1 / 3, uploaded_bytes=1e5,
                           wire_bytes=9.9e4, uploaded_fraction=0.5,
                           participants=4,
                           dropout_rates=np.asarray([0.1, 0.2]))]
    path = tmp_path / "state.npz"
    ckpt.save_run_state(path, ckpt.RunState(
        round=1, arrays=arrays, history=history,
        extra={"sim_time": 123.5}))
    st = ckpt.load_run_state(path, arrays)
    assert st.round == 1
    assert st.extra["sim_time"] == 123.5
    assert _trees_equal(st.arrays["global"], arrays["global"])
    assert st.arrays["losses"].dtype == np.float64
    np.testing.assert_array_equal(st.arrays["losses"], arrays["losses"])
    got = st.history[0]
    assert got.sim_time == history[0].sim_time          # f64 repr exact
    assert got.mean_loss == history[0].mean_loss
    np.testing.assert_array_equal(got.dropout_rates,
                                  history[0].dropout_rates)


def test_load_run_state_rejects_wrong_file(tmp_path):
    path = tmp_path / "plain.npz"
    ckpt.save_checkpoint(path, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError, match="not a RunState snapshot"):
        ckpt.load_run_state(path, {"w": jnp.zeros(3)})


# --- protocol path: inert contract + resume bit-identity ----------------------

@pytest.mark.parametrize("batched", [True, False])
def test_checkpointing_on_is_inert_protocol(batched, tmp_path):
    n = 5
    params = _params(jax.random.PRNGKey(0))
    kw = dict(rounds=4, a_server=0.6, h=2, seed=0, batched=batched)
    ref = run_scheme("feddd", params, _tel(n), _ltf, None, **kw)
    got = run_scheme("feddd", params, _tel(n), _ltf, None,
                     checkpoint_every=1,
                     checkpoint_path=str(tmp_path / "ck.npz"), **kw)
    assert _trees_equal(ref.global_params, got.global_params)
    _records_identical(ref.history, got.history)


@pytest.mark.parametrize("batched", [True, False])
def test_resume_bit_identical_protocol(batched, tmp_path):
    n = 5
    params = _params(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    kw = dict(a_server=0.6, h=2, seed=0, batched=batched)
    full = run_scheme("feddd", params, _tel(n), _ltf, None, rounds=6, **kw)
    run_scheme("feddd", params, _tel(n), _ltf, None, rounds=3,
               checkpoint_every=1, checkpoint_path=path, **kw)
    resumed = run_scheme("feddd", params, _tel(n), _ltf, None, rounds=6,
                         checkpoint_every=1, checkpoint_path=path,
                         resume_from=path, **kw)
    assert _trees_equal(full.global_params, resumed.global_params)
    _records_identical(full.history, resumed.history)


# --- sim path: faults + outages + obs, ragged fleets --------------------------

def _sim_kw(n, tmp_path=None, log=None):
    faults = CellOutageModel(
        n, OutageConfig(cells=2, p_out=0.3, p_back=0.5, seed=3),
        inner=RandomFaults(FaultConfig(crash_rate=0.15, loss_rate=0.1,
                                       seed=5)))
    kw = dict(sim=SimConfig(policy="sync"), faults=faults,
              a_server=0.6, h=2, seed=0)
    if log is not None:
        from repro.obs import ObsConfig
        kw["obs"] = ObsConfig(enabled=True,
                              jsonl_path=str(tmp_path / log))
    return kw


def test_resume_bit_identical_sim_with_faults_and_obs(tmp_path):
    """THE survivability acceptance: interrupt a faulty, outage-ridden,
    observability-enabled wave run; the resumed run reproduces the
    uninterrupted history, event trace, and params bit for bit."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    full = run_sim("feddd", params, _tel(n), _ltf, None, rounds=6,
                   **_sim_kw(n, tmp_path, "full.jsonl"))
    run_sim("feddd", params, _tel(n), _ltf, None, rounds=3,
            checkpoint_every=1, checkpoint_path=path,
            **_sim_kw(n, tmp_path, "part.jsonl"))
    resumed = run_sim("feddd", params, _tel(n), _ltf, None, rounds=6,
                      checkpoint_every=1, checkpoint_path=path,
                      resume_from=path,
                      **_sim_kw(n, tmp_path, "resumed.jsonl"))
    assert _trees_equal(full.global_params, resumed.global_params)
    _records_identical(full.history, resumed.history)
    assert full.event_trace == resumed.event_trace


def test_resume_bit_identical_ragged_wave_fleet(tmp_path):
    """The grouped WAVE fleet checkpoints via its unstacked client-param
    export: a ragged resume is bit-identical too."""
    n, widths = 4, (12, 8)
    gp = _params(jax.random.PRNGKey(0), max(widths))
    clients = [_params(jax.random.PRNGKey(100 + i), widths[i % 2])
               for i in range(n)]
    tel = _tel(n, [_nbytes(p) for p in clients])
    path = str(tmp_path / "ck.npz")
    kw = dict(sim=SimConfig(policy="sync"), client_params=clients,
              faults=RandomFaults(FaultConfig(crash_rate=0.2, seed=4)),
              a_server=0.6, h=2, seed=0)
    full = run_sim("feddd", gp, tel, _ltf, None, rounds=5, **kw)
    run_sim("feddd", gp, tel, _ltf, None, rounds=2,
            checkpoint_every=1, checkpoint_path=path, **kw)
    resumed = run_sim("feddd", gp, tel, _ltf, None, rounds=5,
                      checkpoint_every=1, checkpoint_path=path,
                      resume_from=path, **kw)
    assert _trees_equal(full.global_params, resumed.global_params)
    _records_identical(full.history, resumed.history)
    assert full.event_trace == resumed.event_trace


def test_checkpointing_on_is_inert_sim(tmp_path):
    n = 5
    params = _params(jax.random.PRNGKey(0))
    ref = run_sim("feddd", params, _tel(n), _ltf, None, rounds=4,
                  **_sim_kw(n))
    got = run_sim("feddd", params, _tel(n), _ltf, None, rounds=4,
                  checkpoint_every=2,
                  checkpoint_path=str(tmp_path / "ck.npz"), **_sim_kw(n))
    assert _trees_equal(ref.global_params, got.global_params)
    _records_identical(ref.history, got.history)
    assert ref.event_trace == got.event_trace


# --- routing guards -----------------------------------------------------------

def test_checkpoint_config_validation(tmp_path):
    with pytest.raises(ValueError, match="checkpoint_every must be >= 1"):
        ProtocolConfig(checkpoint_every=0, checkpoint_path="x")
    with pytest.raises(ValueError, match="requires\\s+checkpoint_path"):
        ProtocolConfig(checkpoint_every=1)
    with pytest.raises(ValueError, match="dispatch\\s+boundaries"):
        ProtocolConfig(checkpoint_every=1, checkpoint_path="x",
                       rounds_per_dispatch=2, allocator="jax")


def test_unsupported_executors_raise_loudly(tmp_path):
    n = 4
    params = _params(jax.random.PRNGKey(0))
    kw = dict(rounds=2, a_server=0.6, h=2, seed=0, checkpoint_every=1,
              checkpoint_path=str(tmp_path / "ck.npz"))
    # sharded protocol executor: per-shard device state not captured yet
    with pytest.raises(NotImplementedError, match="batched-engine"):
        run_scheme("feddd", params, _tel(n), _ltf, None, mesh=1, **kw)
    # grouped protocol executor: same
    widths = (12, 8)
    gp = _params(jax.random.PRNGKey(0), max(widths))
    clients = [_params(jax.random.PRNGKey(100 + i), widths[i % 2])
               for i in range(n)]
    with pytest.raises(NotImplementedError, match="batched-engine"):
        run_scheme("feddd", gp, _tel(n, [_nbytes(p) for p in clients]),
                   _ltf, None, client_params=clients, **kw)
    # async sim policy: merges have no wave-round boundary
    with pytest.raises(ValueError, match="wave-round boundaries"):
        run_sim("feddd", params, _tel(n), _ltf, None,
                sim=SimConfig(policy="async"), **kw)


# --- the SIGKILL acceptance ---------------------------------------------------

_KILL_RESUME_SNIPPET = r"""
import hashlib
import os
import signal
import sys

import numpy as np
import jax, jax.numpy as jnp

from repro.core.allocation import ClientTelemetry
from repro.obs import ObsConfig
from repro.sim import (CellOutageModel, FaultConfig, OutageConfig,
                       RandomFaults, SimConfig, run_sim)

mode, ckpt_path, log_path = sys.argv[1], sys.argv[2], sys.argv[3]
N, ROUNDS = 5, 6

def params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"fc0": {"w": jax.random.normal(k1, (20, 12)),
                    "b": jnp.zeros(12)},
            "fc1": {"w": jax.random.normal(k2, (12, 5)),
                    "b": jnp.zeros(5)}}

def tel():
    rng = np.random.default_rng(0)
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params())))
    return ClientTelemetry(
        model_bytes=np.full(N, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, N),
        downlink_rate=rng.uniform(5e3, 2e4, N),
        compute_latency=rng.uniform(1.0, 5.0, N),
        num_samples=rng.integers(10, 50, N).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, N),
        train_loss=np.ones(N))

def ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))

calls = []
def eval_fn(p):
    calls.append(1)
    if mode == "crash" and len(calls) == 4:
        os.kill(os.getpid(), signal.SIGKILL)    # uncatchable, mid-round 4
    return {"probe": float(jnp.sum(p["fc1"]["b"]))}

faults = CellOutageModel(
    N, OutageConfig(cells=2, p_out=0.3, p_back=0.5, seed=3),
    inner=RandomFaults(FaultConfig(crash_rate=0.15, loss_rate=0.1,
                                   seed=5)))
kw = dict(sim=SimConfig(policy="sync"), faults=faults, rounds=ROUNDS,
          a_server=0.6, h=2, seed=0,
          obs=ObsConfig(enabled=True, jsonl_path=log_path))
if mode in ("crash", "resume"):
    kw.update(checkpoint_every=1, checkpoint_path=ckpt_path)
if mode == "resume":
    kw.update(resume_from=ckpt_path)

res = run_sim("feddd", params(), tel(), ltf, eval_fn, **kw)

h = hashlib.sha256()
times = np.asarray([e[0] for e in res.event_trace])
h.update(times.tobytes())
h.update(",".join(f"{e[1]}:{e[2]}" for e in res.event_trace).encode())
rec = np.asarray([[r.sim_time, r.mean_loss, r.participants, r.survivors,
                   r.retries, r.abandoned_bytes, float(r.skipped)]
                  for r in res.history])
h.update(rec.tobytes())
h.update(np.concatenate([np.asarray(r.dropout_rates)
                         for r in res.history]).tobytes())
for leaf in jax.tree_util.tree_leaves(res.global_params):
    h.update(np.asarray(leaf).tobytes())
print(h.hexdigest())
"""


def _run_mode(mode, tmp_path, check=True):
    out = subprocess.run(
        [sys.executable, "-c", _KILL_RESUME_SNIPPET, mode,
         str(tmp_path / "ck.npz"), str(tmp_path / f"{mode}.jsonl")],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/tmp"},
        check=False)
    if check:
        assert out.returncode == 0, out.stderr[-2000:]
    return out


def test_sigkill_resume_bit_identical_digest(tmp_path):
    """A subprocess SIGKILL'd mid-round-4 of a faulty obs-enabled run,
    then resumed from its last atomic snapshot, produces the IDENTICAL
    run digest (event trace + records + dropout rates + params) as an
    uninterrupted process."""
    full = _run_mode("full", tmp_path)
    crashed = _run_mode("crash", tmp_path, check=False)
    assert crashed.returncode == -9         # genuinely SIGKILLed
    assert (tmp_path / "ck.npz").exists()   # ... after >= 1 snapshot
    resumed = _run_mode("resume", tmp_path)
    assert resumed.stdout.strip() == full.stdout.strip()
    assert len(full.stdout.strip()) == 64
