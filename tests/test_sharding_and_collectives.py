"""Sharding-rule resolution + sparse collectives under shard_map.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` so the main pytest process keeps
a single device (conftest policy)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_sub(code: str, devices: int = 4) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_spec_divisibility_dropping():
    """Non-divisible dims must drop to replication, never error."""
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import spec
    mesh = jax.make_mesh((2,2), ("data","model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    with jax.sharding.set_mesh(mesh):
        # kv_heads=3 not divisible by model=2 -> None
        s = spec("batch","kv_seq","kv_heads",None, shape=(4,16,3,8))
        assert s[2] is None, s
        # vocab 10 divisible by 2 -> model
        s2 = spec("vocab","embed", shape=(10,8))
        assert s2[0] == "model", s2
        # batch=1 -> dropped
        s3 = spec("batch",None, shape=(1,8))
        assert s3[0] is None, s3
        print("OK")
    """
    assert "OK" in _run_sub(code)


def test_spec_mesh_axis_dedup():
    code = """
    import jax
    from repro.models.sharding import spec, set_rules, reset_rules
    mesh = jax.make_mesh((2,2), ("data","model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    with jax.sharding.set_mesh(mesh):
        set_rules(kv_seq="model")
        s = spec("batch","kv_seq","kv_heads",None, shape=(4,16,2,8))
        flat = [a for a in s if a is not None]
        # "model" must appear at most once
        assert flat.count("model") <= 1, s
        reset_rules()
        print("OK")
    """
    assert "OK" in _run_sub(code)


def test_sparse_allgather_mean_matches_dense_when_full():
    """k = full channels -> sparse collective == dense weighted mean."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.sparse_collective import (sparse_allgather_mean,
                                              dense_allreduce_mean)
    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    C, F = 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (4, C, F))
    sc = jax.random.uniform(jax.random.PRNGKey(1), (4, C))
    def f(xl, sl):
        return sparse_allgather_mean(xl[0], sl[0], k=C, axis_name="pod")[None]
    y = jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                      out_specs=P("pod"))(x, sc)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x.mean(0)),
                               rtol=1e-5)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_sparse_allgather_mean_partial_k():
    """With k < C: selected channels average over their contributors;
    channels nobody selected keep local values."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.sparse_collective import sparse_allgather_mean
    P_, C, F, K = 4, 8, 4, 2
    x = jnp.arange(P_*C*F, dtype=jnp.float32).reshape(P_, C, F)
    # every pod ranks channel (pod_id) and (pod_id+1)%C highest
    sc = jnp.zeros((P_, C))
    for p in range(P_):
        sc = sc.at[p, p].set(2.0).at[p, (p+1) % C].set(1.0)
    mesh = jax.make_mesh((P_,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    def f(xl, sl):
        return sparse_allgather_mean(xl[0], sl[0], k=K, axis_name="pod")[None]
    y = np.asarray(jax.shard_map(
        f, mesh=mesh, in_specs=(P("pod"), P("pod")),
        out_specs=P("pod"))(x, sc))
    xn = np.asarray(x)
    # channel 0 selected only by pod 0 -> equals pod0's row everywhere
    for p in range(P_):
        np.testing.assert_allclose(y[p, 0], xn[0, 0], rtol=1e-6)
    # channel 1 selected by pods 0 and 1 -> mean of their rows
    for p in range(P_):
        np.testing.assert_allclose(y[p, 1], (xn[0,1]+xn[1,1])/2, rtol=1e-6)
    # channels 6,7 selected by nobody (P_=4 pods cover 0..4) -> local kept
    for p in range(P_):
        np.testing.assert_allclose(y[p, 6], xn[p, 6], rtol=1e-6)
        np.testing.assert_allclose(y[p, 7], xn[p, 7], rtol=1e-6)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_make_production_mesh_shapes():
    code = """
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh(multi_pod=False)
    assert m1.devices.size == 256 and m1.axis_names == ("data","model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.size == 512
    assert m2.axis_names == ("pod","data","model")
    print("OK")
    """
    assert "OK" in _run_sub(code, devices=512)


def test_moe_ep_matches_gspmd_path():
    """The explicit expert-parallel shard_map MoE must produce the same
    outputs as the single-device blocked path (no capacity drops)."""
    code = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import moe
    from repro.models.config import MoEConfig

    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                     capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, 64, mcfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 64))

    y_ref, aux_ref = moe._apply_moe_gspmd(p, x, mcfg, "swiglu")

    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.sharding.set_mesh(mesh):
        assert moe._ep_mesh_info(256, 4) is not None
        y_ep, aux_ep = jax.jit(
            lambda pp, xx: moe.apply_moe(pp, xx, mcfg, "swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-5)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_chunked_attention_used_at_long_seq():
    """self_attention must route through the chunked path at >= 8192 and
    produce finite outputs."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = dataclasses.replace(get_config("granite_3_8b", reduced=True),
                              param_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = A.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 24, cfg.d_model)) * 0.1
    # force the chunked path by lowering the threshold
    old = A.FLASH_MIN_SEQ
    try:
        A.FLASH_MIN_SEQ = 16
        y_chunked = A.self_attention(p, cfg, x, mode="full")
        A.FLASH_MIN_SEQ = 10_000
        y_dense = A.self_attention(p, cfg, x, mode="full")
    finally:
        A.FLASH_MIN_SEQ = old
    import numpy as np
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_dense),
                               rtol=3e-5, atol=3e-6)
