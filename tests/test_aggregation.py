"""Sparse aggregation (Eq. (4)) + client updates (Eq. (5)/(6))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import aggregation, selection
from repro.core.convergence import estimate_epsilon

pytestmark = pytest.mark.flcore


def _clients(key, n, shape=(6, 10)):
    ks = jax.random.split(key, n)
    return [{"w": jax.random.normal(k, shape)} for k in ks]


def test_full_masks_reduce_to_fedavg():
    key = jax.random.PRNGKey(0)
    ps = _clients(key, 4)
    ones = [{"w": jnp.ones((1, 10))} for _ in ps]
    wts = [1.0, 2.0, 3.0, 4.0]
    got = aggregation.aggregate_sparse(ps, ones, wts)
    want = aggregation.fedavg_aggregate(ps, wts)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               rtol=1e-5)


def test_uncovered_positions_keep_prev_global():
    key = jax.random.PRNGKey(1)
    ps = _clients(key, 2)
    # both clients drop channel 0
    m = jnp.ones((1, 10)).at[0, 0].set(0.0)
    masks = [{"w": m}, {"w": m}]
    prev = {"w": jnp.full((6, 10), 7.0)}
    got = aggregation.aggregate_sparse(ps, masks, [1.0, 1.0],
                                       prev_global=prev)
    np.testing.assert_allclose(np.asarray(got["w"][:, 0]), 7.0)
    assert not np.allclose(np.asarray(got["w"][:, 1]), 7.0)


def test_eq4_weighted_elementwise_division():
    p1 = {"w": jnp.ones((2, 2))}
    p2 = {"w": 3.0 * jnp.ones((2, 2))}
    m1 = {"w": jnp.asarray([[1.0, 0.0]])}     # client 1 uploads ch 0 only
    m2 = {"w": jnp.asarray([[1.0, 1.0]])}
    got = aggregation.aggregate_sparse([p1, p2], [m1, m2], [1.0, 1.0])
    # ch0: (1+3)/2 = 2 ; ch1: 3/1 = 3
    np.testing.assert_allclose(np.asarray(got["w"]),
                               [[2.0, 3.0], [2.0, 3.0]])


def test_client_update_sparse_eq5():
    g = {"w": jnp.full((2, 4), 10.0)}
    l = {"w": jnp.full((2, 4), 1.0)}
    m = {"w": jnp.asarray([[1.0, 0.0, 1.0, 0.0]])}
    got = aggregation.client_update_sparse(g, l, m)
    np.testing.assert_allclose(np.asarray(got["w"]),
                               [[10, 1, 10, 1], [10, 1, 10, 1]])


def test_client_update_full_eq6():
    g = {"w": jnp.ones((2, 2))}
    l = {"w": jnp.zeros((2, 2))}
    got = aggregation.client_update_full(g, l)
    np.testing.assert_allclose(np.asarray(got["w"]), 1.0)


def test_epsilon_zero_for_full_masks():
    key = jax.random.PRNGKey(3)
    ps = _clients(key, 3)
    ones = [{"w": jnp.ones((1, 10))} for _ in ps]
    eps = float(estimate_epsilon(ps, ones))
    assert eps < 1e-10


def test_epsilon_grows_with_dropout():
    key = jax.random.PRNGKey(4)
    ps = _clients(key, 5, shape=(20, 40))
    old = {"w": jnp.zeros((20, 40))}
    eps_at = {}
    for rate in (0.2, 0.8):
        masks = [selection.build_masks(old, p, jnp.asarray(rate),
                                       config=selection.SelectionConfig(
                                           scheme="random"),
                                       rng=jax.random.fold_in(key, i))
                 for i, p in enumerate(ps)]
        eps_at[rate] = float(estimate_epsilon(ps, masks))
    assert eps_at[0.8] > eps_at[0.2]


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 6), c=st.integers(2, 12), seed=st.integers(0, 99))
def test_property_kernel_path_matches_jnp_path(n, c, seed):
    key = jax.random.PRNGKey(seed)
    ps = _clients(key, n, shape=(64, c))
    masks = [{"w": (jax.random.uniform(jax.random.fold_in(key, 50 + i),
                                       (1, c)) > 0.4).astype(jnp.float32)}
             for i in range(n)]
    wts = list(np.random.default_rng(seed).uniform(0.5, 2.0, n))
    a = aggregation.aggregate_sparse(ps, masks, wts, use_kernel=False)
    b = aggregation.aggregate_sparse(ps, masks, wts, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=2e-5, atol=1e-6)
