"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
variant of each family runs one forward + one train step on CPU; output
shapes and NaN-freedom asserted."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.optim import adamw

B, S = 2, 16


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["enc_frames"] = jax.random.normal(
            key, (B, 24, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_no_nan(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, cfg, batch)
    s_out = batch["tokens"].shape[1]
    assert logits.shape == (B, s_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    opt = adamw(1e-3)
    state = lm.init_train_state(key, cfg, opt)
    step = jax.jit(lm.make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    state2, metrics = step(state, batch)
    assert int(state2.step) == 1
    assert float(metrics["loss"]) > 0
    assert not bool(jnp.isnan(metrics["loss"]))
    assert not bool(jnp.isnan(metrics["grad_norm"]))
    # a second step must reduce nothing to NaN either
    _, m2 = step(state2, batch)
    assert not bool(jnp.isnan(m2["loss"]))


@pytest.mark.parametrize("arch", ["granite_3_8b", "xlstm_1p3b",
                                  "jamba_1p5_large_398b", "whisper_medium"])
def test_reduced_serve_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    enc = (jax.random.normal(key, (B, 24, cfg.d_model), jnp.bfloat16)
           if cfg.is_encdec else None)
    ds = lm.init_decode_state(params, cfg, B, 32, enc_frames=enc)
    serve = jax.jit(lm.make_serve_step(cfg))
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, ds2 = serve(params, ds, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(ds2.pos) == 1


def test_loss_decreases_over_steps():
    """Sanity: training a tiny model on a fixed batch reduces loss."""
    cfg = get_config("granite_moe_1b_a400m", reduced=True)
    key = jax.random.PRNGKey(0)
    opt = adamw(3e-3)
    state = lm.init_train_state(key, cfg, opt)
    step = jax.jit(lm.make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_microbatched_grads_match_full_batch():
    import dataclasses
    cfg = dataclasses.replace(get_config("chatglm3_6b", reduced=True),
                              param_dtype="float32")
    key = jax.random.PRNGKey(0)
    from repro.optim import sgd
    opt = sgd(0.1)
    state = lm.init_train_state(key, cfg, opt)
    batch = {"tokens": jax.random.randint(key, (4, 8), 0, cfg.vocab_size)}
    s1, m1 = jax.jit(lm.make_train_step(cfg, opt))(state, batch)
    s2, m2 = jax.jit(lm.make_train_step(cfg, opt,
                                        num_microbatches=2))(state, batch)
    import numpy as np
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
