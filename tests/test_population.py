"""Population-scale serving (repro/population): identity contract,
availability/cohort determinism, sticky client state, and observability.

Pins the subsystem's contracts:

* identity — a population whose size equals the fleet, with always-on
  availability and the default sampler, is BIT-identical to today's
  fleet runs on the batched, grouped, and scanned engine paths (event
  trace, round records, and trained global params all exact);
* determinism — availability draws and cohort samples are pure
  functions of ``(seed, tag, epoch, client)``: prefix/permutation
  invariant per client (hypothesis) and identical across processes
  (subprocess digests, mirroring tests/test_faults.py);
* sampling — every sampler returns exactly ``cohort_size`` sorted ids,
  topping up deterministically when availability leaves the online set
  short, and Oort's exploit slots track the sticky utility;
* state — the store's economy arrays update only for the sampled
  cohort, and ``cold_start="mean"`` swaps never-seen cohort members'
  LP telemetry for population means;
* obs — population runs emit per-round ``cohort`` events and the
  report CLI renders a participation section from them.
"""

import hashlib
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.core import FedDDServer, ProtocolConfig
from repro.core.allocation import ClientTelemetry
from repro.obs import ObsConfig, read_events
from repro.obs import report as obs_report
from repro.population import (AlwaysOn, BernoulliAvailability,
                              DiurnalAvailability, Population,
                              TraceAvailability, make_availability,
                              make_sampler, uniform_draws)
from repro.population.availability import _TAG_AVAIL
from repro.sim import AsyncPolicy, SimConfig, run_sim

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key):
    k1, k2 = jax.random.split(key)
    return {
        "fc0": {"w": jax.random.normal(k1, (20, 12)), "b": jnp.zeros(12)},
        "fc1": {"w": jax.random.normal(k2, (12, 5)), "b": jnp.zeros(5)},
    }


def _sub_params(key, width):
    k1, k2 = jax.random.split(key)
    return {
        "fc0": {"w": jax.random.normal(k1, (20, width)),
                "b": jnp.zeros(width)},
        "fc1": {"w": jax.random.normal(k2, (width, 5)), "b": jnp.zeros(5)},
    }


def _tel(n, seed=0):
    rng = np.random.default_rng(seed)
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(
                           _params(jax.random.PRNGKey(0)))))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    """Deterministic pseudo-training (no dataset needed)."""
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _assert_runs_identical(ref, got):
    """Bit-identity: event trace, per-round records, global params."""
    assert ref.event_trace == got.event_trace
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time
        assert rr.mean_loss == rg.mean_loss
        assert rr.uploaded_bytes == rg.uploaded_bytes
        assert rr.wire_bytes == rg.wire_bytes
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
    assert _trees_equal(ref.global_params, got.global_params)


# --- identity contract: population == fleet, bit for bit ---------------------

def test_identity_contract_batched_bit_exact():
    """population=N + always-on + default sampler + cohort==population
    reproduces today's stacked-fleet sim runs exactly — event trace,
    round records, and trained global params."""
    n = 6
    kw = dict(rounds=5, a_server=0.6, h=3, seed=0,
              sim=SimConfig(policy="sync"))
    ref = run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(n),
                  _ltf, None, **kw)
    got = run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(n),
                  _ltf, None, population=Population(_tel(n)), **kw)
    _assert_runs_identical(ref, got)


def test_identity_contract_grouped_bit_exact():
    """Same contract on the grouped (ragged heterogeneous-fleet) path:
    per-client param trees of different widths route through
    _GroupedWaveFleet, and the population store holds each client's
    own-width tree."""
    n = 4
    widths = (12, 8, 12, 6)
    gp = _sub_params(jax.random.PRNGKey(0), 12)
    clients = [_sub_params(jax.random.PRNGKey(100 + i), w)
               for i, w in enumerate(widths)]
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0,
              sim=SimConfig(policy="sync"))
    ref = run_sim("feddd", gp, _tel(n), _ltf, None,
                  client_params=clients, **kw)
    got = run_sim("feddd", gp, _tel(n), _ltf, None,
                  client_params=clients, population=Population(_tel(n)),
                  **kw)
    _assert_runs_identical(ref, got)


def test_identity_contract_scanned_path_bit_exact():
    """Same contract against the scanned driver: with a key-free trainer
    (the same arithmetic whether vmapped inside the lax.scan dispatch or
    run per client in the sim) the population-identity sim reproduces
    FedDDServer's rounds_per_dispatch>1 path exactly — Eq. (12) clock,
    jax-allocator dropout rates, losses, and global params."""
    n = 8

    def ltf(p, idx, key):
        new = jax.tree_util.tree_map(lambda x: x * jnp.float32(0.99), p)
        return new, jnp.mean(jnp.abs(new["fc0"]["w"]))

    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * jnp.float32(0.99), stacked)
        w = new["fc0"]["w"]
        return new, jnp.mean(jnp.abs(w), axis=tuple(range(1, w.ndim)))

    kw = dict(scheme="feddd", rounds=7, a_server=0.6, h=3, seed=0,
              allocator="jax")
    scan = FedDDServer(_params(jax.random.PRNGKey(0)),
                       ProtocolConfig(rounds_per_dispatch=4, **kw),
                       _tel(n)).run(batched_train_fn=batched)
    pop = run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(n),
                  ltf, None, population=Population(_tel(n)),
                  sim=SimConfig(policy="sync"),
                  rounds=7, a_server=0.6, h=3, seed=0, allocator="jax")
    for hs, hp in zip(scan.history, pop.history):
        assert hs.mean_loss == hp.mean_loss
        assert hs.sim_time == hp.sim_time
        np.testing.assert_array_equal(np.asarray(hs.dropout_rates),
                                      np.asarray(hp.dropout_rates))
    assert _trees_equal(scan.global_params, pop.global_params)


# --- churn: cohorts smaller than the population ------------------------------

def test_churn_run_updates_sticky_state():
    """A 100-client population served 8 at a time under Bernoulli
    availability reaches far more than one cohort's worth of clients,
    and the store's economy arrays update only for sampled clients."""
    P, K, R = 100, 8, 5
    pop = Population(_tel(P), availability="bernoulli", sampler="uniform",
                     seed=3)
    res = run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(P),
                  _ltf, None, population=pop, cohort_size=K,
                  rounds=R, a_server=0.6, h=3, seed=0,
                  sim=SimConfig(policy="sync"))
    assert len(res.history) == R
    served = int(pop.seen.sum())
    assert K < served <= K * R
    # economy: only served clients accrue state
    assert int(pop.rounds_participated.sum()) > 0
    assert not pop.rounds_participated[~pop.seen].any()
    assert not pop.uploaded_bytes[~pop.seen].any()
    assert (pop.last_round[~pop.seen] == -1).all()
    assert pop.uploaded_bytes[pop.rounds_participated > 0].min() > 0
    # served clients' learning state was folded back (loss left the
    # all-ones prior; dropout/params parked for their next cohort)
    assert not np.array_equal(pop.loss[pop.seen], np.ones(served))
    assert len(pop._params) == served


def test_oort_cohorts_follow_utility():
    """The oort sampler's exploit slots pick the highest sticky-utility
    seen clients; exploration slots reach never-seen clients."""
    P, K = 40, 10
    pop = Population(_tel(P), sampler=make_sampler("oort", explore=0.2),
                     seed=1)
    first = pop.sample_cohort(0, K)
    assert len(first) == K and pop.first_contact(first) == K
    # mark a cohort served with huge utility for a known subset
    pop.record_round(0, first,
                     arrived=np.ones(K, bool), failed=np.zeros(K, bool),
                     losses=np.full(K, 0.5), uplink_bytes=np.full(K, 1.0),
                     utilities=np.full(K, 1e6))
    nxt = pop.sample_cohort(1, K)
    assert len(nxt) == K
    # 8 exploit slots re-pick the utility leaders, 2 explore slots are
    # reserved for never-seen clients
    assert len(np.intersect1d(nxt, first)) == 8
    assert pop.first_contact(nxt) == 2


# --- determinism: keyed draws ------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_uniform_draws_depend_only_on_own_client(data):
    """Each client's draw is a pure function of (seed, tag, epoch,
    client): restricting to a prefix, permuting, or subsetting the
    client axis never changes any individual draw."""
    seed = data.draw(st.integers(min_value=0, max_value=2**32 - 1))
    epoch = data.draw(st.integers(min_value=0, max_value=10_000))
    n = data.draw(st.integers(min_value=2, max_value=64))
    full = uniform_draws(seed, _TAG_AVAIL, epoch, np.arange(n))
    assert ((full >= 0.0) & (full < 1.0)).all()
    cut = data.draw(st.integers(min_value=1, max_value=n))
    np.testing.assert_array_equal(
        uniform_draws(seed, _TAG_AVAIL, epoch, np.arange(cut)),
        full[:cut])
    perm = np.asarray(data.draw(st.permutations(list(range(n)))))
    np.testing.assert_array_equal(
        uniform_draws(seed, _TAG_AVAIL, epoch, perm), full[perm])
    # availability masks restrict the same way
    model = BernoulliAvailability(n, p=0.5, seed=seed)
    sub = np.asarray(sorted(data.draw(
        st.sets(st.integers(min_value=0, max_value=n - 1), min_size=1))))
    np.testing.assert_array_equal(model.online(epoch, clients=sub),
                                  model.online(epoch)[sub])


def test_availability_models_behave():
    n = 50
    assert AlwaysOn(n).online(3).all()
    assert not BernoulliAvailability(n, p=0.0).online(0).any()
    assert BernoulliAvailability(n, p=1.0).online(0).all()
    # diurnal: per-client phases stagger on/off; duty bounds the online
    # fraction over a full period
    d = DiurnalAvailability(n, period=8.0, duty=0.5, seed=2)
    frac = np.mean([d.online(e).mean() for e in range(8)])
    assert 0.3 < frac < 0.7
    # subset consistency for the deterministic models too
    sub = np.array([0, 7, 31])
    np.testing.assert_array_equal(d.online(5, clients=sub),
                                  d.online(5)[sub])
    tr = TraceAvailability(np.eye(3, dtype=bool))
    np.testing.assert_array_equal(tr.online(4), np.eye(3, dtype=bool)[1])
    with pytest.raises(ValueError, match="unknown availability"):
        make_availability("nope", 4)
    with pytest.raises(ValueError, match="covers"):
        make_availability(AlwaysOn(3), 4)


_POP_DIGEST_SNIPPET = r"""
import hashlib
import numpy as np
from repro.core.allocation import ClientTelemetry
from repro.population import Population, make_availability, uniform_draws
from repro.population.availability import _TAG_AVAIL

h = hashlib.sha256()
ids = np.arange(257)
for epoch in (0, 1, 5, 1000):
    h.update(uniform_draws(7, _TAG_AVAIL, epoch, ids).tobytes())
for name, kw in (("bernoulli", {"p": 0.4}), ("diurnal", {"duty": 0.3})):
    m = make_availability(name, 257, seed=11, **kw)
    for epoch in range(6):
        h.update(np.packbits(m.online(epoch)).tobytes())

def tel(n):
    rng = np.random.default_rng(5)
    return ClientTelemetry(
        model_bytes=np.full(n, 1000.0),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

for sampler in ("uniform", "weighted", "oort"):
    pop = Population(tel(97), availability="bernoulli", sampler=sampler,
                     seed=3)
    for epoch in range(5):
        cohort = pop.sample_cohort(epoch, 16)
        h.update(cohort.astype(np.int64).tobytes())
        pop.record_round(epoch, cohort,
                         arrived=np.ones(16, bool),
                         failed=np.zeros(16, bool),
                         losses=np.linspace(0.1, 1.0, 16),
                         uplink_bytes=np.full(16, 10.0),
                         utilities=np.linspace(1.0, 2.0, 16))
print(h.hexdigest())
"""


def test_population_deterministic_across_processes():
    """Availability draws and cohort sampling (with evolving sticky
    state) hash identically in two fresh interpreters — the keyed-tuple
    RNG has no hidden process-local state."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _POP_DIGEST_SNIPPET],
            capture_output=True, text=True, timeout=300,
            env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu", "HOME": "/tmp"})
        assert out.returncode == 0, out.stderr
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64


# --- samplers: exact-k, top-up, guards ---------------------------------------

def test_samplers_return_exactly_k_sorted():
    pop = Population(_tel(30), seed=0)
    online = np.arange(0, 30, 2, dtype=np.int64)       # 15 online
    for name in ("uniform", "weighted", "oort"):
        s = make_sampler(name, seed=4)
        ids = s.sample(2, 10, online, pop)
        assert len(ids) == 10
        assert (np.sort(ids) == ids).all()
        assert len(np.unique(ids)) == 10
        assert np.isin(ids, online).all()              # enough online
        # scarce online set: deterministic top-up keeps k fixed
        ids = s.sample(2, 10, online[:4], pop)
        assert len(ids) == 10 and len(np.unique(ids)) == 10
        assert np.isin(online[:4], ids).all()          # online come first


def test_sampler_top_up_prefers_recent_participants():
    pop = Population(_tel(20), seed=0)
    pop.last_round[15] = 9          # most recent participant offline
    pop.last_round[12] = 4
    s = make_sampler("uniform", seed=0)
    ids = s.sample(0, 5, np.array([2, 7], dtype=np.int64), pop)
    # both online ids, then offline by last_round desc / id asc
    np.testing.assert_array_equal(ids, np.sort(np.array([2, 7, 15, 12, 0])))


def test_identity_sampler_requires_full_population():
    pop = Population(_tel(5), sampler="identity")
    np.testing.assert_array_equal(pop.sample_cohort(0, 5), np.arange(5))
    with pytest.raises(ValueError, match="identity sampler"):
        pop.sample_cohort(0, 3)
    with pytest.raises(ValueError, match="unknown cohort sampler"):
        make_sampler("nope")


# --- store: cold start and LP integration ------------------------------------

def test_cold_start_mean_replaces_unseen_lp_rows():
    """Under cold_start='mean', never-seen cohort members enter the
    Eq. (9)-(11) solve with population-mean telemetry (and the mean of
    the seen members' observed losses); seen members keep their rows.
    The default 'prior' passes telemetry through untouched."""
    P = 12
    base = _tel(P, seed=7)
    pop = Population(base, cold_start="mean")
    ids = np.array([0, 3, 5, 9])
    pop.seen[[0, 5]] = True
    cohort_tel = base.subset(ids)
    cohort_tel = cohort_tel.__class__(**{
        **{f: getattr(cohort_tel, f) for f in (
            "model_bytes", "uplink_rate", "downlink_rate",
            "compute_latency", "num_samples", "label_coverage")},
        "train_loss": np.array([0.2, 0.8, 0.4, 0.6])})
    out = pop.lp_telemetry(cohort_tel, ids)
    unseen = np.array([1, 3])                  # positions of ids 3, 9
    seen = np.array([0, 2])
    for f in ("uplink_rate", "downlink_rate", "compute_latency",
              "num_samples", "label_coverage"):
        want = float(np.mean(np.asarray(getattr(base, f), float)))
        np.testing.assert_allclose(
            np.asarray(getattr(out, f))[unseen], want)
        np.testing.assert_array_equal(
            np.asarray(getattr(out, f))[seen],
            np.asarray(getattr(cohort_tel, f))[seen])
    np.testing.assert_allclose(np.asarray(out.train_loss)[unseen],
                               np.mean([0.2, 0.4]))
    # model_bytes is structural — never averaged
    np.testing.assert_array_equal(out.model_bytes, cohort_tel.model_bytes)
    # the default passes through by identity (the bit-exactness lever)
    assert Population(base).lp_telemetry(cohort_tel, ids) is cohort_tel


def test_record_round_economy():
    pop = Population(_tel(10))
    ids = np.array([1, 4, 7])
    assert pop.first_contact(ids) == 3
    pop.record_round(2, ids,
                     arrived=np.array([True, False, True]),
                     failed=np.array([False, True, False]),
                     losses=np.array([0.3, 0.9, 0.5]),
                     uplink_bytes=np.array([100.0, 0.0, 50.0]),
                     utilities=np.array([2.0, np.nan, 3.0]))
    assert pop.first_contact(ids) == 0
    np.testing.assert_array_equal(pop.last_round[[1, 4, 7]], [2, -1, 2])
    np.testing.assert_array_equal(pop.rounds_participated[[1, 4, 7]],
                                  [1, 0, 1])
    np.testing.assert_array_equal(pop.failures[[1, 4, 7]], [0, 1, 0])
    np.testing.assert_array_equal(pop.uploaded_bytes[[1, 4, 7]],
                                  [100.0, 0.0, 50.0])
    np.testing.assert_array_equal(pop.loss[[1, 4, 7]], [0.3, 0.9, 0.5])
    assert pop.utility[1] == 2.0 and pop.utility[7] == 3.0


# --- routing and guards ------------------------------------------------------

def test_population_mode_guards():
    n = 6
    params = _params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="cohort_size requires"):
        run_sim("feddd", params, _tel(n), _ltf, None, cohort_size=4,
                rounds=2, a_server=0.6, h=3, seed=0)
    with pytest.raises(ValueError, match="population size"):
        run_sim("feddd", params, _tel(n), _ltf, None,
                population=Population(_tel(n + 1)),
                rounds=2, a_server=0.6, h=3, seed=0)
    with pytest.raises(ValueError, match="cohort_size"):
        run_sim("feddd", params, _tel(n), _ltf, None,
                population=Population(_tel(n)), cohort_size=n + 1,
                rounds=2, a_server=0.6, h=3, seed=0)
    with pytest.raises(ValueError, match="sync/deadline/retry"):
        run_sim("feddd", params, _tel(n), _ltf, None,
                population=Population(_tel(n)), cohort_size=2,
                sim=SimConfig(policy=AsyncPolicy()),
                rounds=2, a_server=0.6, h=3, seed=0)
    with pytest.raises(ValueError, match="RunState"):
        run_sim("feddd", params, _tel(n), _ltf, None,
                population=Population(_tel(n)), checkpoint_every=1,
                rounds=2, a_server=0.6, h=3, seed=0)
    with pytest.raises(ValueError, match="cold_start"):
        Population(_tel(n), cold_start="bogus")


def test_run_scheme_population_kwarg_routes_to_simulator():
    """run_scheme(population=...) routes through the simulator even
    without an explicit sim config, and ProtocolConfig carries the
    validated population/cohort_size fields."""
    from repro.core import run_scheme
    pop = Population(_tel(10), availability="bernoulli", seed=2)
    res = run_scheme("feddd", _params(jax.random.PRNGKey(0)), _tel(10),
                     _ltf, None, population=pop, cohort_size=4,
                     rounds=3, a_server=0.6, h=3, seed=0)
    assert len(res.history) == 3
    assert int(pop.seen.sum()) >= 4
    with pytest.raises(ValueError):
        ProtocolConfig(cohort_size=4)
    with pytest.raises(ValueError):
        ProtocolConfig(population=10, cohort_size=11)


# --- observability -----------------------------------------------------------

def test_cohort_events_and_report_section(tmp_path, capsys):
    """Population runs emit one ``cohort`` event per round (population,
    cohort ids, contributors, first contacts) and the report CLI renders
    a participation section; fleet-mode logs render no such section."""
    P, K, R = 30, 6, 4
    log = tmp_path / "pop.jsonl"
    pop = Population(_tel(P), availability="bernoulli", seed=5)
    run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(P), _ltf, None,
            population=pop, cohort_size=K, rounds=R,
            a_server=0.6, h=3, seed=0, sim=SimConfig(policy="sync"),
            obs=ObsConfig(enabled=True, jsonl_path=str(log)))
    events = read_events(str(log))
    cohorts = [e for e in events if e.get("event") == "cohort"]
    assert len(cohorts) == R
    for e in cohorts:
        assert e["population"] == P
        assert e["cohort_size"] == K
        assert len(e["cohort"]) == K
        assert set(e["participated"]) <= set(e["cohort"])
        assert 0 <= e["first_contact"] <= K
    assert cohorts[0]["first_contact"] == K      # round 1: all fresh
    rc = obs_report.main([str(log)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "Cohort participation" in out
    assert f"population: {P}" in out
    assert "rounds-participated histogram" in out
    assert "first contacts/round" in out
    # fleet-mode logs don't grow the section
    clean = tmp_path / "fleet.jsonl"
    run_sim("feddd", _params(jax.random.PRNGKey(0)), _tel(4), _ltf, None,
            sim=SimConfig(policy="sync"), rounds=2,
            a_server=0.6, h=3, seed=0,
            obs=ObsConfig(enabled=True, jsonl_path=str(clean)))
    obs_report.main([str(clean)])
    assert "Cohort participation" not in capsys.readouterr().out
