import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py forces
# 512 placeholder devices (and only in its own process).

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
