"""Shape-grouped round engine vs the per-client reference loop.

The grouped engine (core/round_engine.py GroupedRoundEngine) is the
heterogeneous hot path: clients partitioned by sub-model shape, one fused
jit step per shape census.  These tests pin its contracts:

* bit-exactness — on a ragged 3-width fleet, feddd runs (h-period full
  rounds included, Eq. (21) coverage rectification active) produce exactly
  the global params, client params, masks, and history of the loop;
* baselines — dense grouped rounds match the loop to float tolerance
  (summation order differs, as for the homogeneous engine);
* sim integration — run_sim accepts ragged fleets; sync + static
  reproduces the closed-form driver exactly; deadline/async compose;
* determinism — same seed gives identical results in any process
  (subprocess digests, mirroring tests/test_sim.py).
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import aggregation, coverage as cov_mod, run_scheme, selection
from repro.core.round_engine import (GroupBatch, GroupedRoundEngine,
                                     stack_pytrees, unstack_pytree)
from repro.core.selection import SelectionConfig

pytestmark = pytest.mark.flcore

WIDTHS = (12, 8, 6)           # ragged 3-width fleet, two clients per width


def _sub_params(key, w):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}


def _ragged_fleet(n=6, seed=0):
    """n clients cycling the three widths (non-contiguous groups)."""
    gp = _sub_params(jax.random.PRNGKey(seed), max(WIDTHS))
    clients = [_sub_params(jax.random.PRNGKey(seed + 100 + i),
                           WIDTHS[i % len(WIDTHS)]) for i in range(n)]
    return gp, clients


def _tel_for(clients, seed=0):
    from repro.core.allocation import ClientTelemetry
    n = len(clients)
    rng = np.random.default_rng(seed)
    nbytes = [float(sum(l.size * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(p)))
              for p in clients]
    return ClientTelemetry(
        model_bytes=np.asarray(nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    """Deterministic pseudo-training (no dataset needed)."""
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --- group metadata ----------------------------------------------------------

def test_group_by_shape_partition():
    from repro.fl.heterogeneity import group_by_shape, shape_signature

    _, clients = _ragged_fleet(7)          # widths 12,8,6,12,8,6,12
    groups = group_by_shape(clients)
    assert [g.indices for g in groups] == [(0, 3, 6), (1, 4), (2, 5)]
    assert [g.size for g in groups] == [3, 2, 2]
    # signature identifies shape classes exactly
    assert shape_signature(clients[0]) == shape_signature(clients[3])
    assert shape_signature(clients[0]) != shape_signature(clients[1])
    # homogeneous fleet: one group
    assert len(group_by_shape([clients[0]] * 4)) == 1


# --- step-level bit-exactness ------------------------------------------------

def _pad_to(p, g):
    return jax.tree_util.tree_map(
        lambda pl, gl: pl if pl.shape == gl.shape else jnp.pad(
            pl, [(0, gs - ps) for ps, gs in zip(pl.shape, gl.shape)]),
        p, g)


def _pad_mask_to(m, p, g):
    def _pad(ml, pl, gl):
        full = jnp.broadcast_to(ml, pl.shape)
        if pl.shape == gl.shape:
            return full
        return jnp.pad(full, [(0, gs - ps)
                              for ps, gs in zip(pl.shape, gl.shape)])
    return jax.tree_util.tree_map(_pad, m, p, g)


@pytest.mark.parametrize("full_round", [False, True])
def test_grouped_step_bit_identical_to_padded_loop(full_round):
    """One grouped step == build_masks-with-coverage + zero-pad + Eq. (4)
    stack + Eq. (5)/(6), client by client (exactly what the reference loop
    executor does for a ragged fleet)."""
    from repro.fl.heterogeneity import group_by_shape

    n = 6
    gp, olds = _ragged_fleet(n, seed=3)
    rk = jax.random.PRNGKey(11)
    news = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.1 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(50), i), x.shape), p)
        for i, p in enumerate(olds)]
    drop = np.linspace(0.0, 0.75, n)
    weights = np.arange(1.0, n + 1.0)
    cfg = SelectionConfig()

    full_w = cov_mod.channel_widths(gp)
    cr = cov_mod.coverage_rates(
        [cov_mod.channel_widths(p) for p in olds], full_w)

    # --- per-client reference (loop-executor maths)
    masks, dens = [], []
    for i in range(n):
        cov = cov_mod.coverage_pytree(olds[i], cr)
        m = selection.build_masks(
            olds[i], news[i], jnp.asarray(drop[i], jnp.float32), config=cfg,
            coverage=cov, rng=jax.random.fold_in(rk, 10_000 + i))
        masks.append(m)
        dens.append(float(selection.mask_density(news[i], m)))
    agg = aggregation.aggregate_sparse(
        [_pad_to(news[i], gp) for i in range(n)],
        [_pad_mask_to(masks[i], news[i], gp) for i in range(n)],
        weights, prev_global=gp)
    updates = []
    for i in range(n):
        g_local = jax.tree_util.tree_map(
            lambda g, l: g if g.shape == l.shape
            else g[tuple(slice(0, s) for s in l.shape)], agg, news[i])
        if full_round:
            updates.append(g_local)
        else:
            updates.append(aggregation.client_update_sparse(
                g_local, news[i], masks[i]))

    # --- grouped engine
    groups = group_by_shape(olds)
    batches = [GroupBatch(
        indices=jnp.asarray(g.indices, jnp.int32),
        stacked_old=stack_pytrees([olds[i] for i in g.indices]),
        stacked_new=stack_pytrees([news[i] for i in g.indices]),
        coverage=cov_mod.coverage_pytree(olds[g.indices[0]], cr),
        dropout=jnp.asarray(drop[list(g.indices)], jnp.float32))
        for g in groups]
    out = GroupedRoundEngine(cfg).step(batches, gp, weights, rk,
                                       full_round=full_round)

    assert _trees_equal(agg, out.global_params)
    got_dens = np.asarray(out.densities)
    for g, stacked in zip(groups, out.group_client_params):
        for pos, i in enumerate(g.indices):
            upd = jax.tree_util.tree_map(lambda l, pos=pos: l[pos], stacked)
            assert _trees_equal(updates[i], upd), f"client {i}"
            assert got_dens[i] == pytest.approx(dens[i], abs=1e-6)


def test_build_masks_batched_coverage_matches_per_client():
    """Eq. (21) coverage division in the batched builder is bit-identical
    to looping build_masks with the same (shared) coverage slice."""
    n = 4
    key = jax.random.PRNGKey(9)
    olds = [_sub_params(jax.random.fold_in(key, i), 8) for i in range(n)]
    news = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.05 * jax.random.normal(
            jax.random.fold_in(key, 100 + i), x.shape), p)
        for i, p in enumerate(olds)]
    cov = jax.tree_util.tree_map(
        lambda l: jnp.linspace(0.2, 1.0, l.shape[-1]), olds[0])
    drop = np.linspace(0.1, 0.7, n)
    rk = jax.random.PRNGKey(2)
    ids = np.asarray([3, 7, 11, 12])       # non-contiguous fleet positions
    batched, _ = selection.build_masks_batched(
        stack_pytrees(olds), stack_pytrees(news),
        jnp.asarray(drop, jnp.float32), config=SelectionConfig(), rng=rk,
        coverage=cov, client_indices=ids)
    for pos, i in enumerate(ids):
        ref = selection.build_masks(
            olds[pos], news[pos], jnp.asarray(drop[pos], jnp.float32),
            config=SelectionConfig(), coverage=cov,
            rng=jax.random.fold_in(rk, 10_000 + int(i)))
        got = jax.tree_util.tree_map(lambda l: l[pos], batched)
        assert _trees_equal(ref, got)


def test_aggregate_sparse_grouped_single_canvas_matches_sequential():
    """The fused single-scatter canvas (all groups padded + concatenated,
    ONE .at[rows].set per leaf) is bit-identical to the sequential
    per-group scatter path it replaced — including zero-weight rows and
    rows no group owns (prev_global fill)."""
    from repro.fl.heterogeneity import group_by_shape

    n = 7                     # one more row than clients: an un-owned row
    gp, clients = _ragged_fleet(6, seed=11)
    news = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.1 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(3), i), x.shape), p)
        for i, p in enumerate(clients)]
    groups = group_by_shape(clients)
    rk = jax.random.PRNGKey(5)
    drop = np.linspace(0.0, 0.7, 6)
    group_params, group_masks, group_idx = [], [], []
    for g in groups:
        stacked_old = stack_pytrees([clients[i] for i in g.indices])
        stacked_new = stack_pytrees([news[i] for i in g.indices])
        masks, _ = selection.build_masks_batched(
            stacked_old, stacked_new,
            jnp.asarray(drop[list(g.indices)], jnp.float32),
            config=SelectionConfig(), rng=rk,
            client_indices=jnp.asarray(g.indices, jnp.int32))
        group_params.append(stacked_new)
        group_masks.append(masks)
        group_idx.append(jnp.asarray(g.indices, jnp.int32))
    weights = np.asarray([1.0, 2.0, 0.0, 3.0, 1.5, 2.5, 4.0])  # 0-weight row
    kw = dict(global_template=gp, prev_global=gp)
    fused = aggregation.aggregate_sparse_grouped(
        group_params, group_masks, group_idx, weights, **kw)
    seq = aggregation.aggregate_sparse_grouped(
        group_params, group_masks, group_idx, weights,
        single_canvas=False, **kw)
    assert _trees_equal(fused, seq)


# --- end-to-end protocol parity ---------------------------------------------

def test_run_scheme_grouped_bit_identical_to_loop():
    """Algorithm 1 on a ragged 3-width fleet: grouped engine vs reference
    loop over several rounds including an h-period full broadcast —
    identical globals, client states, and history."""
    from repro.core import FedDDServer, ProtocolConfig

    n = 6
    gp, clients = _ragged_fleet(n)
    tel = _tel_for(clients)
    kw = dict(scheme="feddd", rounds=4, a_server=0.6, h=3, seed=0)

    s_loop = FedDDServer(gp, ProtocolConfig(batched=False, **kw), tel,
                         client_params=clients)
    assert s_loop.heterogeneous
    r_loop = s_loop.run(_ltf)
    s_grp = FedDDServer(gp, ProtocolConfig(batched=True, **kw), tel,
                        client_params=clients)
    assert s_grp.executor_kind == "grouped"
    r_grp = s_grp.run(_ltf)

    assert _trees_equal(r_loop.global_params, r_grp.global_params)
    for a, b in zip(s_loop.clients, s_grp.clients):
        assert _trees_equal(a.params, b.params)
    for rl, rb in zip(r_loop.history, r_grp.history):
        assert rl.mean_loss == pytest.approx(rb.mean_loss, abs=1e-9)
        assert rl.uploaded_fraction == pytest.approx(rb.uploaded_fraction,
                                                     abs=1e-6)
        np.testing.assert_allclose(rl.dropout_rates, rb.dropout_rates,
                                   atol=1e-12)
        assert rl.participants == rb.participants
        assert rl.sim_time == rb.sim_time


@pytest.mark.parametrize("scheme", ["fedavg", "fedcs", "oort"])
def test_grouped_baselines_match_loop(scheme):
    """Dense baselines on a ragged fleet ride the grouped step (all-ones
    masks, non-participation as 0-weights): history identical, params equal
    to float tolerance (summation order differs)."""
    n = 6
    gp, clients = _ragged_fleet(n, seed=5)
    tel = _tel_for(clients, seed=1)
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0)
    loop = run_scheme(scheme, gp, tel, _ltf, None, client_params=clients,
                      batched=False, **kw)
    grp = run_scheme(scheme, gp, tel, _ltf, None, client_params=clients,
                     batched=True, **kw)
    for x, y in zip(jax.tree_util.tree_leaves(loop.global_params),
                    jax.tree_util.tree_leaves(grp.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-7)
    for rl, rb in zip(loop.history, grp.history):
        assert rl.participants == rb.participants
        assert rl.sim_time == rb.sim_time
        assert rl.uploaded_fraction == pytest.approx(rb.uploaded_fraction,
                                                     abs=1e-9)
        assert rl.mean_loss == pytest.approx(rb.mean_loss, abs=1e-9)


# --- sim runner: ragged fleets -----------------------------------------------

def test_sim_sync_static_ragged_reproduces_protocol_exactly():
    """The grouped engine inside the event-driven runner: sync over a
    static network == the closed-form driver, bit for bit, on a ragged
    fleet (the combined contract of test_sim + this module)."""
    from repro.sim import SimConfig, run_sim

    n = 6
    gp, clients = _ragged_fleet(n)
    tel = _tel_for(clients)
    kw = dict(rounds=5, a_server=0.6, h=3, seed=0)
    ref = run_scheme("feddd", gp, tel, _ltf, None, client_params=clients,
                     batched=False, **kw)
    got = run_sim("feddd", gp, tel, _ltf, None,
                  sim=SimConfig(policy="sync"), client_params=clients, **kw)
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time          # exact, not approx
        assert rr.uploaded_fraction == pytest.approx(rg.uploaded_fraction,
                                                     abs=1e-6)
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
    assert _trees_equal(ref.global_params, got.global_params)


def test_sim_deadline_and_async_accept_ragged_fleet():
    """Stragglers x ragged fleets: the paper's hardest combined setting
    runs the fast path under every policy."""
    from repro.sim import SimConfig, TraceNetwork, run_sim

    n = 6
    gp, clients = _ragged_fleet(n, seed=7)
    tel = _tel_for(clients, seed=3)
    kw = dict(rounds=4, a_server=0.6, h=3, seed=0)

    # client 0's uplink collapses -> the deadline policy drops it
    epochs = 10
    up = np.tile(tel.uplink_rate, (epochs, 1))
    up[1:, 0] /= 200.0
    net = TraceNetwork(up, np.tile(tel.downlink_rate, (epochs, 1)),
                       np.tile(tel.compute_latency, (epochs, 1)))
    dl = run_sim("feddd", gp, tel, _ltf, None,
                 sim=SimConfig(policy="deadline"), network=net,
                 client_params=clients, **kw)
    assert any(r.participants < n for r in dl.history)
    assert all(r.participants >= 1 for r in dl.history)

    As = run_sim("feddd", gp, tel, _ltf, None, sim=SimConfig(policy="async"),
                 client_params=clients, **kw)
    from repro.sim import AsyncPolicy
    k = AsyncPolicy().resolved_buffer(n)
    assert all(r.participants == k for r in As.history)
    times = [r.sim_time for r in As.history]
    assert all(b > a for a, b in zip(times, times[1:]))


# --- determinism across processes --------------------------------------------

_DIGEST_SNIPPET = r"""
import hashlib
import numpy as np
import jax, jax.numpy as jnp
from repro.core.allocation import ClientTelemetry
from repro.sim import MarkovFadingNetwork, SimConfig, run_sim

WIDTHS = (12, 8, 6)

def sub(key, w):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}

def fleet(n=6):
    gp = sub(jax.random.PRNGKey(0), max(WIDTHS))
    return gp, [sub(jax.random.PRNGKey(100 + i), WIDTHS[i % 3])
                for i in range(n)]

def tel(clients):
    n = len(clients)
    rng = np.random.default_rng(0)
    nbytes = [float(sum(l.size * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(p)))
              for p in clients]
    return ClientTelemetry(
        model_bytes=np.asarray(nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

def ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))

h = hashlib.sha256()
for policy in ("sync", "deadline", "async"):
    gp, clients = fleet()
    t = tel(clients)
    net = MarkovFadingNetwork(t, p_fade=0.3, p_recover=0.4,
                              fade_factor=0.05, seed=7)
    res = run_sim("feddd", gp, t, ltf, None,
                  sim=SimConfig(policy=policy), network=net,
                  client_params=clients, rounds=3, a_server=0.6, h=2, seed=0)
    times = np.asarray([e[0] for e in res.event_trace])
    h.update(times.tobytes())
    h.update(",".join(f"{e[1]}:{e[2]}" for e in res.event_trace).encode())
    h.update(np.asarray([r.sim_time for r in res.history]).tobytes())
    for leaf in jax.tree_util.tree_leaves(res.global_params):
        h.update(np.asarray(leaf).tobytes())
print(h.hexdigest())
"""


def test_grouped_determinism_across_processes():
    """Same seed => identical event order, sim times, and final params in
    independent processes — ragged fleet, all three policies, fading
    network (the grouped-engine analogue of test_sim's digest)."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _DIGEST_SNIPPET],
            capture_output=True, text=True,
            env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu", "HOME": "/tmp"},
            check=False)
        assert out.returncode == 0, out.stderr[-2000:]
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64
