"""Strong correctness check: one-token decode through the KV-cache /
recurrent-state path must exactly reproduce the parallel prefill logits —
this validates the ring-buffer local attention, chunked Mamba scan,
chunkwise-stabilised mLSTM, sLSTM, cross-attention, and RoPE offsets."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.config import MoEConfig

ARCHS = ["granite_3_8b", "gemma3_27b", "jamba_1p5_large_398b",
         "xlstm_1p3b", "chatglm3_6b", "nemotron_4_340b", "pixtral_12b"]


def _fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = _fp32(get_config(arch, reduced=True))
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        # decode path covers text tokens only; drop the patch prefix here
        cfg = dataclasses.replace(cfg, num_patch_tokens=0)
        params = lm.init_model(key, cfg)
    full, _ = lm.forward(params, cfg, batch, remat=False)
    serve = jax.jit(lm.make_serve_step(cfg))
    ds = lm.init_decode_state(params, cfg, b, s)
    outs = []
    for t in range(s):
        lg, ds = serve(params, ds, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 5e-5


def test_decode_matches_prefill_encdec():
    cfg = _fp32(get_config("whisper_medium", reduced=True))
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    b, s, se = 2, 10, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    frames = jax.random.normal(key, (b, se, cfg.d_model), jnp.float32)
    full, _ = lm.forward(params, cfg,
                         {"tokens": toks, "enc_frames": frames}, remat=False)
    serve = jax.jit(lm.make_serve_step(cfg))
    ds = lm.init_decode_state(params, cfg, b, s, enc_frames=frames)
    outs = []
    for t in range(s):
        lg, ds = serve(params, ds, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 5e-5


def test_decode_matches_prefill_moe_no_capacity_drops():
    cfg = _fp32(get_config("qwen3_moe_30b_a3b", reduced=True))
    cfg = dataclasses.replace(cfg, moe=MoEConfig(
        num_experts=4, top_k=2, d_ff_expert=128, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = lm.init_model(key, cfg)
    b, s = 2, 10
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _ = lm.forward(params, cfg, {"tokens": toks}, remat=False)
    serve = jax.jit(lm.make_serve_step(cfg))
    ds = lm.init_decode_state(params, cfg, b, s)
    outs = []
    for t in range(s):
        lg, ds = serve(params, ds, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 5e-5


def test_sliding_window_ring_buffer_wraps():
    """Decoding past the window length must keep matching prefill (the ring
    buffer overwrites old slots)."""
    cfg = _fp32(get_config("gemma3_27b", reduced=True))
    cfg = dataclasses.replace(cfg, window_size=6)
    key = jax.random.PRNGKey(1)
    params = lm.init_model(key, cfg)
    b, s = 1, 20                      # 20 tokens through a 6-wide window
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _ = lm.forward(params, cfg, {"tokens": toks}, remat=False)
    serve = jax.jit(lm.make_serve_step(cfg))
    ds = lm.init_decode_state(params, cfg, b, s)
    outs = []
    for t in range(s):
        lg, ds = serve(params, ds, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 5e-5
