"""Batched round engine vs the per-client loop: bit-identical results.

The batched engine (core/round_engine.py) is the homogeneous FedDD hot
path; these tests pin its contract: for a fixed seed it produces exactly
the masks, aggregates, client updates, and history the per-client loop
produces — plus the lax.top_k / argsort tie-handling equivalence the mask
builder relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, run_scheme, selection
from repro.core.round_engine import (BatchedRoundEngine, stack_pytrees,
                                     unstack_pytree)
from repro.core.selection import SelectionConfig

pytestmark = pytest.mark.flcore


def _client_params(key, n, scale=1.0):
    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "fc0": {"w": scale * jax.random.normal(k1, (20, 12)),
                    "b": jnp.zeros(12)},
            "fc1": {"w": scale * jax.random.normal(k2, (12, 5)),
                    "b": jnp.zeros(5)},
        }
    return [one(jax.random.fold_in(key, i)) for i in range(n)]


def _perturb(params, key, eps=0.1):
    return [jax.tree_util.tree_map(
        lambda x: x + eps * jax.random.normal(jax.random.fold_in(key, i),
                                              x.shape), p)
        for i, p in enumerate(params)]


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("scheme", selection.SCHEMES)
@pytest.mark.parametrize("full_round", [False, True])
def test_engine_step_bit_identical_to_loop(scheme, full_round):
    """Masks, Eq.(4) aggregate, and Eq.(5)/(6) updates match the loop
    exactly (same seed, same dropout rates)."""
    n = 6
    key = jax.random.PRNGKey(0)
    olds = _client_params(key, n)
    news = _perturb(olds, jax.random.fold_in(key, 1))
    global_params = _client_params(jax.random.fold_in(key, 2), 1)[0]
    drop = np.random.default_rng(0).uniform(0.0, 0.8, n)
    weights = np.arange(1.0, n + 1.0)
    rk = jax.random.PRNGKey(7)
    cfg = SelectionConfig(scheme=scheme)

    # --- per-client loop reference (exactly what FedDDServer.run does)
    masks, dens = [], []
    for i in range(n):
        m = selection.build_masks(
            olds[i], news[i], jnp.asarray(drop[i], jnp.float32), config=cfg,
            rng=jax.random.fold_in(rk, 10_000 + i))
        masks.append(m)
        dens.append(float(selection.mask_density(news[i], m)))
    agg = aggregation.aggregate_sparse(news, masks, weights,
                                       prev_global=global_params)
    if full_round:
        updates = [agg] * n
    else:
        updates = [aggregation.client_update_sparse(agg, news[i], masks[i])
                   for i in range(n)]

    # --- batched engine
    out = BatchedRoundEngine(cfg).step(
        stack_pytrees(olds), stack_pytrees(news), global_params, drop,
        weights, rk, full_round=full_round)

    assert _trees_equal(agg, out.global_params)
    for i, upd in enumerate(unstack_pytree(out.client_params, n)):
        assert _trees_equal(updates[i], upd), f"client {i}"
    np.testing.assert_allclose(np.asarray(out.densities), dens, atol=1e-6)


def test_build_masks_batched_matches_loop_masks():
    n = 5
    key = jax.random.PRNGKey(3)
    olds = _client_params(key, n)
    news = _perturb(olds, jax.random.fold_in(key, 9))
    drop = np.linspace(0.0, 0.75, n)
    rk = jax.random.PRNGKey(11)
    cfg = SelectionConfig()
    batched, _ = selection.build_masks_batched(
        stack_pytrees(olds), stack_pytrees(news),
        jnp.asarray(drop, jnp.float32), config=cfg, rng=rk)
    for i in range(n):
        ref = selection.build_masks(
            olds[i], news[i], jnp.asarray(drop[i], jnp.float32), config=cfg,
            rng=jax.random.fold_in(rk, 10_000 + i))
        got = jax.tree_util.tree_map(lambda l: l[i], batched)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(ref)[0],
                jax.tree_util.tree_flatten_with_path(got)[0]):
            assert a.shape == b.shape
            assert bool(jnp.all(a == b)), jax.tree_util.keystr(path)


def test_aggregate_sparse_stacked_matches_list_path():
    n = 4
    key = jax.random.PRNGKey(5)
    news = _client_params(key, n)
    masks = [selection.build_masks(p, p, jnp.asarray(0.5),
                                   config=SelectionConfig(scheme="ordered"))
             for p in news]
    prev = _client_params(jax.random.fold_in(key, 1), 1)[0]
    wts = [1.0, 2.0, 0.5, 3.0]
    a = aggregation.aggregate_sparse(news, masks, wts, prev_global=prev)
    b = aggregation.aggregate_sparse_stacked(
        stack_pytrees(news), stack_pytrees(masks), wts, prev_global=prev)
    assert _trees_equal(a, b)


def test_run_scheme_batched_bit_identical_to_loop():
    """End-to-end Algorithm 1: batched vs loop over several rounds,
    including a full-broadcast (h) round — identical history + globals."""
    from repro.core.allocation import ClientTelemetry

    n = 6
    rng = np.random.default_rng(0)
    params = _client_params(jax.random.PRNGKey(0), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

    def ltf(p, idx, key):
        # deterministic pseudo-training: same fn both paths
        return (jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
            1.0 / (idx + 1.0))

    kw = dict(rounds=4, a_server=0.6, h=3, seed=0)
    loop = run_scheme("feddd", params, tel, ltf, None, batched=False, **kw)
    bat = run_scheme("feddd", params, tel, ltf, None, batched=True, **kw)
    assert _trees_equal(loop.global_params, bat.global_params)
    for rl, rb in zip(loop.history, bat.history):
        assert rl.uploaded_fraction == pytest.approx(rb.uploaded_fraction,
                                                     abs=1e-6)
        assert rl.mean_loss == pytest.approx(rb.mean_loss, abs=1e-9)
        np.testing.assert_allclose(rl.dropout_rates, rb.dropout_rates,
                                   atol=1e-12)
        assert rl.participants == rb.participants


@pytest.mark.parametrize("scheme", ["fedavg", "fedcs", "oort"])
def test_baselines_batched_engine_matches_loop(scheme):
    """Baselines ride the fused engine step too (dense all-ones masks,
    non-participation as a 0 aggregation weight): history identical to the
    per-client loop, params equal to float tolerance (summation order)."""
    from repro.core.allocation import ClientTelemetry

    n = 6
    rng = np.random.default_rng(0)
    params = _client_params(jax.random.PRNGKey(0), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

    def ltf(p, idx, key):
        return (jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
            1.0 / (idx + 1.0))

    kw = dict(rounds=4, a_server=0.6, h=3, seed=0)
    loop = run_scheme(scheme, params, tel, ltf, None, batched=False, **kw)
    bat = run_scheme(scheme, params, tel, ltf, None, batched=True, **kw)
    for x, y in zip(jax.tree_util.tree_leaves(loop.global_params),
                    jax.tree_util.tree_leaves(bat.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-7)
    for rl, rb in zip(loop.history, bat.history):
        assert rl.participants == rb.participants
        assert rl.sim_time == rb.sim_time
        assert rl.uploaded_fraction == pytest.approx(rb.uploaded_fraction,
                                                     abs=1e-9)
        assert rl.mean_loss == pytest.approx(rb.mean_loss, abs=1e-9)


def test_batched_train_fn_fuses_training():
    """batched_train_fn path == per-client python training (same maths)."""
    from repro.core import FedDDServer, ProtocolConfig
    from repro.core.allocation import ClientTelemetry

    n = 4
    params = _client_params(jax.random.PRNGKey(2), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    rng = np.random.default_rng(1)
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=np.full(n, 10.0),
        label_coverage=np.ones(n),
        train_loss=np.ones(n))

    def per_client(p, idx, key):
        del key
        return jax.tree_util.tree_map(lambda x: 0.9 * x, p), 0.5

    def batched(stacked, key):
        del key
        return (jax.tree_util.tree_map(lambda x: 0.9 * x, stacked),
                jnp.full((n,), 0.5))

    kw = dict(scheme="feddd", rounds=3, a_server=0.6, h=2, seed=0)
    s1 = FedDDServer(params, ProtocolConfig(**kw), tel)
    r1 = s1.run(per_client)
    s2 = FedDDServer(params, ProtocolConfig(**kw), tel)
    r2 = s2.run(batched_train_fn=batched)
    assert _trees_equal(r1.global_params, r2.global_params)
    # stacked client state synced back into ClientState
    assert _trees_equal(s1.clients[0].params, s2.clients[0].params)


@pytest.mark.parametrize("scheme", ["fedavg", "fedcs", "oort"])
def test_batched_train_fn_baselines_respect_participation(scheme):
    """Dense-baseline runs may fuse training too, but non-participants must
    not train: their params stay stale (out of the aggregate) and their
    losses stay stale in the server's view — identical to the per-client
    engine trainer that simply skips them."""
    from repro.core import FedDDServer, ProtocolConfig
    from repro.core.allocation import ClientTelemetry

    n = 6
    params = _client_params(jax.random.PRNGKey(4), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    rng = np.random.default_rng(2)
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

    def per_client(p, idx, key):
        del key
        return jax.tree_util.tree_map(lambda x: 0.9 * x, p), 0.25

    def batched(stacked, key):
        del key
        return (jax.tree_util.tree_map(lambda x: 0.9 * x, stacked),
                jnp.full((n,), 0.25))

    kw = dict(scheme=scheme, rounds=3, a_server=0.5, h=2, seed=0)
    s1 = FedDDServer(params, ProtocolConfig(**kw), tel)
    r1 = s1.run(per_client)
    s2 = FedDDServer(params, ProtocolConfig(**kw), tel)
    r2 = s2.run(batched_train_fn=batched)
    assert _trees_equal(r1.global_params, r2.global_params)
    for a, b in zip(s1.clients, s2.clients):
        assert _trees_equal(a.params, b.params)
    for ra, rb in zip(r1.history, r2.history):
        assert ra.participants == rb.participants
        assert ra.mean_loss == pytest.approx(rb.mean_loss, abs=1e-9)
        assert ra.uploaded_fraction == pytest.approx(rb.uploaded_fraction,
                                                     abs=1e-9)
    # sanity: the scenario exercises actual non-participation
    assert any(r.participants < n for r in r1.history) or scheme == "fedavg"


def test_batched_train_fn_rejected_off_engine_path():
    from repro.core import FedDDServer, ProtocolConfig
    from repro.core.allocation import ClientTelemetry

    n = 2
    params = {"w": jnp.ones((4, 4))}
    tel = ClientTelemetry(*[np.ones(n)] * 7)
    server = FedDDServer(params, ProtocolConfig(scheme="feddd",
                                                batched=False), tel)
    with pytest.raises(ValueError, match="batched_train_fn"):
        server.run(batched_train_fn=lambda s, k: (s, jnp.zeros(n)))


# --- multi-round scanned dispatch (rounds_per_dispatch > 1) ----------------

def _scan_telemetry(n, nbytes, seed=0):
    from repro.core.allocation import ClientTelemetry

    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _make_scan_fixture(n=8, seed=0):
    params = _client_params(jax.random.PRNGKey(seed), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    tel = _scan_telemetry(n, nbytes, seed=seed)

    # jitted so the sequential path runs the same XLA-compiled arithmetic
    # the scan inlines (an eager fn can differ in the last f32 bit: fma)
    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1), x.shape), stacked)
        l0 = jax.tree_util.tree_leaves(new)[0]
        losses = jnp.mean(jnp.abs(l0.reshape(l0.shape[0], -1)), axis=1)
        return new, losses

    return params, tel, batched


def _assert_histories_identical(h_seq, h_scan):
    """Learning state must match EXACTLY; the allocator-derived fields
    (dropout rates and the Eq. (12) clock computed from them) are held to
    float32-ulp scale — XLA compiles the fenced golden-section search per
    program, so its last bit is context sensitive (it matches exactly on
    this fixture today, but a jax/XLA bump may legally flip an ulp)."""
    assert len(h_seq) == len(h_scan)
    for ra, rb in zip(h_seq, h_scan):
        assert ra.round == rb.round
        assert ra.mean_loss == rb.mean_loss                   # exact
        assert ra.uploaded_fraction == rb.uploaded_fraction   # exact
        assert ra.participants == rb.participants
        np.testing.assert_allclose(ra.dropout_rates, rb.dropout_rates,
                                   rtol=0, atol=5e-7)
        assert rb.sim_time == pytest.approx(ra.sim_time, rel=1e-6)
        assert rb.sim_round_time == pytest.approx(ra.sim_round_time,
                                                  rel=1e-6)


@pytest.mark.parametrize("scheme", ["feddd", "fedavg", "fedcs", "oort"])
def test_rounds_per_dispatch_bit_identical_to_sequential(scheme):
    """K scanned rounds == K per-round engine dispatches, bit for bit:
    global params, client params, losses, dropout rates, and Eq. (12)
    times (feddd runs the in-scan allocator + clock; the dense baselines
    run full uploads with fedcs static / oort traced selection).  rounds=7
    with K=4 also exercises the partial trailing chunk."""
    from repro.core import FedDDServer, ProtocolConfig

    params, tel, batched = _make_scan_fixture()
    kw = dict(scheme=scheme, rounds=7, a_server=0.6, h=3, seed=0,
              allocator="jax")
    s_seq = FedDDServer(params, ProtocolConfig(**kw), tel)
    r_seq = s_seq.run(batched_train_fn=batched)
    s_scan = FedDDServer(params, ProtocolConfig(rounds_per_dispatch=4,
                                                **kw), tel)
    r_scan = s_scan.run(batched_train_fn=batched)

    assert _trees_equal(r_seq.global_params, r_scan.global_params)
    for a, b in zip(s_seq.clients, s_scan.clients):
        assert _trees_equal(a.params, b.params)
    _assert_histories_identical(r_seq.history, r_scan.history)
    # the scenario actually exercises selection for the budgeted baselines
    if scheme in ("fedcs", "oort"):
        assert any(r.participants < tel.num_clients
                   for r in r_seq.history)


def test_rounds_per_dispatch_chunk_boundaries_agree():
    """Chunk size must not leak into results: K=2, K=3 (uneven chunks),
    and K=rounds all reproduce the K=1 stream."""
    from repro.core import FedDDServer, ProtocolConfig

    params, tel, batched = _make_scan_fixture(seed=3)
    kw = dict(scheme="feddd", rounds=6, a_server=0.6, h=3, seed=0,
              allocator="jax")
    ref = FedDDServer(params, ProtocolConfig(**kw), tel).run(
        batched_train_fn=batched)
    for k in (2, 3, 6):
        got = FedDDServer(params, ProtocolConfig(rounds_per_dispatch=k,
                                                 **kw), tel).run(
            batched_train_fn=batched)
        assert _trees_equal(ref.global_params, got.global_params), k
        _assert_histories_identical(ref.history, got.history)


def test_scanned_engine_run_trace_and_device_clock():
    """Engine-level contract of BatchedRoundEngine.run: trace shapes are
    (K, N), the traced f32 clock tracks the float64 host recompute, and
    the final carry losses/dropout equal the last trace row."""
    from repro.core import baselines
    from repro.core.round_engine import (BatchedRoundEngine, ScanState,
                                         ScanTelemetry, stack_pytrees)

    n, k = 6, 5
    params, tel, batched = _make_scan_fixture(n=n, seed=1)
    engine = BatchedRoundEngine(SelectionConfig())
    state = ScanState(
        client_params=stack_pytrees([params] * n),
        global_params=params,
        losses=jnp.ones((n,), jnp.float32),
        dropout=jnp.zeros((n,), jnp.float32),
        rng=jax.random.PRNGKey(0),
        sim_time=jnp.zeros((), jnp.float32))
    out, trace = engine.run(
        state, ScanTelemetry.from_host(tel), num_rounds=k,
        batched_train_fn=batched, weights=tel.num_samples, h=3,
        a_server=0.6, d_max=0.8, delta=1.0,
        global_model_bytes=float(np.max(tel.model_bytes)))
    assert trace.losses.shape == (k, n)
    assert trace.densities.shape == (k, n)
    assert trace.next_dropout.shape == (k, n)
    assert trace.participants.shape == (k, n)
    assert trace.round_time.shape == (k,)
    assert bool(jnp.all(trace.participants))         # feddd: everyone
    np.testing.assert_array_equal(np.asarray(out.losses),
                                  np.asarray(trace.losses[-1]))
    np.testing.assert_array_equal(np.asarray(out.dropout),
                                  np.asarray(trace.next_dropout[-1]))
    # device f32 clock vs host f64 Eq. (12): close, and cumulative
    d = np.zeros(n)
    expect = []
    for j in range(k):
        expect.append(np.max(baselines.round_times(tel, d)))
        d = np.asarray(trace.next_dropout[j], float)
    np.testing.assert_allclose(np.asarray(trace.round_time), expect,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(trace.sim_time),
                               np.cumsum(expect), rtol=1e-5)


def test_scanned_run_donates_stacked_carry():
    """donate_argnums targets BOTH model-buffer carries: the stacked
    client params AND the global params update in place (no per-dispatch
    copy of either); the tiny losses/rng/clock entries stay un-donated.
    The protocol executor copies the user-provided global pytree once
    before its first chunk, so caller arrays are never invalidated
    (test_rounds_per_dispatch_* cover that side).  XLA implements
    donation on CPU/GPU/TPU for the pinned jax version; if a backend ever
    declines it, it falls back to a copy and jax warns at compile — this
    test would catch the regression by the carries staying live."""
    from repro.core.round_engine import (BatchedRoundEngine, ScanState,
                                         ScanTelemetry, stack_pytrees)

    n = 4
    params, tel, batched = _make_scan_fixture(n=n, seed=2)
    stacked = stack_pytrees([params] * n)
    gparams = jax.tree_util.tree_map(jnp.array, params)
    donated_leaf = jax.tree_util.tree_leaves(stacked)[0]
    global_leaf = jax.tree_util.tree_leaves(gparams)[0]
    losses_in = jnp.ones((n,), jnp.float32)
    engine = BatchedRoundEngine(SelectionConfig())
    state = ScanState(stacked, gparams, losses_in,
                      jnp.zeros((n,), jnp.float32), jax.random.PRNGKey(1),
                      jnp.zeros((), jnp.float32))
    kw = dict(num_rounds=3, batched_train_fn=batched,
              weights=tel.num_samples, h=3, a_server=0.6, d_max=0.8,
              delta=1.0, global_model_bytes=float(np.max(tel.model_bytes)))
    out, _ = engine.run(state, ScanTelemetry.from_host(tel), **kw)
    assert donated_leaf.is_deleted()         # stacked carry consumed
    assert global_leaf.is_deleted()          # global carry consumed too
    assert not losses_in.is_deleted()        # small carries never donated
    # chaining chunks off the returned carry works (each chunk donates
    # the previous chunk's output, which only the caller holds)
    out2, _ = engine.run(out, ScanTelemetry.from_host(tel), **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(out2.client_params))
    assert jax.tree_util.tree_leaves(out.client_params)[0].is_deleted()
    assert jax.tree_util.tree_leaves(out.global_params)[0].is_deleted()


def test_rounds_per_dispatch_validation():
    """The scanned path's preconditions fail loudly: numpy allocator,
    K < 1, missing batched_train_fn, per-round eval_fn, and non-engine
    routes (heterogeneous fleets, batched=False) are all rejected."""
    from repro.core import FedDDServer, ProtocolConfig
    from repro.core.allocation import ClientTelemetry

    with pytest.raises(ValueError, match="allocator"):
        ProtocolConfig(rounds_per_dispatch=2)
    with pytest.raises(ValueError, match="rounds_per_dispatch"):
        ProtocolConfig(rounds_per_dispatch=0)

    params, tel, batched = _make_scan_fixture(n=4)
    cfg = dict(scheme="feddd", rounds=2, allocator="jax",
               rounds_per_dispatch=2)

    def ltf(p, idx, key):
        return p, 1.0

    srv = FedDDServer(params, ProtocolConfig(**cfg), tel)
    with pytest.raises(ValueError, match="batched_train_fn"):
        srv.run(ltf)
    srv = FedDDServer(params, ProtocolConfig(**cfg), tel)
    with pytest.raises(ValueError, match="eval_fn"):
        srv.run(batched_train_fn=batched, eval_fn=lambda p: {})
    srv = FedDDServer(params, ProtocolConfig(batched=False, **cfg), tel)
    with pytest.raises(ValueError, match="homogeneous"):
        srv.run(batched_train_fn=batched)

    # ragged fleet routes to the grouped engine -> rejected
    ragged = [params] + [jax.tree_util.tree_map(
        lambda l: l[..., :-1] if l.ndim else l, params)] * 3
    n4 = ClientTelemetry(*[np.ones(4)] * 7)
    srv = FedDDServer(params, ProtocolConfig(**cfg), n4,
                      client_params=ragged)
    with pytest.raises(ValueError):
        srv.run(batched_train_fn=batched)


# --- lax.top_k vs argsort tie handling -------------------------------------

def test_mask_from_scores_topk_matches_argsort_on_ties():
    """Both break ties toward the LOWER channel index; masks must be equal
    for every keep count, including duplicate-heavy score vectors."""
    cases = [
        jnp.asarray([1.0, 3.0, 3.0, 2.0, 3.0, 1.0]),
        jnp.zeros(8),
        jnp.asarray([2.0, 2.0, 2.0, 2.0]),
        jnp.asarray([5.0, 4.0, 3.0, 2.0, 1.0]),
        jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0]),
    ]
    for scores in cases:
        c = scores.shape[0]
        for keep in range(c + 1):
            a = selection.mask_from_scores(scores, keep, c)
            b = selection.mask_from_scores_argsort(scores, keep, c)
            assert bool(jnp.all(a == b)), (scores, keep)
            assert int(a.sum()) == keep


def test_mask_from_scores_tie_prefers_lower_index():
    scores = jnp.asarray([1.0, 7.0, 7.0, 7.0, 0.0])
    m = selection.mask_from_scores(scores, 2, 5)
    np.testing.assert_array_equal(np.asarray(m), [0, 1, 1, 0, 0])


# --- batched kernel wrappers -----------------------------------------------

def test_kernel_batched_importance_matches_per_client():
    from repro.kernels.importance import ops as kops
    key = jax.random.PRNGKey(0)
    wo = jax.random.normal(key, (5, 33, 17))
    wn = wo + 0.2 * jax.random.normal(jax.random.fold_in(key, 1), wo.shape)
    got = kops.channel_importance_batched(wo, wn, channel_axis=-1)
    want = jnp.stack([kops.channel_importance(wo[i], wn[i], channel_axis=-1)
                      for i in range(5)])
    assert got.shape == (5, 17)
    assert bool(jnp.all(got == want))


def test_engine_use_kernel_matches_jnp_path():
    n = 4
    key = jax.random.PRNGKey(8)
    olds = _client_params(key, n)
    news = _perturb(olds, jax.random.fold_in(key, 4))
    g = _client_params(jax.random.fold_in(key, 5), 1)[0]
    drop = np.full(n, 0.5)
    w = np.ones(n)
    rk = jax.random.PRNGKey(0)
    a = BatchedRoundEngine(SelectionConfig(use_kernel=False)).step(
        stack_pytrees(olds), stack_pytrees(news), g, drop, w, rk,
        full_round=False)
    b = BatchedRoundEngine(SelectionConfig(use_kernel=True)).step(
        stack_pytrees(olds), stack_pytrees(news), g, drop, w, rk,
        full_round=False)
    for x, y in zip(jax.tree_util.tree_leaves(a.global_params),
                    jax.tree_util.tree_leaves(b.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=1e-6)


# --- sparse_collective satellite fixes -------------------------------------

def test_make_federated_allreduce_forwards_k_local():
    """k_local zero-weights rows beyond each participant's own keep count;
    with a single participant and k_local=1 only the top-1 channel (plus
    untouched positions) can change."""
    from jax.experimental.shard_map import shard_map

    from repro.core.sparse_collective import make_federated_allreduce

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    local = jnp.arange(12.0).reshape(6, 2)
    scores = jnp.asarray([0.0, 5.0, 1.0, 4.0, 2.0, 3.0])
    f = make_federated_allreduce(0.5, "pod")   # static buffer k=3

    def body(x, s, kl):
        return f(x, s, 1.0, kl[0])

    out = shard_map(
        body, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),) * 3,
        out_specs=jax.sharding.PartitionSpec(),
        check_rep=False)(local, scores, jnp.asarray([1]))
    # rows beyond k_local=1 keep their LOCAL values (weight 0 => uncovered)
    np.testing.assert_allclose(np.asarray(out), np.asarray(local))

    # signature is importable/evaluable (the latent Optional NameError)
    import typing
    from repro.core import sparse_collective
    hints = typing.get_type_hints(sparse_collective.sparse_allgather_mean)
    assert "k_local" in hints
