"""Data pipeline + partitioners (paper §6.1 settings)."""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.data import (label_coverage_score, label_distribution,
                        make_dataset, partition_class_imbalanced,
                        partition_dirichlet, partition_iid,
                        partition_noniid_a, partition_noniid_b)


@pytest.fixture(scope="module")
def ds():
    train, _ = make_dataset("mnist", num_train=5000, num_test=100, seed=0)
    return train


def _assert_partition(parts, n_total):
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(np.unique(all_idx))     # disjoint
    assert len(all_idx) <= n_total


def test_iid_uniform_classes(ds):
    parts = partition_iid(ds, 10, seed=0)
    _assert_partition(parts, len(ds))
    assert sum(map(len, parts)) == len(ds)
    for p in parts:
        dist = label_distribution(ds, p)
        assert dist.max() < 0.2          # roughly uniform over 10 classes


def test_noniid_b_three_classes(ds):
    parts = partition_noniid_b(ds, 10, seed=0)
    _assert_partition(parts, len(ds))
    for p in parts:
        assert (label_distribution(ds, p) > 0).sum() <= 3


def test_noniid_a_class_counts(ds):
    parts = partition_noniid_a(ds, 10, seed=0)
    for p in parts:
        k = (label_distribution(ds, p) > 0).sum()
        assert 1 <= k <= 10


def test_coverage_score_range(ds):
    parts = partition_noniid_b(ds, 10, seed=0)
    for p in parts:
        s = label_coverage_score(ds, p)
        assert 0.0 < s <= 10.0
        assert s <= 3.0 + 1e-9           # 3 classes max under Non-IID-b


def test_class_imbalanced_rare_classes(ds):
    parts = partition_class_imbalanced(ds, 10, rare_classes=(0, 1, 2),
                                       rare_ratio=0.4, seed=0)
    all_idx = np.concatenate(parts)
    counts = np.bincount(ds.y[all_idx], minlength=10)
    common = counts[3:].mean()
    for c in (0, 1, 2):
        assert counts[c] < 0.6 * common


@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.05, 5.0), seed=st.integers(0, 100))
def test_dirichlet_partition_valid(ds, alpha, seed):
    parts = partition_dirichlet(ds, 8, alpha=alpha, seed=seed)
    _assert_partition(parts, len(ds))
    assert sum(map(len, parts)) == len(ds)


def test_dataset_learnable_structure():
    """Classes must be separable (synthetic data sanity)."""
    train, test = make_dataset("mnist", num_train=2000, num_test=500, seed=0)
    # nearest-class-mean classifier should beat chance comfortably
    xf = train.x.reshape(len(train), -1)
    means = np.stack([xf[train.y == c].mean(0) for c in range(10)])
    xt = test.x.reshape(len(test), -1)
    pred = np.argmin(((xt[:, None] - means[None]) ** 2).sum(-1), axis=1)
    assert (pred == test.y).mean() > 0.5


def test_lm_dataset():
    from repro.data import make_lm_dataset
    toks = make_lm_dataset(vocab_size=128, num_tokens=1000, seed=0)
    assert toks.shape == (1000,)
    assert toks.min() >= 0 and toks.max() < 128


def test_batch_iterator_deterministic_and_complete():
    from repro.data.pipeline import BatchIterator
    import numpy as np
    x = np.arange(100).reshape(100, 1).astype(np.float32)
    y = np.arange(100).astype(np.int32)
    it = BatchIterator(x, y, batch_size=16, seed=3)
    assert it.steps_per_epoch() == 6
    b1 = list(it.epoch(0))
    b2 = list(it.epoch(0))
    assert len(b1) == 6
    for (xa, ya), (xb, yb) in zip(b1, b2):
        np.testing.assert_array_equal(xa, xb)   # deterministic per epoch
    b3 = list(it.epoch(1))
    assert not all(np.array_equal(a[1], b[1]) for a, b in zip(b1, b3))
    seen = np.concatenate([b[1] for b in b1])
    assert len(np.unique(seen)) == 96           # no repeats within epoch


def test_packed_lm_batcher():
    from repro.data.pipeline import PackedLMBatcher
    import numpy as np
    toks = np.arange(1000, dtype=np.int32)
    b = PackedLMBatcher(toks, seq_len=32, batch_size=4, seed=0)
    out = b.batch(0)
    assert out["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b.batch(5)["tokens"],
                                  b.batch(5)["tokens"])
