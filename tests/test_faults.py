"""Fault-injection layer (repro/sim/faults.py): zero-rate bit-exactness,
hand-computed failure scenarios, server degradation, and determinism.

Pins the failure axis's contracts:

* zero-rate transparency — a fault model with every rate at 0 leaves each
  scheme x policy run BIT-IDENTICAL to the fault-free simulator (records,
  event trace, and learning state);
* scripted faults — a sync+static round with one scripted crash
  reproduces the hand-computed survivor-renormalized Eq. (4) aggregate
  and the Eq. (12) clock exactly; scripted retransmits add exactly their
  bytes and backoff to the wire and the clock;
* server degradation — corrupted payloads are quarantined (bit-identical
  global to the same client crashing), quorum misses skip the round and
  hold the global, 100% loss degenerates to "no round ever commits";
* determinism — a faulty run is a pure function of (seed, config), with
  identical digests across processes.
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import CommConfig
from repro.comm.payload import WireSpec, analytic_wire_bytes, \
    delivered_prefix_counts
from repro.core import aggregation, baselines, run_scheme
from repro.core.allocation import ClientTelemetry
from repro.sim import (DeadlinePolicy, FaultConfig, RandomFaults,
                       RetryPolicy, ScriptedFaults, SimConfig, SyncPolicy,
                       TraceNetwork, make_policy, run_sim)
from repro.sim.engine import Event, UPLOAD_DONE
from repro.sim.runner import ObservedTelemetry, SimResult

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key):
    k1, k2 = jax.random.split(key)
    return {
        "fc0": {"w": jax.random.normal(k1, (20, 12)), "b": jnp.zeros(12)},
        "fc1": {"w": jax.random.normal(k2, (12, 5)), "b": jnp.zeros(5)},
    }


def _tel(n, seed=0):
    rng = np.random.default_rng(seed)
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(
                           _params(jax.random.PRNGKey(0)))))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    """Deterministic pseudo-training (no dataset needed)."""
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --- config / floor semantics -------------------------------------------------

def test_fault_config_validates_rates():
    with pytest.raises(ValueError, match="crash_rate"):
        FaultConfig(crash_rate=1.5)
    with pytest.raises(ValueError, match="corrupt_kind"):
        FaultConfig(corrupt_kind="gamma_ray")
    with pytest.raises(ValueError, match="chunk_bytes"):
        FaultConfig(chunk_bytes=0)
    with pytest.raises(ValueError, match="scripted corrupt kind"):
        ScriptedFaults(corrupt={(0, 0): "zap"})


def test_quorum_floor_fraction_and_absolute():
    frac = RandomFaults(FaultConfig(quorum=0.5))
    assert frac.quorum_floor(8) == 4
    assert frac.quorum_floor(5) == 3          # ceil
    absolute = RandomFaults(FaultConfig(quorum=3))
    assert absolute.quorum_floor(8) == 3
    assert absolute.quorum_floor(2) == 2      # capped at scheduled
    assert RandomFaults(FaultConfig(quorum=0)).quorum_floor(8) == 1


# --- zero-rate transparency ---------------------------------------------------

@pytest.mark.parametrize("scheme,policy", [
    ("feddd", "sync"), ("feddd", "deadline"), ("feddd", "retry"),
    ("fedavg", "sync"), ("fedcs", "sync"),
])
def test_zero_rate_faults_bit_identical_to_fault_free(scheme, policy):
    """The acceptance contract: all fault rates 0 => the full RoundRecord
    stream, event trace, and learning state match the fault-free run
    bit for bit."""
    n = 6
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(rounds=4, a_server=0.6, h=3, seed=0,
              sim=SimConfig(policy=policy))
    ref = run_sim(scheme, params, tel, _ltf, None, **kw)
    got = run_sim(scheme, params, tel, _ltf, None,
                  faults=RandomFaults(FaultConfig()), **kw)
    assert ref.event_trace == got.event_trace
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time
        assert rr.participants == rg.participants
        assert rr.mean_loss == rg.mean_loss
        assert rr.uploaded_bytes == rg.uploaded_bytes
        assert rr.wire_bytes == rg.wire_bytes
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
        # failure-economy fields carry their no-fault values
        assert not rg.skipped
        assert rg.retries == 0
        assert rg.abandoned_bytes == 0.0
        assert rg.quarantined_bytes == 0.0
        assert rg.survivors == rr.participants or rg.survivors >= \
            rg.participants
    assert _trees_equal(ref.global_params, got.global_params)


def test_zero_rate_matches_closed_form_protocol():
    """0% fault rate through the sync+static sim == the closed-form
    protocol driver (transitively via the sim's own equivalence)."""
    n = 5
    params = _params(jax.random.PRNGKey(1))
    tel = _tel(n, seed=2)
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0)
    ref = run_scheme("feddd", params, tel, _ltf, None, **kw)
    got = run_scheme("feddd", params, tel, _ltf, None,
                     faults=RandomFaults(FaultConfig()), **kw)
    assert isinstance(got, SimResult)      # faults= routes to the sim
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
    assert _trees_equal(ref.global_params, got.global_params)


# --- scripted crash: hand-computed Eq. (4) + Eq. (12) -------------------------

def test_scripted_crash_hand_computed_survivor_aggregate_and_clock():
    """One scripted crash in a sync+static round 1 (D^1 = 0, masks all
    ones): the global must equal the survivor-renormalized Eq. (4)
    weighted mean recomputed by hand, and the round clock must equal
    max over SURVIVORS of the Eq. (12) row — both exactly."""
    n = 3
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=ScriptedFaults(crashes={(0, 2): 0.5}),
                  rounds=1, a_server=0.6, h=5, seed=0)
    rec = res.history[0]
    assert rec.participants == 2
    assert rec.survivors == 2
    assert not rec.skipped

    # replicate the round's local training exactly (same key schedule)
    rng = jax.random.PRNGKey(0)
    _, rk = jax.random.split(rng)
    news = [_ltf(params, i, jax.random.fold_in(rk, i))[0]
            for i in range(n)]
    # Eq. (4) with the crashed client at weight 0 (all-ones masks, D=0),
    # mirroring _leaf_masked_mean's arithmetic order exactly
    w = np.asarray(tel.num_samples, np.float32).copy()
    w[2] = 0.0
    expected = []
    for leaves in zip(*[jax.tree_util.tree_leaves(p) for p in news]):
        stack = jnp.stack(leaves).astype(jnp.float32)
        wts = jnp.asarray(w, jnp.float32).reshape(
            (n,) + (1,) * (stack.ndim - 1))
        num = jnp.sum(stack * wts, axis=0)
        den = jnp.sum(jnp.ones_like(stack) * wts, axis=0)
        expected.append((num / jnp.maximum(den, 1e-12)
                         ).astype(leaves[0].dtype))
    got = jax.tree_util.tree_leaves(res.global_params)
    for e, g in zip(expected, got):
        np.testing.assert_array_equal(np.asarray(e), np.asarray(g))

    # Eq. (12): the dead client never uploads; the round ends at the
    # latest surviving arrival
    ti = baselines.round_times(tel, np.zeros(n))
    assert rec.sim_time == float(max(ti[0], ti[1]))


def test_scripted_retransmits_exact_bytes_and_delay():
    """k scripted chunk retransmits charge exactly k*chunk_bytes on the
    wire and k*chunk/r_u + backoff_base*(2^k - 1) on the Eq. (12)
    clock."""
    n = 3
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    cfgkw = dict(rounds=1, a_server=0.6, h=5, seed=0)
    base = run_sim("feddd", params, tel, _ltf, None,
                   sim=SimConfig(policy="sync"), **cfgkw)
    k = 3
    fc = FaultConfig()
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=ScriptedFaults(chunk_retries={(0, 0): k},
                                        config=fc), **cfgkw)
    rec, ref = res.history[0], base.history[0]
    assert rec.retries == k
    assert rec.wire_bytes == ref.wire_bytes + k * fc.chunk_bytes
    ti = baselines.round_times(tel, np.zeros(n))
    delay = (k * fc.chunk_bytes / float(tel.uplink_rate[0])
             + fc.backoff_base * (2.0 ** k - 1.0))
    assert rec.sim_time == float(max(ti[0] + delay, ti[1], ti[2]))
    # the retransmitted upload still aggregates: same learning state
    assert _trees_equal(base.global_params, res.global_params)


# --- corruption + validation screen -------------------------------------------

@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_corrupted_payload_quarantined_equals_crash(kind):
    """A non-finite corrupted upload is quarantined: 0 weight on Eq. (4),
    so the GLOBAL is bit-identical to the same client crashing outright
    (both are non-participation); the bytes are accounted as quarantined."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(rounds=1, a_server=0.6, h=5, seed=0,
              sim=SimConfig(policy="sync"))
    corrupted = run_sim("feddd", params, tel, _ltf, None,
                        faults=ScriptedFaults(corrupt={(0, 0): kind}), **kw)
    crashed = run_sim("feddd", params, tel, _ltf, None,
                      faults=ScriptedFaults(crashes={(0, 0): 0.5}), **kw)
    rec = corrupted.history[0]
    assert rec.participants == n - 1
    assert rec.quarantined_bytes == float(tel.model_bytes[0])
    assert crashed.history[0].quarantined_bytes == 0.0
    assert _trees_equal(corrupted.global_params, crashed.global_params)


def test_norm_anomaly_screen_quarantines_blown_up_update():
    """An arrived-but-insane update (huge finite norm) is quarantined by
    the median-norm screen even though it is finite."""
    n = 6
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)

    def spiky_ltf(p, idx, key):
        if idx == 0:     # client 0 diverges: update norm >> the fleet's
            return jax.tree_util.tree_map(lambda x: x + 500.0, p), 1.0
        return _ltf(p, idx, key)

    kw = dict(rounds=1, a_server=0.6, h=5, seed=0,
              sim=SimConfig(policy="sync"))
    clean = run_sim("feddd", params, tel, spiky_ltf, None, **kw)
    screened = run_sim("feddd", params, tel, spiky_ltf, None,
                       faults=RandomFaults(FaultConfig()), **kw)
    # without the fault layer the insane update poisons the global ...
    assert float(np.max(np.abs(np.asarray(
        clean.global_params["fc0"]["w"])))) > 50.0
    # ... with it attached the screen quarantines client 0
    assert screened.history[0].participants == n - 1
    assert screened.history[0].quarantined_bytes > 0.0
    assert float(np.max(np.abs(np.asarray(
        screened.global_params["fc0"]["w"])))) < 50.0


# --- quorum + degenerate configs ----------------------------------------------

def test_quorum_miss_skips_round_and_holds_global():
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    crashes = {(0, i): 0.3 for i in range(3)}    # round 1: one survivor
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=ScriptedFaults(crashes=crashes,
                                        config=FaultConfig(quorum=2)),
                  rounds=1, a_server=0.6, h=5, seed=0)
    rec = res.history[0]
    assert rec.skipped
    assert rec.participants == 0
    assert rec.survivors == 1
    assert rec.uploaded_bytes == 0.0
    assert rec.abandoned_bytes > 0.0     # the survivor's upload is wasted
    # global held: bit-identical to the initial model
    assert _trees_equal(params, res.global_params)


def test_full_loss_every_round_skipped_global_never_moves():
    """100% packet loss: every upload aborts, every round misses quorum,
    the global stays bit-identical to round 0 for the whole run."""
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=RandomFaults(FaultConfig(loss_rate=1.0,
                                                  max_retries=2)),
                  rounds=3, a_server=0.6, h=2, seed=0)
    assert all(r.skipped for r in res.history)
    assert all(r.participants == 0 for r in res.history)
    assert all(r.abandoned_bytes > 0.0 for r in res.history)
    times = [r.sim_time for r in res.history]
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert _trees_equal(params, res.global_params)


def test_crashed_clients_excluded_from_allocation_resolve():
    """A quorum-skipped round re-solves the LP on survivor telemetry only:
    crashed clients keep their previous dropout rate."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    crashes = {(0, i): 0.2 for i in range(4)}    # round 1: quorum miss
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=ScriptedFaults(crashes=crashes,
                                        config=FaultConfig(quorum=2)),
                  rounds=2, a_server=0.6, h=5, seed=0)
    assert res.history[0].skipped
    # records carry the POST-round re-solve (the D for round t+1); the
    # skipped round's solve ran on survivor telemetry only, so crashed
    # clients hold their round-1 rate (D^1 = 0) instead of consuming
    # budget from stale rows
    d_after_skip = res.history[0].dropout_rates
    np.testing.assert_array_equal(d_after_skip[:4], np.zeros(4))
    assert d_after_skip[4] >= 0.0
    # round 2 completes normally => its end-of-round solve is full-fleet
    assert not res.history[1].skipped
    assert float(np.sum(res.history[1].dropout_rates[:4])) > 0.0


# --- telemetry EWMA under missing/insane measurements -------------------------

def test_ewma_skips_missing_and_nonfinite_measurements():
    n = 3
    tel = _tel(n)
    obs = ObservedTelemetry(tel, ewma=0.5)
    before = obs.uplink.copy()
    # a non-finite measurement is discarded outright
    obs.observe(Event(time=1.0, seq=1, kind=UPLOAD_DONE, client=0,
                      payload=("uplink", float("nan"))))
    np.testing.assert_array_equal(obs.uplink, before)
    # a real measurement EWMA-updates; equal-value stays bit-identical
    obs.observe(Event(time=2.0, seq=2, kind=UPLOAD_DONE, client=0,
                      payload=("uplink", before[0])))
    assert obs.uplink[0] == before[0]
    obs.observe(Event(time=3.0, seq=3, kind=UPLOAD_DONE, client=0,
                      payload=("uplink", 3.0 * before[0])))
    assert obs.uplink[0] == 0.5 * 3.0 * before[0] + 0.5 * before[0]


def test_crashed_client_telemetry_stays_stale_not_zero():
    """A client that crashes every round produces NO events, so its
    uplink estimate must remain the prior exactly — not decay toward 0 —
    even while its true rate collapses 50x."""
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    net = TraceNetwork.straggler_collapse(tel, clients=(0,), factor=50.0)
    crashes = {(e, 0): 0.01 for e in range(6)}   # dies before any event
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"), network=net,
                  faults=ScriptedFaults(crashes=crashes),
                  rounds=5, a_server=0.6, h=3, seed=0)
    obs = res.observed_telemetry
    assert obs.uplink_rate[0] == tel.uplink_rate[0]     # exact, stale
    assert all(r.survivors == n - 1 for r in res.history)


# --- deadline partial aggregation ---------------------------------------------

def test_delivered_prefix_counts_endpoints_and_monotonicity():
    params = _params(jax.random.PRNGKey(0))
    spec = WireSpec.from_params(params, channel_axis=-1)
    comm = CommConfig(codec="index", qbits=8)
    d = 0.4
    total = float(analytic_wire_bytes(spec, d, comm))
    full = delivered_prefix_counts(spec, d, comm, total)
    kept = [int(np.clip(np.ceil(c * (1 - d)), 0, c))
            for c, _ in spec.leaves]
    np.testing.assert_array_equal(full, kept)     # cut at total = all
    np.testing.assert_array_equal(
        delivered_prefix_counts(spec, d, comm, 0.0),
        np.zeros(len(spec.leaves), np.int32))     # cut at 0 = none
    prev = -1
    for frac in (0.1, 0.3, 0.5, 0.7, 0.9):
        got = int(delivered_prefix_counts(spec, d, comm,
                                          frac * total).sum())
        assert got >= prev
        prev = got


def test_truncate_masks_to_prefix_semantics():
    m = jnp.asarray([[[1.0, 0.0, 1.0, 1.0]],
                     [[1.0, 1.0, 0.0, 1.0]]])      # (N=2, 1, C=4)
    masks = {"w": m}
    sentinel = np.iinfo(np.int32).max
    # client 0 delivered 2 kept channels, client 1 everything
    out = aggregation.truncate_masks_to_prefix(
        masks, (jnp.asarray([2, sentinel], jnp.int32),))
    np.testing.assert_array_equal(
        np.asarray(out["w"]),
        [[[1.0, 0.0, 1.0, 0.0]], [[1.0, 1.0, 0.0, 1.0]]])
    # scalar-ish leaf: count >= 1 keeps it, 0 drops it
    out2 = aggregation.truncate_masks_to_prefix(
        {"b": jnp.asarray([1.0, 1.0])}, (jnp.asarray([0, 1], jnp.int32),))
    np.testing.assert_array_equal(np.asarray(out2["b"]), [0.0, 1.0])
    with pytest.raises(ValueError, match="mismatch"):
        aggregation.truncate_masks_to_prefix(masks, ())


def test_deadline_partial_rescues_straggler_prefix():
    """partial=True turns a cut straggler into a partial contributor:
    its delivered mask-channel prefix aggregates, the delivered bytes are
    charged to the wire, and the learning state genuinely moves."""
    n = 6
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(rounds=3, a_server=0.6, h=5, seed=0, d_max=0.3,
              comm=CommConfig(codec="index", qbits=8))

    def _run(partial):
        return run_sim(
            "feddd", params, tel, _ltf, None,
            sim=SimConfig(policy=DeadlinePolicy(quantile=1.0, slack=1.0,
                                                partial=partial)),
            network=TraceNetwork.straggler_collapse(tel, clients=(0,),
                                                    factor=8.0),
            faults=RandomFaults(FaultConfig()), **kw)

    cut, rescued = _run(False), _run(True)
    cut_rounds = [i for i, r in enumerate(cut.history)
                  if r.participants < n]
    assert cut_rounds, "straggler never cut — scenario broken"
    for i in cut_rounds:
        assert rescued.history[i].participants == n     # prefix counted
        assert rescued.history[i].wire_bytes > \
            cut.history[i].wire_bytes                   # bytes charged
        assert cut.history[i].abandoned_bytes > 0.0
        assert rescued.history[i].abandoned_bytes == 0.0
    assert not _trees_equal(cut.global_params, rescued.global_params)


# --- retry policy --------------------------------------------------------------

def test_retry_policy_horizon_and_factory():
    exp = np.array([1.0, 2.0, 4.0])
    assert RetryPolicy().horizon(exp) == pytest.approx(12.0)
    assert RetryPolicy(slack=2.0).horizon(exp) == pytest.approx(8.0)
    assert isinstance(make_policy("retry"), RetryPolicy)
    from repro.sim.policies import POLICIES
    assert "retry" in POLICIES


def test_retry_policy_bounds_lossy_straggler():
    """Under heavy loss the retry horizon cuts a retransmit-delayed
    straggler that plain sync would wait out."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    faults = ScriptedFaults(
        chunk_retries={(t, 0): 5 for t in range(4)},
        config=FaultConfig(chunk_bytes=8 * float(tel.model_bytes[0])))
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0)
    sync = run_sim("feddd", params, tel, _ltf, None,
                   sim=SimConfig(policy="sync"), faults=faults, **kw)
    retry = run_sim("feddd", params, tel, _ltf, None,
                    sim=SimConfig(policy="retry",
                                  policy_kw={"slack": 2.0}),
                    faults=faults, **kw)
    assert all(r.participants == n for r in sync.history)
    assert any(r.participants < n for r in retry.history)
    assert retry.history[-1].sim_time < sync.history[-1].sim_time


# --- fleets / guards -----------------------------------------------------------

def _sub_params(key, w):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}


def test_ragged_fleet_supports_crash_faults():
    n = 3
    widths = (12, 8, 6)
    gp = _sub_params(jax.random.PRNGKey(0), max(widths))
    clients = [_sub_params(jax.random.PRNGKey(100 + i), widths[i])
               for i in range(n)]
    tel = _tel(n)
    res = run_sim("feddd", gp, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  client_params=clients,
                  faults=ScriptedFaults(crashes={(0, 1): 0.5}),
                  rounds=2, a_server=0.6, h=2, seed=0)
    assert res.history[0].participants == n - 1
    assert res.history[0].survivors == n - 1
    assert res.history[1].participants == n


def test_fault_guards_reject_unsupported_combinations():
    n = 3
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    clients = [_sub_params(jax.random.PRNGKey(100 + i), w)
               for i, w in enumerate((12, 8, 6))]
    kw = dict(rounds=1, a_server=0.6, seed=0)
    # async supports crash/loss/staleness but NOT wire corruption (the
    # merge consumes pending host pytrees, not a staged stacked upload)
    with pytest.raises(ValueError, match="wave-policy only"):
        run_sim("feddd", params, tel, _ltf, None,
                sim=SimConfig(policy="async"),
                faults=RandomFaults(FaultConfig(corrupt_rate=0.2)), **kw)
    with pytest.raises(ValueError, match="corruption"):
        run_sim("feddd", params, tel, _ltf, None,
                sim=SimConfig(policy="sync"), client_params=clients,
                faults=ScriptedFaults(corrupt={(0, 0): "nan"}), **kw)
    with pytest.raises(ValueError, match="partial"):
        run_sim("feddd", params, tel, _ltf, None,
                sim=SimConfig(policy=DeadlinePolicy(partial=True)),
                client_params=clients,
                faults=RandomFaults(FaultConfig()), **kw)


# --- determinism across processes ---------------------------------------------

_FAULT_DIGEST_SNIPPET = r"""
import hashlib
import numpy as np
import jax, jax.numpy as jnp
from repro.core.allocation import ClientTelemetry
from repro.sim import (FaultConfig, MarkovFadingNetwork, RandomFaults,
                       SimConfig, run_sim)

def params():
    return {"fc0": {"w": jax.random.normal(jax.random.PRNGKey(0), (20, 12)),
                    "b": jnp.zeros(12)},
            "fc1": {"w": jax.random.normal(jax.random.PRNGKey(9), (12, 5)),
                    "b": jnp.zeros(5)}}

def tel(n):
    rng = np.random.default_rng(0)
    p = params()
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(p)))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

def ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))

h = hashlib.sha256()
for policy in ("sync", "deadline", "retry"):
    t = tel(5)
    net = MarkovFadingNetwork(t, p_fade=0.3, p_recover=0.4,
                              fade_factor=0.05, seed=7)
    faults = RandomFaults(FaultConfig(crash_rate=0.2, loss_rate=0.15,
                                      corrupt_rate=0.15, quorum=1,
                                      seed=5))
    res = run_sim("feddd", params(), t, ltf, None,
                  sim=SimConfig(policy=policy), network=net,
                  faults=faults, rounds=4, a_server=0.6, h=2, seed=0)
    times = np.asarray([e[0] for e in res.event_trace])
    h.update(times.tobytes())
    h.update(",".join(f"{e[1]}:{e[2]}" for e in res.event_trace).encode())
    rec = np.asarray([[r.sim_time, r.participants, r.survivors,
                       r.retries, r.abandoned_bytes, r.quarantined_bytes,
                       float(r.skipped)] for r in res.history])
    h.update(rec.tobytes())
    for leaf in jax.tree_util.tree_leaves(res.global_params):
        h.update(np.asarray(leaf).tobytes())
print(h.hexdigest())
"""


def test_faulty_run_deterministic_across_processes():
    """Same (seed, fault config) => identical event trace, failure
    accounting, and final params in independent processes."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    digests = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _FAULT_DIGEST_SNIPPET],
            capture_output=True, text=True,
            env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu", "HOME": "/tmp"},
            check=False)
        assert out.returncode == 0, out.stderr[-2000:]
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]
    assert len(digests[0]) == 64


# --- survivability: small-survivor validation policy --------------------------

def test_norm_screen_never_engages_below_three_finite_survivors():
    """n <= 2 finite survivors: the norm-anomaly screen stays out of the
    loop even when ``min_reference`` is configured below the hard floor
    of 3 — the median of 1-2 norms cannot identify an anomaly (n=1 can
    never exceed a factor of itself, n=2 would let either arrival veto
    the other)."""
    from repro.sim import ValidationConfig
    from repro.sim.faults import screen_quarantine
    vcfg = ValidationConfig(min_reference=1, norm_factor=2.0)
    q = screen_quarantine(np.array([1e12]), np.array([True]),
                          np.array([True]), vcfg)
    assert not q.any()
    q = screen_quarantine(np.array([1.0, 1e12]), np.array([True, True]),
                          np.array([True, True]), vcfg)
    assert not q.any()
    # with 3 finite survivors the screen engages and takes the outlier
    q = screen_quarantine(np.array([1.0, 1.1, 1e12]),
                          np.array([True, True, True]),
                          np.array([True, True, True]), vcfg)
    assert q.tolist() == [False, False, True]


def test_finite_screen_still_fires_for_tiny_survivor_sets():
    """The non-finite check is unconditional — it quarantines NaN/Inf
    arrivals even when the survivor set is too small for the norm
    screen; non-candidates are never touched."""
    from repro.sim import ValidationConfig
    from repro.sim.faults import screen_quarantine
    vcfg = ValidationConfig(min_reference=1, norm_factor=2.0)
    q = screen_quarantine(np.array([np.nan, 1.0]),
                          np.array([False, True]),
                          np.array([True, True]), vcfg)
    assert q.tolist() == [True, False]
    q = screen_quarantine(np.array([np.nan, 1.0]),
                          np.array([False, True]),
                          np.array([False, True]), vcfg)
    assert q.tolist() == [False, False]


# --- survivability: fault-draw locality (property) ----------------------------

from hypothesis_compat import given, settings, st  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_round_faults_draws_depend_only_on_own_client(data):
    """Every client's fault draw is a pure function of (seed, epoch,
    client, own telemetry): restricting the scheduled set to a prefix, or
    permuting OTHER clients' telemetry, never changes a client's draw.
    This is what makes fault streams replay-identical across executors
    that visit clients in different orders."""
    seed = data.draw(st.integers(0, 2 ** 16))
    epoch = data.draw(st.integers(0, 3))
    n = data.draw(st.integers(2, 8))
    m = data.draw(st.integers(1, n))
    rng = np.random.default_rng(seed + 1)
    wire = rng.uniform(2e3, 2e5, n)
    up = rng.uniform(1e3, 5e3, n)
    model = RandomFaults(FaultConfig(crash_rate=0.35, loss_rate=0.3,
                                     corrupt_rate=0.25, max_retries=3,
                                     seed=seed))
    fields = ("crashed", "crash_frac", "aborted", "retries",
              "extra_bytes", "extra_delay", "sent_bytes", "corrupt")
    full = model.round_faults(epoch, wire, up)
    sub = model.round_faults(epoch, wire[:m], up[:m])
    for f in fields:
        np.testing.assert_array_equal(getattr(full, f)[:m],
                                      getattr(sub, f), err_msg=f)
    i = data.draw(st.integers(0, n - 1))
    wire2, up2 = wire[::-1].copy(), up[::-1].copy()
    wire2[i], up2[i] = wire[i], up[i]
    other = model.round_faults(epoch, wire2, up2)
    for f in fields:
        np.testing.assert_array_equal(getattr(full, f)[i],
                                      getattr(other, f)[i], err_msg=f)


# --- survivability: corrupt-but-finite clients vs robust aggregation ----------

def _byzantine_ltf(p, idx, key):
    """Client 0 returns a finite but wildly wrong update; the rest are
    honest (same contraction as _ltf)."""
    if idx == 0:
        return jax.tree_util.tree_map(lambda x: x + 500.0, p), 1.0
    return _ltf(p, idx, key)


def _peak(res):
    return max(float(jnp.max(jnp.abs(l)))
               for l in jax.tree_util.tree_leaves(res.global_params))


def test_corrupt_but_finite_client_mean_diverges_trimmed_holds():
    """ISSUE acceptance, fault-layer edition: a corrupt-but-FINITE
    client slips past a disabled norm screen and blows up the plain
    masked mean, while the trimmed-mean engine variant holds the global
    bounded; with the default screen the norm quarantine catches the
    same client, so either defense alone survives the attack."""
    from repro.sim import ValidationConfig
    n = 6
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(sim=SimConfig(policy="sync"), rounds=3, a_server=0.6,
              h=3, seed=0)
    no_screen = RandomFaults(FaultConfig(
        validation=ValidationConfig(norm_factor=0.0)))
    diverged = run_sim("feddd", params, tel, _byzantine_ltf, None,
                       faults=no_screen, **kw)
    assert _peak(diverged) > 50.0
    trimmed = run_sim("feddd", params, tel, _byzantine_ltf, None,
                      faults=RandomFaults(FaultConfig(
                          validation=ValidationConfig(norm_factor=0.0))),
                      robust_agg="trimmed:0.25", **kw)
    assert _peak(trimmed) < 10.0
    screened = run_sim("feddd", params, tel, _byzantine_ltf, None,
                       faults=RandomFaults(FaultConfig()), **kw)
    assert _peak(screened) < 10.0
    assert sum(r.quarantined_bytes for r in screened.history) > 0


# --- survivability: async faults ---------------------------------------------

def test_async_zero_rate_faults_bit_identical():
    """A zero-rate fault model on the buffered-async path is fully
    transparent: identical records, event trace, and final params."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    kw = dict(sim=SimConfig(policy="async"), rounds=4, a_server=0.6,
              h=2, seed=0)
    base = run_sim("feddd", params, _tel(n), _ltf, None, **kw)
    faulty = run_sim("feddd", params, _tel(n), _ltf, None,
                     faults=RandomFaults(FaultConfig()), **kw)
    assert _trees_equal(base.global_params, faulty.global_params)
    assert base.event_trace == faulty.event_trace
    assert len(base.history) == len(faulty.history)
    for a, b in zip(base.history, faulty.history):
        assert (a.sim_time, a.participants, a.survivors, a.wire_bytes,
                a.retries, a.abandoned_bytes) == \
               (b.sim_time, b.participants, b.survivors, b.wire_bytes,
                b.retries, b.abandoned_bytes)
        assert np.array_equal(a.dropout_rates, b.dropout_rates)


def test_async_crash_and_abort_faults_complete_with_accounting(tmp_path):
    """Crash/abort faults on the async path re-dispatch the slot instead
    of stalling the buffer: every merge still fills, fault incidents
    reach the run log, and the run is deterministic."""
    import json
    from repro.obs import ObsConfig
    from repro.sim import AsyncPolicy
    n = 5
    params = _params(jax.random.PRNGKey(0))
    path = tmp_path / "async.jsonl"

    def go(jsonl=None):
        obs = (ObsConfig(enabled=True, jsonl_path=str(jsonl))
               if jsonl else None)
        kw = dict(sim=SimConfig(policy=AsyncPolicy(buffer_size=2)),
                  rounds=5, a_server=0.6, h=2, seed=0,
                  faults=RandomFaults(FaultConfig(
                      crash_rate=0.25, loss_rate=0.25, max_retries=1,
                      seed=11)))
        if obs is not None:
            kw["obs"] = obs
        return run_sim("feddd", params, _tel(n), _ltf, None, **kw)

    res = go(jsonl=path)
    assert len(res.history) == 5
    assert all(r.participants == 2 for r in res.history)
    kinds = {json.loads(line).get("kind")
             for line in path.read_text().splitlines()
             if json.loads(line).get("event") == "fault"}
    assert kinds & {"crash", "abort"}
    again = go()
    assert _trees_equal(res.global_params, again.global_params)
    assert [r.sim_time for r in res.history] == \
           [r.sim_time for r in again.history]
    assert [(r.retries, r.abandoned_bytes) for r in res.history] == \
           [(r.retries, r.abandoned_bytes) for r in again.history]


def test_async_staleness_budget_drops_stale_buffered_updates(tmp_path):
    """With a staleness budget, an extreme straggler's buffered update
    is dropped at merge time (stale_drop incident + abandoned bytes) and
    the client is re-dispatched; without a budget the same update is
    merged."""
    import json
    from repro.obs import ObsConfig
    from repro.sim import AsyncPolicy
    n = 4
    params = _params(jax.random.PRNGKey(0))

    def go(budget, jsonl=None):
        tel = _tel(n)
        tel.uplink_rate[0] /= 20.0       # heavy straggler
        kw = dict(sim=SimConfig(policy=AsyncPolicy(buffer_size=2)),
                  rounds=6, a_server=0.6, h=2, seed=0,
                  faults=RandomFaults(FaultConfig(
                      staleness_budget=budget)))
        if jsonl is not None:
            kw["obs"] = ObsConfig(enabled=True, jsonl_path=str(jsonl))
        return run_sim("feddd", params, tel, _ltf, None, **kw)

    path = tmp_path / "stale.jsonl"
    res = go(budget=1, jsonl=path)
    drops = [json.loads(line)
             for line in path.read_text().splitlines()
             if json.loads(line).get("kind") == "stale_drop"]
    assert drops, "no stale_drop incident recorded"
    assert all(d["budget"] == 1 and d["staleness"] > 1 for d in drops)
    assert sum(r.abandoned_bytes for r in res.history) > 0
    assert len(res.history) == 6
    lax = go(budget=0)
    assert sum(r.abandoned_bytes for r in lax.history) == 0
