"""Dropout-rate allocation LP (paper §4.1): exactness + invariants."""

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis_compat import given, settings, st

from repro.core.allocation import (ClientTelemetry, regularizer,
                                   solve_dropout_rates,
                                   solve_dropout_rates_jax)

pytestmark = pytest.mark.flcore


def _tel(rng, n):
    return ClientTelemetry(
        model_bytes=rng.uniform(1e5, 5e6, n),
        uplink_rate=rng.uniform(1e3, 1e4, n),
        downlink_rate=rng.uniform(5e3, 3e4, n),
        compute_latency=rng.uniform(0.1, 10.0, n),
        num_samples=rng.integers(10, 1000, n).astype(float),
        label_coverage=rng.uniform(1.0, 10.0, n),
        train_loss=rng.uniform(0.1, 3.0, n),
    )


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("a_server", [0.2, 0.5, 0.8])
def test_budget_constraint_met_exactly(seed, a_server):
    rng = np.random.default_rng(seed)
    tel = _tel(rng, 30)
    res = solve_dropout_rates(tel, a_server=a_server, d_max=0.9, delta=1.0)
    assert res.feasible
    uploaded = np.sum(tel.model_bytes * (1 - res.dropout_rates))
    np.testing.assert_allclose(uploaded, a_server * np.sum(tel.model_bytes),
                               rtol=1e-5)
    assert np.all(res.dropout_rates >= -1e-9)
    assert np.all(res.dropout_rates <= 0.9 + 1e-9)


def test_infeasible_when_dmax_too_small():
    # A_server=0.1 requires dropping 90% of mass but D_max=0.2 allows 20%.
    rng = np.random.default_rng(0)
    tel = _tel(rng, 10)
    res = solve_dropout_rates(tel, a_server=0.1, d_max=0.2, delta=1.0)
    assert not res.feasible


def test_slow_clients_get_higher_dropout():
    """System heterogeneity: with delta=0 (pure makespan objective), the
    slowest client must not upload more than the LP's straggler bound."""
    n = 8
    up = np.full(n, 1e3)
    up[0] = 20.0            # client 0: terrible uplink
    tel = ClientTelemetry(
        model_bytes=np.full(n, 1e5), uplink_rate=up,
        downlink_rate=np.full(n, 1e4),
        compute_latency=np.full(n, 1.0),
        num_samples=np.full(n, 100.0),
        label_coverage=np.full(n, 10.0),
        train_loss=np.full(n, 1.0))
    res = solve_dropout_rates(tel, a_server=0.6, d_max=0.9, delta=0.0)
    assert res.feasible
    assert res.dropout_rates[0] == max(res.dropout_rates)
    assert res.dropout_rates[0] > 0.85    # near D_max for the straggler


def test_valuable_clients_get_lower_dropout():
    """Data heterogeneity: all else equal, higher re_n -> lower D_n."""
    n = 6
    cov = np.full(n, 5.0)
    cov[2] = 10.0           # client 2 has the best label coverage
    cov[3] = 1.0
    tel = ClientTelemetry(
        model_bytes=np.full(n, 1e5), uplink_rate=np.full(n, 1e3),
        downlink_rate=np.full(n, 1e4),
        compute_latency=np.full(n, 1.0),
        num_samples=np.full(n, 100.0),
        label_coverage=cov,
        train_loss=np.full(n, 1.0))
    res = solve_dropout_rates(tel, a_server=0.6, d_max=0.9, delta=100.0)
    assert res.dropout_rates[2] <= res.dropout_rates[3] + 1e-9


def test_jax_matches_numpy():
    rng = np.random.default_rng(7)
    tel = _tel(rng, 25)
    res = solve_dropout_rates(tel, a_server=0.55, d_max=0.8, delta=2.0)
    dj, tj = solve_dropout_rates_jax(
        jnp.asarray(tel.model_bytes), jnp.asarray(tel.uplink_rate),
        jnp.asarray(tel.downlink_rate), jnp.asarray(tel.compute_latency),
        jnp.asarray(tel.num_samples), jnp.asarray(tel.label_coverage),
        jnp.asarray(tel.train_loss),
        a_server=0.55, d_max=0.8, delta=2.0)
    np.testing.assert_allclose(np.asarray(dj), res.dropout_rates, atol=2e-3)
    np.testing.assert_allclose(float(tj), res.t_server, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 40), seed=st.integers(0, 10_000),
       a_server=st.floats(0.3, 0.9), delta=st.floats(0.0, 10.0))
def test_property_feasibility_and_optimality_vs_uniform(n, seed, a_server,
                                                        delta):
    """The LP optimum never exceeds the objective of the uniform-dropout
    feasible point (when that point is feasible)."""
    rng = np.random.default_rng(seed)
    tel = _tel(rng, n)
    d_max = 0.95
    res = solve_dropout_rates(tel, a_server=a_server, d_max=d_max,
                              delta=delta)
    d_uni = 1.0 - a_server
    if d_uni <= d_max:
        assert res.feasible
        re = regularizer(tel, float(np.max(tel.model_bytes)))
        k = tel.model_bytes * (1 / tel.uplink_rate + 1 / tel.downlink_rate)
        obj_uni = (np.max(tel.compute_latency + k * (1 - d_uni))
                   + delta * np.sum(re * d_uni))
        assert res.objective <= obj_uni + 1e-4 * max(1.0, abs(obj_uni))


def _assert_numpy_jax_agree(tel, *, a_server, d_max, delta, atol=5e-3):
    """Both solvers feasible, agreeing, within bounds, on-budget."""
    res = solve_dropout_rates(tel, a_server=a_server, d_max=d_max,
                              delta=delta)
    assert res.feasible
    assert np.all(res.dropout_rates >= -1e-9)
    assert np.all(res.dropout_rates <= d_max + 1e-9)
    uploaded = np.sum(tel.model_bytes * (1 - res.dropout_rates))
    np.testing.assert_allclose(uploaded, a_server * np.sum(tel.model_bytes),
                               rtol=1e-5)
    dj, tj = solve_dropout_rates_jax(
        jnp.asarray(tel.model_bytes), jnp.asarray(tel.uplink_rate),
        jnp.asarray(tel.downlink_rate), jnp.asarray(tel.compute_latency),
        jnp.asarray(tel.num_samples), jnp.asarray(tel.label_coverage),
        jnp.asarray(tel.train_loss),
        a_server=a_server, d_max=d_max, delta=delta)
    dj = np.asarray(dj, np.float64)
    np.testing.assert_allclose(dj, res.dropout_rates, atol=atol)
    assert np.all(dj >= -1e-6) and np.all(dj <= d_max + 1e-6)
    np.testing.assert_allclose(np.sum(tel.model_bytes * (1 - dj)),
                               a_server * np.sum(tel.model_bytes), rtol=1e-4)
    np.testing.assert_allclose(float(tj), res.t_server, rtol=1e-3)
    return res


def test_degenerate_near_zero_uplink_straggler():
    """One client's uplink is ~zero (its k_n dominates every timescale):
    solvers must stay feasible, agree, pin the straggler at D_max, and
    hold the budget equality."""
    n = 8
    up = np.full(n, 2e3)
    up[0] = 1e-3                      # effectively a dead link
    tel = ClientTelemetry(
        model_bytes=np.full(n, 1e5), uplink_rate=up,
        downlink_rate=np.full(n, 1e4),
        compute_latency=np.full(n, 1.0),
        num_samples=np.full(n, 100.0),
        label_coverage=np.full(n, 5.0),
        train_loss=np.full(n, 1.0))
    res = _assert_numpy_jax_agree(tel, a_server=0.6, d_max=0.8, delta=1.0)
    # the dead-link straggler sets the makespan => it drops the maximum
    assert res.dropout_rates[0] == pytest.approx(0.8, abs=1e-6)


def test_degenerate_all_identical_fleet():
    """A perfectly homogeneous fleet: the unique optimum is the uniform
    rate D_n = 1 - A_server on every client, in both solvers."""
    n = 12
    tel = ClientTelemetry(
        model_bytes=np.full(n, 4e5), uplink_rate=np.full(n, 3e3),
        downlink_rate=np.full(n, 1.2e4),
        compute_latency=np.full(n, 2.0),
        num_samples=np.full(n, 50.0),
        label_coverage=np.full(n, 4.0),
        train_loss=np.full(n, 0.7))
    res = _assert_numpy_jax_agree(tel, a_server=0.55, d_max=0.8, delta=2.0)
    np.testing.assert_allclose(res.dropout_rates, 0.45, atol=1e-6)
    # makespan at the uniform point: every client finishes together
    k = 4e5 * (1 / 3e3 + 1 / 1.2e4)
    np.testing.assert_allclose(res.t_server, 2.0 + k * 0.55, rtol=1e-6)


def test_regularizer_formula():
    rng = np.random.default_rng(1)
    tel = _tel(rng, 4)
    re = regularizer(tel, 1e6)
    m = tel.num_samples.sum()
    want = (tel.num_samples / m) * tel.label_coverage \
        * (tel.model_bytes / 1e6) * tel.train_loss
    np.testing.assert_allclose(re, want)


# --- allocator dispatch (ProtocolConfig.allocator = "numpy" | "jax") --------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("a_server", [0.3, 0.6])
def test_allocator_jax_parity_on_budget(seed, a_server):
    """The jax allocator must land ON the communication budget (the LP's
    equality constraint) and match the numpy reference's objective — the
    contract FedDDServer.allocate relies on whichever backend is picked."""
    from repro.core.allocation import solve_dropout_rates_with

    rng = np.random.default_rng(seed)
    tel = _tel(rng, 24)
    kw = dict(a_server=a_server, d_max=0.9, delta=1.0)
    ref = solve_dropout_rates_with("numpy", tel, **kw)
    got = solve_dropout_rates_with("jax", tel, **kw)
    assert ref.feasible and got.feasible
    total = np.sum(tel.model_bytes)
    for res in (ref, got):
        uploaded = np.sum(tel.model_bytes * (1 - res.dropout_rates))
        np.testing.assert_allclose(uploaded, a_server * total, rtol=1e-4)
        assert np.all(res.dropout_rates >= -1e-9)
        assert np.all(res.dropout_rates <= 0.9 + 1e-9)
    # same LP, same optimum (float32 golden section => loose-ish tol)
    np.testing.assert_allclose(got.objective, ref.objective, rtol=1e-3)


def test_allocator_unknown_rejected():
    from repro.core.allocation import solve_dropout_rates_with

    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="allocator"):
        solve_dropout_rates_with("scipy", _tel(rng, 4), a_server=0.5,
                                 d_max=0.8, delta=1.0)


def test_protocol_config_allocator_jax_end_to_end():
    """A server run with allocator='jax' stays on budget every round and
    produces rates close to the numpy run (identical training path)."""
    import jax
    from repro.core import run_scheme

    n = 6
    rng = np.random.default_rng(3)
    params = {"fc0": {"w": jnp.ones((20, 12)), "b": jnp.zeros(12)},
              "fc1": {"w": jnp.ones((12, 5)), "b": jnp.zeros(5)}}
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

    def ltf(p, idx, key):
        return (jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
            1.0 / (idx + 1.0))

    kw = dict(rounds=3, a_server=0.6, h=5, seed=0)
    res = run_scheme("feddd", params, tel, ltf, None, allocator="jax", **kw)
    total = np.sum(tel.model_bytes)
    for rec in res.history:
        uploaded = np.sum(tel.model_bytes * (1 - rec.dropout_rates))
        np.testing.assert_allclose(uploaded, 0.6 * total, rtol=1e-4)

    ref = run_scheme("feddd", params, tel, ltf, None, allocator="numpy",
                     **kw)
    for rr, rj in zip(ref.history, res.history):
        np.testing.assert_allclose(rj.dropout_rates, rr.dropout_rates,
                                   atol=5e-3)
