"""Uploaded-parameter selection (Algorithm 2): masks + variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import selection
from repro.core.importance import (channel_importance,
                                   elementwise_importance)

pytestmark = pytest.mark.flcore


def _params(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "l1": {"w": scale * jax.random.normal(k1, (12, 32)),
               "b": jnp.zeros(32)},
        "l2": {"w": scale * jax.random.normal(k2, (32, 16))},
        "out": {"w": scale * jax.random.normal(k3, (16, 8))},
    }


@pytest.mark.parametrize("scheme", selection.SCHEMES)
@pytest.mark.parametrize("rate", [0.0, 0.25, 0.5, 0.9])
def test_mask_density_matches_rate(scheme, rate):
    key = jax.random.PRNGKey(0)
    p_old = _params(key)
    p_new = jax.tree_util.tree_map(
        lambda x: x + 0.1 * jax.random.normal(key, x.shape), p_old)
    m = selection.build_masks(p_old, p_new, jnp.asarray(rate),
                              config=selection.SelectionConfig(scheme=scheme),
                              rng=jax.random.PRNGKey(1))
    for (path, leaf), (_, mask) in zip(
            jax.tree_util.tree_flatten_with_path(p_new)[0],
            jax.tree_util.tree_flatten_with_path(m)[0]):
        nch = leaf.shape[-1]
        keep = int(np.ceil(nch * (1 - rate)))
        assert int(mask.sum()) == keep, jax.tree_util.keystr(path)
        assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}


def test_feddd_selects_highest_importance_channels():
    key = jax.random.PRNGKey(0)
    w_old = jax.random.normal(key, (6, 10))
    # channel 3 gets a huge update -> must be kept at any rate < 1
    w_new = w_old.at[:, 3].add(100.0)
    scores = channel_importance(w_old, w_new, channel_axis=-1)
    assert int(jnp.argmax(scores)) == 3
    m = selection.build_masks({"w": w_old}, {"w": w_new}, jnp.asarray(0.8))
    assert float(m["w"][0, 3]) == 1.0


def test_zero_rate_keeps_everything():
    key = jax.random.PRNGKey(0)
    p = _params(key)
    m = selection.build_masks(p, p, jnp.asarray(0.0))
    assert float(selection.mask_density(p, m)) == 1.0


def test_elementwise_importance_eps_guard():
    w_old = jnp.zeros((4, 4))
    w_new = jnp.ones((4, 4))
    imp = elementwise_importance(w_old, w_new)
    assert bool(jnp.all(jnp.isfinite(imp)))


def test_coverage_rectification_prefers_rare_channels():
    """Eq. (21): lower CR(k) boosts the index."""
    key = jax.random.PRNGKey(2)
    w_old = jax.random.normal(key, (8, 6))
    w_new = w_old * 1.1
    cov = jnp.ones(6).at[2].set(0.1)      # channel 2 is rarely covered
    base = channel_importance(w_old, w_new, channel_axis=-1)
    rect = channel_importance(w_old, w_new, channel_axis=-1, coverage=cov)
    ratio = rect / base
    assert float(ratio[2]) == pytest.approx(10.0, rel=1e-4)


def test_always_upload_predicate():
    key = jax.random.PRNGKey(0)
    p = _params(key)
    m = selection.build_masks(
        p, p, jnp.asarray(0.9),
        always_upload=lambda name: "out" in name)
    assert float(m["out"]["w"].min()) == 1.0
    assert float(m["l1"]["w"].sum()) < m["l1"]["w"].size


@settings(max_examples=20, deadline=None)
@given(c=st.integers(2, 64), f=st.integers(1, 32),
       rate=st.floats(0.0, 0.99), seed=st.integers(0, 1000))
def test_property_mask_exact_topk(c, f, rate, seed):
    key = jax.random.PRNGKey(seed)
    w_old = jax.random.normal(key, (f, c))
    w_new = w_old + 0.3 * jax.random.normal(jax.random.fold_in(key, 1),
                                            (f, c))
    m = selection.build_masks({"w": w_old}, {"w": w_new}, jnp.asarray(rate))
    keep = int(np.ceil(c * (1 - rate)))
    scores = channel_importance(w_old, w_new, channel_axis=-1)
    kept_idx = set(np.where(np.asarray(m["w"][0]) > 0)[0].tolist())
    top_idx = set(np.argsort(-np.asarray(scores))[:keep].tolist())
    # identical up to score ties
    s = np.asarray(scores)
    if len(np.unique(s)) == c:
        assert kept_idx == top_idx
    else:
        assert len(kept_idx) == keep
