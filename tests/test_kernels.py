"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels.importance import ops as imp_ops
from repro.kernels.importance.ref import channel_importance_ref
from repro.kernels.masked_merge import ops as mm_ops
from repro.kernels.masked_merge.ref import masked_merge_ref
from repro.kernels.sparse_agg import ops as agg_ops
from repro.kernels.sparse_agg.ref import masked_weighted_sum_ref

SHAPES_2D = [(8, 16), (64, 128), (100, 300), (7, 1000), (1000, 7),
             (256, 512), (257, 513), (3, 3)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_importance_kernel_sweep(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    wo = jax.random.normal(key, shape).astype(dtype)
    wn = (wo.astype(jnp.float32)
          + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), shape)
          ).astype(dtype)
    got = imp_ops.channel_importance(wo, wn, channel_axis=0)
    want = channel_importance_ref(wo, wn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-5)


@pytest.mark.parametrize("rank_shape", [(4, 6, 10), (3, 4, 5, 6)])
@pytest.mark.parametrize("axis", [0, -1])
def test_importance_kernel_rank_axis(rank_shape, axis):
    key = jax.random.PRNGKey(0)
    wo = jax.random.normal(key, rank_shape)
    wn = wo * 1.07
    got = imp_ops.channel_importance(wo, wn, channel_axis=axis)
    c = rank_shape[axis]
    ref_in_o = jnp.moveaxis(wo, axis, 0).reshape(c, -1)
    ref_in_n = jnp.moveaxis(wn, axis, 0).reshape(c, -1)
    want = channel_importance_ref(ref_in_o, ref_in_n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5)


@pytest.mark.parametrize("n,c,f", [(2, 8, 16), (4, 64, 128), (7, 100, 300),
                                   (16, 33, 70), (32, 128, 256)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparse_agg_kernel_sweep(n, c, f, dtype):
    key = jax.random.PRNGKey(n * 1000 + c)
    sw = jax.random.normal(key, (n, c, f)).astype(dtype)
    sm = (jax.random.uniform(jax.random.fold_in(key, 1), (n, c, 1))
          > 0.5).astype(dtype)
    wts = jax.random.uniform(jax.random.fold_in(key, 2), (n,)) + 0.5
    num, den = agg_ops.masked_weighted_sum(sw, sm, wts)
    wn, wd = masked_weighted_sum_ref(
        sw, jnp.broadcast_to(sm, sw.shape), wts)
    np.testing.assert_allclose(np.asarray(num), np.asarray(wn),
                               rtol=5e-3 if dtype == jnp.bfloat16 else 3e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(den), np.asarray(wd),
                               rtol=3e-5, atol=1e-5)


@pytest.mark.parametrize("c,f", [(8, 16), (64, 128), (100, 37), (7, 7),
                                 (300, 500)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_merge_kernel_sweep(c, f, dtype):
    key = jax.random.PRNGKey(c * 100 + f)
    g = jax.random.normal(key, (c, f)).astype(dtype)
    l = jax.random.normal(jax.random.fold_in(key, 1), (c, f)).astype(dtype)
    m = (jax.random.uniform(jax.random.fold_in(key, 2), (c,))
         > 0.5).astype(jnp.float32)
    got = mm_ops.masked_merge(g, l, m, channel_axis=0)
    want = masked_merge_ref(g, l, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 80), f=st.integers(1, 120), seed=st.integers(0, 99))
def test_property_importance_matches_oracle(c, f, seed):
    key = jax.random.PRNGKey(seed)
    wo = jax.random.normal(key, (c, f))
    wn = wo + jax.random.normal(jax.random.fold_in(key, 1), (c, f))
    got = imp_ops.channel_importance(wo, wn, channel_axis=0)
    want = channel_importance_ref(wo, wn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 60), f=st.integers(1, 90), seed=st.integers(0, 99))
def test_property_merge_is_select(c, f, seed):
    """Merged output rows equal either G or L exactly (binary mask)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (c, f))
    l = jax.random.normal(jax.random.fold_in(key, 1), (c, f))
    m = (jax.random.uniform(jax.random.fold_in(key, 2), (c,))
         > 0.5).astype(jnp.float32)
    out = np.asarray(mm_ops.masked_merge(g, l, m, channel_axis=0))
    gn, ln = np.asarray(g), np.asarray(l)
    for i in range(c):
        src = gn[i] if float(m[i]) == 1.0 else ln[i]
        np.testing.assert_allclose(out[i], src, rtol=1e-6)


# ----------------------------- flash attention ------------------------------

import math

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_ref


@pytest.mark.parametrize("shape", [(2, 64, 4, 2, 32), (1, 100, 8, 8, 16),
                                   (2, 96, 4, 1, 32), (1, 130, 4, 2, 48)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, causal, window, dtype):
    b, s, h, hkv, hd = shape
    key = jax.random.PRNGKey(sum(shape))
    q = jax.random.normal(key, (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, s, hkv, hd)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=32, bk=16, interpret=True)
    want = gqa_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(8, 140), hd=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 99))
def test_property_flash_matches_ref(s, hd, seed):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, s, 4, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, hd))
    got = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                          interpret=True)
    want = gqa_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)
