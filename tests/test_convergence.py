"""Theorem 2 bound: feasibility condition + monotonicity claims (§5)."""

import dataclasses

import pytest

from repro.core.convergence import (BoundInputs, eta_max, residual_error,
                                    theorem2_bound)

BASE = BoundInputs(L=4.0, eta=0.01, eps=0.1, sigma_sq_mean=1.0,
                   f0_minus_fstar=10.0, h=5, T=1000)


def test_bound_finite_for_small_eta():
    assert theorem2_bound(BASE) < float("inf")


def test_bound_infinite_beyond_eta_max():
    bad = dataclasses.replace(BASE, eta=eta_max(BASE.L, BASE.eps) * 1.01)
    assert theorem2_bound(bad) == float("inf")


def test_residual_monotone_in_h():
    """Paper §5: residual error is monotone increasing in h."""
    vals = [residual_error(dataclasses.replace(BASE, h=h))
            for h in (1, 2, 5, 10, 50)]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


def test_residual_monotone_in_eps():
    vals = [residual_error(dataclasses.replace(BASE, eps=e))
            for e in (0.0, 0.05, 0.1, 0.2)]
    assert vals[0] == pytest.approx(0.0, abs=1e-12)
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


def test_bound_vanishes_with_T_when_eps_zero():
    """eps=0 (full upload) -> FedAvg O(1/T) rate (paper §5)."""
    b0 = dataclasses.replace(BASE, eps=0.0, T=100)
    b1 = dataclasses.replace(BASE, eps=0.0, T=100_000)
    assert theorem2_bound(b1) < theorem2_bound(b0)
    assert theorem2_bound(b1) == pytest.approx(
        theorem2_bound(b0) * 100 / 100_000, rel=1e-6)


def test_eta_max_decreases_with_eps():
    assert eta_max(4.0, 0.5) < eta_max(4.0, 0.1) < eta_max(4.0, 0.0)
